"""Activation-sharding context: logical `with_sharding_constraint` helpers.

Model code calls `constrain(x, "dp", None, "tp")` with *logical* axes; the
launcher installs the mesh via `activation_mesh(mesh)`.  Without an installed
mesh (unit tests, single-device runs) constraints are no-ops, so layer code
stays mesh-agnostic.  Dims that don't divide their mapped axes fall back to
replicated — same policy as the parameter rules.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


@contextlib.contextmanager
def activation_mesh(mesh: Mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def current_mesh() -> Optional[Mesh]:
    return _MESH


def _resolve(mesh: Mesh, logical, shape) -> P:
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    mapping = {"dp": dp, "tp": ("model",) if "model" in names else ()}
    out = []
    used: set = set()
    for dim, logi in zip(shape, logical):
        axes = mapping.get(logi, ()) if logi else ()
        axes = tuple(a for a in axes if a not in used)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if not axes or size == 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    return P(*out)


def constrain(x: jax.Array, *logical):
    """Apply a logical activation-sharding constraint (no-op without a mesh)."""
    if _MESH is None or _MESH.size == 1:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = _resolve(_MESH, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
