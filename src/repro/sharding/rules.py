"""Rule-based parameter/activation sharding with divisibility fallback.

Logical axes:
  fsdp -> the data-parallel mesh axes (("pod","data") / ("data",)) — FSDP
          weight sharding + ZeRO optimizer-state sharding.
  tp   -> the model axis — tensor/expert parallelism.

A dim whose size does not divide the mapped mesh axes is replicated instead
(e.g. 8 KV heads on a 16-way model axis).  Rules are keyed on (leaf name,
rank); params stacked with a leading scan-repeat dim get None prepended
automatically.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# (name, rank) -> logical spec (per unstacked shape)
_PARAM_RULES: dict[tuple[str, int], tuple] = {
    ("embed", 2): ("tp", "fsdp"),
    ("lm_head", 2): ("fsdp", "tp"),
    ("scale", 1): (None,),
    # attention
    ("w_q", 2): ("fsdp", "tp"),
    ("w_k", 2): ("fsdp", "tp"),
    ("w_v", 2): ("fsdp", "tp"),
    ("w_o", 2): ("tp", "fsdp"),
    # MLA
    ("w_dkv", 2): ("fsdp", None),
    ("w_kr", 2): ("fsdp", None),
    ("w_uk", 2): ("fsdp", "tp"),
    ("w_uv", 2): ("fsdp", "tp"),
    # dense ffn
    ("w_gate", 2): ("fsdp", "tp"),
    ("w_up", 2): ("fsdp", "tp"),
    ("w_down", 2): ("tp", "fsdp"),
    # moe (experts over tp, fsdp within the expert)
    ("router", 2): ("fsdp", None),
    ("w_gate", 3): ("tp", "fsdp", None),
    ("w_up", 3): ("tp", "fsdp", None),
    ("w_down", 3): ("tp", "fsdp", None),
    # mamba
    ("in_proj", 2): ("fsdp", "tp"),
    ("conv_w", 2): (None, "tp"),
    ("conv_b", 1): ("tp",),
    ("x_proj", 2): ("tp", None),
    ("dt_proj", 2): (None, "tp"),
    ("dt_bias", 1): ("tp",),
    ("A_log", 2): ("tp", None),
    ("D", 1): ("tp",),
    ("out_proj", 2): ("tp", "fsdp"),
    # mlstm
    ("up_proj", 2): ("fsdp", "tp"),
    ("down_proj", 2): ("tp", "fsdp"),
    ("w_i", 2): ("fsdp", None),
    ("w_f", 2): ("fsdp", None),
    ("b_i", 1): (None,),
    ("b_f", 1): (None,),
    ("gn_scale", 1): ("tp",),
}

# decode-cache leaves: (name, rank) -> logical spec including the leading R dim
# seq-dim sharding is decided dynamically (see cache_sharding).
_CACHE_SEQ_LEAVES = {"k", "v", "ckv", "kr", "xk", "xv"}
_CACHE_RULES: dict[tuple[str, int], tuple] = {
    ("h", 4): (None, "dp", "tp", None),          # mamba state (R,B,di,N)
    ("conv", 4): (None, "dp", None, "tp"),       # conv buffer (R,B,dc-1,di)
    ("C", 5): (None, "dp", None, "tp", None),    # mlstm matrix (R,B,H,dh,dh)
    ("n", 4): (None, "dp", None, "tp"),
    ("m", 3): (None, "dp", None),
}


def _axes_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(mesh, logical: tuple, shape: tuple, *, fsdp_axes, tp_axes) -> P:
    """Map logical spec -> PartitionSpec with divisibility fallback."""
    mapping = {"fsdp": fsdp_axes, "tp": tp_axes, "dp": fsdp_axes}
    out = []
    used: set = set()
    for dim, logi in zip(shape, logical):
        axes = mapping.get(logi) if logi else None
        if axes is None:
            out.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        axes_t = tuple(a for a in axes_t if a not in used)
        if not axes_t or dim % _axes_size(mesh, axes_t) != 0:
            out.append(None)
            continue
        used.update(axes_t)
        out.append(axes_t[0] if len(axes_t) == 1 else axes_t)
    return P(*out)


def param_sharding(mesh, params, *, mode: str = "train"):
    """Sharding tree for a param pytree.  mode: 'train' (FSDP×TP) or
    'serve' (TP only + replication — decode avoids per-step weight gathers
    unless the model cannot fit, see serve_big)."""
    from repro.launch.mesh import dp_axes
    fsdp = dp_axes(mesh) if mode in ("train", "serve_big") else ()
    tp = ("model",)

    def leaf_sharding(path, leaf):
        name = _leaf_name(path)
        # params under a scanned stack ("blocks"/"enc_blocks") carry a leading
        # repeat dim; look the rule up at the *unstacked* rank (a stacked dense
        # (R,d,ff) must not match the MoE (E,d,ff) rule).
        stacked = any(getattr(e, "key", None) in ("blocks", "enc_blocks")
                      for e in path)
        rank = leaf.ndim - (1 if stacked else 0)
        rule = _PARAM_RULES.get((name, rank))
        if rule is None:
            return NamedSharding(mesh, P())
        logical = ((None,) + rule) if stacked else rule
        spec = resolve_spec(mesh, logical, leaf.shape,
                            fsdp_axes=fsdp or None, tp_axes=tp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_sharding, params)


def batch_sharding(mesh, batch):
    """Data inputs: batch dim over (pod, data)."""
    from repro.launch.mesh import dp_axes
    dp = dp_axes(mesh)
    dp_spec = dp[0] if len(dp) == 1 else dp

    def leaf(path, x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        if x.shape[0] % _axes_size(mesh, dp) == 0:
            return NamedSharding(mesh, P(dp_spec, *(None,) * (x.ndim - 1)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, batch)


def cache_sharding(mesh, cache, *, seq_shard_axis: str | None = None):
    """Decode-cache sharding.  KV-type leaves (R,B,S,...): batch over dp when
    divisible; when batch cannot shard (e.g. long_500k B=1) the sequence dim
    shards over dp instead.  seq_shard_axis optionally forces additional seq
    sharding over the model axis (sequence-parallel decode, §Perf)."""
    from repro.launch.mesh import dp_axes
    dp = dp_axes(mesh)
    dp_size = _axes_size(mesh, dp)
    dp_spec = dp[0] if len(dp) == 1 else dp

    def leaf(path, x):
        name = _leaf_name(path)
        if (name, x.ndim) in _CACHE_RULES:
            spec = resolve_spec(mesh, _CACHE_RULES[(name, x.ndim)], x.shape,
                                fsdp_axes=dp, tp_axes=("model",))
            return NamedSharding(mesh, spec)
        if name in _CACHE_SEQ_LEAVES:
            R, B, S = x.shape[0], x.shape[1], x.shape[2]
            parts = [None, None, None] + [None] * (x.ndim - 3)
            if B % dp_size == 0:
                parts[1] = dp_spec
            elif S % dp_size == 0:
                parts[2] = dp_spec
            # kv-head dim over model; when the heads don't divide (GQA with
            # few KV heads) shard the sequence dim over model instead — the
            # cache is by far the largest serving tensor.
            tp_size = mesh.shape.get("model", 1)
            if x.ndim >= 4 and x.shape[3] % tp_size == 0 and tp_size > 1:
                parts[3] = "model"
            elif parts[2] is None and S % tp_size == 0 and tp_size > 1:
                parts[2] = "model"
            return NamedSharding(mesh, P(*parts))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, cache)


def opt_state_sharding(mesh, params_sharding, opt_state):
    """Moments inherit parameter sharding; scalars replicated."""
    def match(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return None
    flat_p = {_path_str(p): s for p, s in
              jax.tree_util.tree_flatten_with_path(params_sharding)[0]}

    def leaf(path, x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        # opt-state paths look like ("m", <param path...>) — strip the head
        sub = _path_str(path[1:])
        if sub in flat_p:
            return flat_p[sub]
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, opt_state)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _path_str(path) -> str:
    return "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
