"""Scenario-runner CLI for the cluster control plane.

  PYTHONPATH=src python -m repro.cluster.run --list
  PYTHONPATH=src python -m repro.cluster.run --list-policies
  PYTHONPATH=src python -m repro.cluster.run --scenario smoke \
      --policy tally-priority
  PYTHONPATH=src python -m repro.cluster.run --scenario smoke
  PYTHONPATH=src python -m repro.cluster.run --scenario diurnal-mixed \
      --devices 20000 --hours 12 --seed 0 --engine xla --out report.json
  PYTHONPATH=src python -m repro.cluster.run --scenario fault-storm \
      --no-graceful-exit --devices 500 --hours 2
  PYTHONPATH=src python -m repro.cluster.run --check-schema report.json

Reports are deterministic JSON (no wall-clock fields): the same scenario,
devices, hours, and seed always produce byte-identical output — including
across tick engines (--engine numpy and --engine xla emit the same bytes;
CI diffs them).  Timing goes to stderr.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.cluster.control import REPORT_SCHEMA, run_scenario
from repro.cluster.scenario import SCENARIOS, scenario_by_name
from repro.policies import available, resolve

# top-level keys every v1 report must carry (None allowed for unused parts)
SCHEMA_KEYS = ("schema", "scenario", "sim", "jobs", "faults", "agents",
               "autoscaler", "pools", "events")


def check_schema(report: dict) -> list[str]:
    """Validate the v1 report shape; returns a list of problems (empty=ok)."""
    problems = []
    if report.get("schema") != REPORT_SCHEMA:
        problems.append(f"schema != {REPORT_SCHEMA!r}: "
                        f"{report.get('schema')!r}")
    for k in SCHEMA_KEYS:
        if k not in report:
            problems.append(f"missing key {k!r}")
    ev = report.get("events") or {}
    for k in ("n_events", "counts", "digest"):
        if k not in ev:
            problems.append(f"events missing {k!r}")
    sim = report.get("sim") or {}
    for k in ("policy", "n_jobs", "n_finished", "avg_slowdown",
              "errors_injected", "errors_propagated"):
        if k not in sim:
            problems.append(f"sim missing {k!r}")
    if not isinstance(report.get("pools"), list) or not report["pools"]:
        problems.append("pools missing or empty")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cluster.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--scenario", default="smoke",
                    help="registry name (see --list)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--hours", type=float, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--policy", default=None,
                    help="sharing-policy override (see --list-policies)")
    ap.add_argument("--engine", default=None, choices=("numpy", "xla"),
                    help="tick-engine backend; reports are byte-identical "
                         "across engines (numpy is the faster one on CPU "
                         "today — see README 'Performance')")
    ap.add_argument("--tick", type=float, default=None)
    gx = ap.add_mutually_exclusive_group()
    gx.add_argument("--graceful-exit", dest="graceful", action="store_true",
                    default=None)
    gx.add_argument("--no-graceful-exit", dest="graceful",
                    action="store_false")
    ap.add_argument("--out", default=None, help="write report JSON here "
                    "(default: stdout)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--list-policies", action="store_true",
                    help="list registered sharing policies and exit")
    ap.add_argument("--check-schema", metavar="REPORT.json", default=None,
                    help="validate an existing report file and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, sc in sorted(SCENARIOS.items()):
            print(f"{name:16s} {sc.description}")
        return 0
    if args.list_policies:
        for name in available():
            pol = resolve(name)
            tags = "".join(t for t, on in
                           (("[needs-predictor] ", pol.needs_predictor),
                            ("[no-scheduling] ", not pol.wants_scheduling))
                           if on)
            print(f"{name:18s} {tags}{pol.description}")
        return 0
    if args.check_schema:
        with open(args.check_schema) as f:
            problems = check_schema(json.load(f))
        for p in problems:
            print(f"SCHEMA: {p}", file=sys.stderr)
        print("schema " + ("FAIL" if problems else "OK"), file=sys.stderr)
        return 1 if problems else 0

    sc = scenario_by_name(args.scenario)
    t0 = time.perf_counter()
    report = run_scenario(
        sc, n_devices=args.devices, hours=args.hours, seed=args.seed,
        policy=args.policy, tick_s=args.tick, graceful_exit=args.graceful,
        engine=args.engine)
    wall = time.perf_counter() - t0
    out = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(out + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(out)
    s = report["sim"]
    print(f"[{sc.name}] {s['policy']} n={report['scenario']['n_devices']} "
          f"{report['scenario']['hours']}h: finished "
          f"{s['n_finished']}/{s['n_jobs']} jobs, slowdown "
          f"{s['avg_slowdown']:.3f}x, errors {s['errors_propagated']}"
          f"/{s['errors_injected']} propagated, "
          f"{report['events']['n_events']} events "
          f"({wall:.1f}s wall)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
