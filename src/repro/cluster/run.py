"""Deprecated scenario-runner entry point.

``python -m repro.cluster.run`` is now a thin delegate of the unified CLI —
``python -m repro sim`` (see :mod:`repro.cli`).  Flags and stdout bytes are
unchanged; a deprecation note goes to stderr.  ``check_schema`` /
``SCHEMA_KEYS`` live in :mod:`repro.cluster.control` now and are re-exported
here for backward compatibility.
"""
from __future__ import annotations

import sys

from repro.cluster.control import (REPORT_SCHEMA, SCHEMA_KEYS,  # noqa: F401
                                   check_schema)
from repro.cli import deprecation_note, sim_main


def main(argv=None) -> int:
    deprecation_note("python -m repro.cluster.run", "python -m repro sim")
    return sim_main(argv, prog="python -m repro.cluster.run")


if __name__ == "__main__":
    sys.exit(main())
