"""Offline-job lifecycle: submit → queue → place → run → checkpoint →
preempt/migrate → requeue → complete.

The :class:`JobManager` is a pure observer of engine events (it never mutates
the simulator) that gives every offline job a legal state machine and the
checkpoint-restore cost model the engine's struct-of-arrays core does not
track per job: queue waits, placement counts, preemptions, work lost since
the last checkpoint, and the restart overhead (image pull + restore) paid on
every re-placement after a preemption.

Legality is enforced at transition time: placing a job that is already
RUNNING (double placement) or placing/finishing one that is COMPLETED
(run-after-complete) raises :class:`LifecycleError` in strict mode — the
subsystem tests run every scenario strict.
"""
from __future__ import annotations

import dataclasses
import enum

from repro.cluster.events import Event, EventBus, EventKind


class JobState(str, enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"


class LifecycleError(RuntimeError):
    """An illegal job-lifecycle transition."""


@dataclasses.dataclass
class JobRecord:
    job_id: int
    model: str
    submit_s: float
    duration_s: float
    state: JobState = JobState.QUEUED
    device: int = -1
    placements: int = 0
    preemptions: int = 0
    queue_wait_s: float = 0.0          # total time spent QUEUED before runs
    lost_work_s: float = 0.0           # progress − checkpoint at evictions
    restore_overhead_s: float = 0.0    # modeled restart cost (re-placements)
    queued_at: float = 0.0
    completed_at: float | None = None
    jct_s: float | None = None


class JobManager:
    """Event-driven lifecycle tracker for every offline job in a scenario."""

    def __init__(self, bus: EventBus, *, restart_delay_s: float = 90.0,
                 strict: bool = True):
        self.bus = bus
        self.restart_delay_s = restart_delay_s
        self.strict = strict
        self.jobs: dict[int, JobRecord] = {}
        self.violations: list[str] = []
        for kind in (EventKind.JOB_SUBMIT, EventKind.JOB_START,
                     EventKind.JOB_FINISH, EventKind.JOB_EVICT):
            bus.subscribe(self._on_event, kind)

    # ------------------------------------------------------------ transitions
    def _illegal(self, msg: str) -> None:
        if self.strict:
            raise LifecycleError(msg)
        self.violations.append(msg)

    def _on_event(self, ev: Event) -> None:
        data = dict(ev.data)
        if ev.kind is EventKind.JOB_SUBMIT:
            if ev.job in self.jobs:
                self._illegal(f"job {ev.job} submitted twice")
                return
            self.jobs[ev.job] = JobRecord(
                job_id=ev.job, model=data.get("model", "?"),
                submit_s=ev.t, duration_s=data.get("duration_s", 0.0),
                queued_at=ev.t)
            return
        rec = self.jobs.get(ev.job)
        if rec is None:
            self._illegal(f"{ev.kind.value} for unknown job {ev.job}")
            return
        if ev.kind is EventKind.JOB_START:
            if rec.state is JobState.RUNNING:
                self._illegal(f"job {ev.job} double-placed "
                              f"(devices {rec.device} and {ev.device})")
                return
            if rec.state is JobState.COMPLETED:
                self._illegal(f"job {ev.job} placed after completion")
                return
            rec.queue_wait_s += ev.t - rec.queued_at
            rec.state = JobState.RUNNING
            rec.device = ev.device
            rec.placements += 1
            if rec.preemptions:
                # checkpoint-restore cost model: every re-placement after a
                # preemption pays image pull + restore before making progress
                rec.restore_overhead_s += self.restart_delay_s
        elif ev.kind is EventKind.JOB_EVICT:
            if rec.state is not JobState.RUNNING:
                self._illegal(f"job {ev.job} evicted while {rec.state.value}")
                return
            rec.device = -1
            requeued = bool(data.get("requeued", True))
            rec.lost_work_s += max(
                0.0, data.get("progress_s", 0.0) - data.get("checkpoint_s", 0.0))
            if requeued:
                rec.state = JobState.QUEUED
                rec.queued_at = ev.t
                rec.preemptions += 1
            else:
                # evicted past its duration: treat as completed-at-eviction
                rec.state = JobState.COMPLETED
                rec.completed_at = ev.t
                rec.jct_s = ev.t - rec.submit_s
        elif ev.kind is EventKind.JOB_FINISH:
            if rec.state is JobState.COMPLETED:
                self._illegal(f"job {ev.job} finished after completion")
                return
            if rec.state is not JobState.RUNNING:
                self._illegal(f"job {ev.job} finished while {rec.state.value}")
                return
            rec.state = JobState.COMPLETED
            rec.device = -1
            rec.completed_at = ev.t
            rec.jct_s = data.get("jct_s", ev.t - rec.submit_s)

    # --------------------------------------------------------------- queries
    def by_state(self) -> dict[str, int]:
        out = {s.value: 0 for s in JobState}
        for rec in self.jobs.values():
            out[rec.state.value] += 1
        return out

    def summary(self) -> dict:
        recs = list(self.jobs.values())
        done = [r for r in recs if r.state is JobState.COMPLETED]
        n = max(len(recs), 1)
        return {
            "n_jobs": len(recs),
            "by_state": self.by_state(),
            "completed": len(done),
            "avg_jct_s": (sum(r.jct_s or 0.0 for r in done) / len(done)
                          if done else 0.0),
            "avg_queue_wait_s": sum(r.queue_wait_s for r in recs) / n,
            "total_preemptions": sum(r.preemptions for r in recs),
            "max_preemptions": max((r.preemptions for r in recs), default=0),
            "total_placements": sum(r.placements for r in recs),
            "total_lost_work_s": sum(r.lost_work_s for r in recs),
            "total_restore_overhead_s": sum(r.restore_overhead_s
                                            for r in recs),
            "lifecycle_violations": len(self.violations),
        }
