"""Heterogeneous fleets: named GPU pools with per-pool type, speed, and HBM.

A :class:`FleetSpec` expands a list of :class:`GPUPool` fractions into the
per-device arrays the vectorized engine consumes (``gpu_type``, ``speed``,
``hbm_gb``, ``pool_of``).  Pools are contiguous device ranges sized by the
largest-remainder method, so the same spec always expands to the same fleet.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class GPUPool:
    """One homogeneous slice of the fleet."""
    name: str
    gpu_type: str              # predictor model key (e.g. "T4", "A10")
    weight: float              # fraction of the fleet (normalized over pools)
    speed: float = 1.0         # offline-throughput multiplier vs T4
    hbm_gb: float = 16.0       # device memory (T4-class default)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


DEFAULT_POOLS = (GPUPool("t4", "T4", weight=0.75, speed=1.0, hbm_gb=16.0),
                 GPUPool("a10", "A10", weight=0.25, speed=1.35, hbm_gb=24.0))


class FleetSpec:
    """Per-device arrays for a pooled fleet (the simulator's ``fleet=`` duck
    type: ``gpu_type``, ``speed``, ``hbm_gb``, ``pool_of``, ``pool_names``)."""

    def __init__(self, n_devices: int,
                 pools: tuple[GPUPool, ...] = DEFAULT_POOLS):
        if not pools:
            raise ValueError("FleetSpec needs at least one pool")
        self.pools = tuple(pools)
        self.n = n_devices
        total_w = sum(p.weight for p in pools)
        if total_w <= 0:
            raise ValueError("pool weights must sum to > 0")
        # largest-remainder apportionment -> deterministic pool sizes
        quotas = [p.weight / total_w * n_devices for p in pools]
        counts = [int(q) for q in quotas]
        rem = n_devices - sum(counts)
        order = sorted(range(len(pools)),
                       key=lambda i: (quotas[i] - counts[i], -i), reverse=True)
        for i in order[:rem]:
            counts[i] += 1
        self.counts = counts
        self.pool_names = [p.name for p in pools]
        self.pool_of = np.repeat(np.arange(len(pools), dtype=np.int64),
                                 counts)
        self.gpu_type = [pools[p].gpu_type for p in self.pool_of]
        self.speed = np.array([pools[p].speed for p in self.pool_of],
                              np.float64)
        self.hbm_gb = np.array([pools[p].hbm_gb for p in self.pool_of],
                               np.float64)

    @property
    def gpu_types(self) -> tuple[str, ...]:
        """Distinct predictor model keys, in pool order."""
        seen: list[str] = []
        for p in self.pools:
            if p.gpu_type not in seen:
                seen.append(p.gpu_type)
        return tuple(seen)

    def to_dict(self) -> dict:
        return {"n_devices": self.n,
                "pools": [p.to_dict() for p in self.pools],
                "counts": list(self.counts)}
