"""Per-device node agents (§5's DeviceProbe + SysMonitor daemons).

In production every device runs two agents: DeviceProbe samples GPU metrics
and SysMonitor drives the protection state machine; the global scheduler only
trusts devices whose agents are reporting.  :class:`NodeAgentFleet` models
that layer in struct-of-arrays form: each agent heartbeats every
``heartbeat_s`` (dropping a report with probability ``drop_rate`` — flaky
daemons, kubelet restarts, network partitions), and a device whose last
report is older than ``stale_after`` heartbeats is *stale*: the control plane
masks it out of scheduling until the agent reports again.

The agent snapshot wraps the three telemetry sources a real NodeAgent ships:
the VectorSysMonitor state code, the device's current dynamic-SM share, and
the kernel-throttle duty proxy (the SM share actually exercised by the
offline partner).  Snapshot values are *as of each device's last successful
heartbeat* — staleness is visible in the data, exactly the failure mode the
paper's global manager has to tolerate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster.events import EventBus, EventKind
from repro.core.dynamic_sm import dynamic_sm_array


@dataclasses.dataclass(frozen=True)
class AgentConfig:
    heartbeat_s: float = 30.0
    stale_after: float = 3.0      # heartbeats missed before a device is stale
    drop_rate: float = 0.0        # P(miss a heartbeat report)


def stale_mask(now, last_heartbeat, timeout_s):
    """THE failure-detection predicate: a node is stale/dead when its last
    heartbeat is strictly older than ``timeout_s``.

    Shared by :class:`NodeAgentFleet` (vectorized staleness masking) and
    :class:`repro.runtime.fault_tolerance.HeartbeatMonitor` (per-node
    training-launch supervision) so the two detectors can never drift.
    Works element-wise on arrays and on scalars."""
    return (np.asarray(now) - np.asarray(last_heartbeat)) > timeout_s


class NodeAgentFleet:
    """Vectorized per-device agent state: heartbeats, staleness, and the
    last-reported telemetry snapshot."""

    def __init__(self, n: int, cfg: AgentConfig, seed: int,
                 bus: EventBus | None = None):
        self.n = n
        self.cfg = cfg
        self.bus = bus
        # chaos seam: optional FaultInjector (crashed agents miss their
        # heartbeat; clock skew backdates reported timestamps).  Consults
        # never touch self.rng, so the no-chaos stream is unperturbed.
        self.fault_injector = None
        self.rng = np.random.default_rng(seed)
        self.last_report = np.zeros(n, np.float64)    # all report at t=0
        self.stale = np.zeros(n, bool)
        self.stale_episodes = 0
        self.stale_device_ticks = 0
        self.reports_sent = 0
        self.reports_dropped = 0
        self._next_beat = 0.0
        # last-reported telemetry (NaN until first report lands)
        self.seen = {k: np.full(n, np.nan, np.float64)
                     for k in ("gpu_util", "sm_activity", "mem_used",
                               "sm_clock", "sm_share", "duty")}
        self.seen_state = np.full(n, -1, np.int8)     # SysMonitor state code

    def observe(self, sim, t: float, telemetry: dict) -> np.ndarray:
        """One control-plane tick: heartbeat if due, refresh staleness, and
        return the fresh-agent mask (True = agent reporting, schedulable)."""
        cfg = self.cfg
        inj = self.fault_injector
        if t >= self._next_beat:
            if cfg.drop_rate > 0.0:
                ok = self.rng.random(self.n) >= cfg.drop_rate
            else:
                ok = np.ones(self.n, bool)
            if inj is not None:
                down = inj.agent_outage(t)
                if down is not None:
                    ok = ok & ~down       # crashed agents miss the beat
            self.reports_sent += int(ok.sum())
            self.reports_dropped += int((~ok).sum())
            self.last_report[ok] = t
            if inj is not None:
                skew = inj.heartbeat_skew(t)
                if skew is not None:
                    # skewed clocks stamp reports in the past; enough skew
                    # makes a live device look stale until the episode ends
                    self.last_report[ok] = t - np.broadcast_to(
                        np.asarray(skew, np.float64), (self.n,))[ok]
            # a successful report carries the device's current telemetry
            share = sim.state.sm_share
            duty = np.where(sim.state.has_job, share, 0.0)
            for key, src in (("gpu_util", telemetry.get("gpu_util")),
                             ("sm_activity", telemetry.get("sm_activity")),
                             ("mem_used", telemetry.get("mem_used")),
                             ("sm_clock", telemetry.get("sm_clock")),
                             ("sm_share", share), ("duty", duty)):
                if src is not None:
                    np.copyto(self.seen[key], src, where=ok)
            np.copyto(self.seen_state, sim.monitor.state, where=ok)
            self._next_beat = t + cfg.heartbeat_s
        now_stale = stale_mask(t, self.last_report,
                               cfg.stale_after * cfg.heartbeat_s)
        went_stale = now_stale & ~self.stale
        recovered = ~now_stale & self.stale
        if self.bus is not None:
            for i in np.flatnonzero(went_stale):
                self.bus.emit(t, EventKind.AGENT_STALE, device=int(i))
            for i in np.flatnonzero(recovered):
                self.bus.emit(t, EventKind.AGENT_FRESH, device=int(i))
        self.stale_episodes += int(went_stale.sum())
        self.stale_device_ticks += int(now_stale.sum())
        self.stale = now_stale
        return ~now_stale

    def snapshot(self, now: float) -> dict:
        """Last-reported per-device telemetry (arrays; NaN = never reported)."""
        out = {k: v.copy() for k, v in self.seen.items()}
        out["monitor_state"] = self.seen_state.copy()
        out["stale"] = self.stale.copy()
        out["age_s"] = now - self.last_report
        # §4.3 recommendation from last-reported device SM activity (an
        # upper bound on the online share): what the dynamic-SM allocator
        # would grant an offline partner if it trusted this agent's
        # telemetry; never-reported devices conservatively get the floor
        act = np.nan_to_num(out["sm_activity"], nan=1.0)
        out["dyn_sm_recommended"] = dynamic_sm_array(act)
        return out

    def summary(self) -> dict:
        return {
            "heartbeat_s": self.cfg.heartbeat_s,
            "drop_rate": self.cfg.drop_rate,
            "reports_sent": self.reports_sent,
            "reports_dropped": self.reports_dropped,
            "stale_episodes": self.stale_episodes,
            "stale_device_ticks": self.stale_device_ticks,
            "stale_now": int(self.stale.sum()),
        }
