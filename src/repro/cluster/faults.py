"""Fault campaigns: seeded §4.2 ErrorKind injection at cluster scale.

A campaign drives extra offline-container errors into the fleet at
configurable per-pool rates, sampling kinds from the production mix
(:data:`repro.core.errors.ERROR_MIX` — Fig. 7 — unless overridden) and
routing every one through the engine's :class:`MixedErrorHandler` via
``ClusterSim.force_error``.  It measures what the paper's Table/Fig. 7
analysis measures: how many injected errors *propagate* to the co-located
online workload with graceful exit enabled vs disabled.

The campaign owns its own RNG stream (derived from the scenario seed), so it
never perturbs the engine's trace/failure stream: the same scenario with the
campaign on and off sees identical diurnal load and hardware failures.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.errors import ERROR_MIX, ErrorKind, error_from_uniform


@dataclasses.dataclass(frozen=True)
class FaultCampaignConfig:
    rate_per_device_hour: float = 0.0        # baseline rate for every pool
    pool_rates: tuple = ()                   # ((pool_name, rate), ...) overrides
    kind_weights: tuple = ()                 # ((kind_value, weight), ...); empty -> ERROR_MIX
    start_s: float = 0.0
    end_s: float = 1e18          # effectively "until the horizon" (JSON-safe)

    def rate_for(self, pool: str) -> float:
        for name, rate in self.pool_rates:
            if name == pool:
                return rate
        return self.rate_per_device_hour

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s


class FaultCampaign:
    """Tick-driven injector with per-kind injection/propagation accounting."""

    def __init__(self, cfg: FaultCampaignConfig, sim, seed: int):
        self.cfg = cfg
        self.sim = sim
        self.rng = np.random.default_rng(seed)
        n = sim.cfg.n_devices
        # per-device injection probability per tick-second
        rates = np.array([cfg.rate_for(name) for name in sim.pool_names])
        self.p_per_s = rates[sim.pool_of] / 3600.0
        self.any_rate = bool((rates > 0).any())
        if cfg.kind_weights:
            self.kinds = [ErrorKind(k) for k, _ in cfg.kind_weights]
            w = np.array([w for _, w in cfg.kind_weights], np.float64)
        else:
            self.kinds = list(ERROR_MIX)
            w = np.array([ERROR_MIX[k] for k in self.kinds], np.float64)
        self.cum = np.cumsum(w / w.sum())
        self.cum[-1] = 1.0   # cumsum can land 1-2 ulp short of 1.0; a draw
        #                      in that sliver would index past the last kind
        self.injected_by_kind: dict[str, int] = {}
        self.propagated_by_kind: dict[str, int] = {}
        self._n = n

    def _sample_kind(self, u: float) -> ErrorKind:
        if self.cfg.kind_weights:
            return self.kinds[int(np.searchsorted(self.cum, u, side="left"))]
        return error_from_uniform(u)

    def inject(self, t: float, dt: float) -> int:
        """Called once per tick *before* the engine tick; returns the number
        of errors injected.  Draws are fixed-shape per tick so the stream is
        reproducible regardless of fleet state."""
        if not self.any_rate or not self.cfg.active(t):
            return 0
        hit_u, kind_u = self.rng.random((2, self._n))
        hit = self.sim.state.has_job & (hit_u < self.p_per_s * dt)
        count = 0
        for i in np.flatnonzero(hit):
            kind = self._sample_kind(float(kind_u[i]))
            handled = self.sim.force_error(int(i), t, kind)
            if handled is None:
                continue
            count += 1
            k = kind.value
            self.injected_by_kind[k] = self.injected_by_kind.get(k, 0) + 1
            if handled.propagated:
                self.propagated_by_kind[k] = (
                    self.propagated_by_kind.get(k, 0) + 1)
        return count

    @property
    def injected(self) -> int:
        return sum(self.injected_by_kind.values())

    @property
    def propagated(self) -> int:
        return sum(self.propagated_by_kind.values())

    def propagation_rate(self) -> float:
        return self.propagated / self.injected if self.injected else 0.0

    def summary(self) -> dict:
        return {
            "injected": self.injected,
            "propagated": self.propagated,
            "propagation_rate": self.propagation_rate(),
            "injected_by_kind": dict(sorted(self.injected_by_kind.items())),
            "propagated_by_kind": dict(sorted(
                self.propagated_by_kind.items())),
        }
