"""repro.cluster — the event-driven cluster control plane (§5).

Layers the paper's deployment loop over the vectorized
:class:`repro.core.simulator.ClusterSim` engine:

* :mod:`repro.cluster.events` — typed events + deterministic event bus;
* :mod:`repro.cluster.agents` — per-device NodeAgent heartbeats/staleness
  wrapping SysMonitor, dynamic-SM, and throttle telemetry;
* :mod:`repro.cluster.jobs` — the offline-job lifecycle state machine
  (submit → queue → place → run → checkpoint → preempt → requeue/complete);
* :mod:`repro.cluster.faults` — fault campaigns injecting the §4.2
  ErrorKind mix through the mixed error handler;
* :mod:`repro.cluster.fleet` — heterogeneous GPU pools;
* :mod:`repro.cluster.scenario` — named, seeded, replayable scenario specs;
* :mod:`repro.cluster.control` — the ControlPlane that owns the tick loop;
* ``python -m repro.cluster.run`` — the scenario-runner CLI.
"""
from repro.cluster.control import ControlPlane, run_scenario
from repro.cluster.events import Event, EventBus, EventKind
from repro.cluster.faults import FaultCampaign, FaultCampaignConfig
from repro.cluster.fleet import FleetSpec, GPUPool
from repro.cluster.jobs import JobManager, JobState, LifecycleError
from repro.cluster.scenario import SCENARIOS, Scenario, scenario_by_name

__all__ = [
    "ControlPlane", "run_scenario", "Event", "EventBus", "EventKind",
    "FaultCampaign", "FaultCampaignConfig", "FleetSpec", "GPUPool",
    "JobManager", "JobState", "LifecycleError", "SCENARIOS", "Scenario",
    "scenario_by_name",
]
