"""The ControlPlane: owns the tick loop and wires every §5 deployment piece.

Layering (control plane ⇄ sim core)::

    Scenario ──► ControlPlane ──────────────────────────────┐
                   │  per tick, in order:                   │
                   │   1. submit due jobs  (JobManager)     │
                   │   2. inject faults    (FaultCampaign)  │
                   │   3. agent heartbeats (NodeAgentFleet) │──► EventBus
                   │   4. autoscale online pools            │     │
                   │   5. ClusterSim.step(t)  ◄─ SimHooks ──┘     ▼
                   │        (vectorized engine tick)         JSON report
                   └─► ClusterSim.finalize(t)

The engine stays a pure vectorized core; everything event-shaped lives up
here.  With all control-plane features neutral (no campaign, no heartbeat
drops, trace-driven jobs) the trajectory is identical to ``ClusterSim.run``
— that passthrough is what lets the figure benchmarks ride the same entry
point without renumbering.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.chaos import CHAOS_SCHEMA, ChaosCampaign
from repro.cluster.agents import NodeAgentFleet
from repro.cluster.events import EventBus, EventKind
from repro.cluster.faults import FaultCampaign
from repro.cluster.fleet import FleetSpec
from repro.cluster.jobs import JobManager
from repro.cluster.scenario import Scenario, scenario_by_name
from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.core.interference import ONLINE_SERVICE_PROFILES
from repro.core.simulator import (ClusterSim, SimConfig, SimHooks,
                                  build_sim_config)
from repro.core.traces import SERVICES, make_trace
from repro.obs import ALERTS_SCHEMA, OBS_SCHEMA, ObsPlane
from repro.policies import resolve as resolve_policy
from repro.serving_plane import SERVING_SCHEMA, ServingPlane

# v5: adds the top-level "resilience" section (chaos plane: injected
# infrastructure faults, the degradation-ladder engagements that answered
# them, fault↔recovery pairing; null when no chaos campaign ran).
# v4 added the "incidents" section (alert engine: rule catalog,
# incident lifecycle counts, stream digest; null when alerting is off).
# v3 added the "obs" section (observability plane: emitted-series counts
# and stream digests) and the events summary's "log_dropped" count.
# v2 added the "serving" section (request-level serving plane).
REPORT_SCHEMA = "repro.cluster.report/v5"

SCHEMA_KEYS = ("schema", "scenario", "sim", "jobs", "faults", "agents",
               "autoscaler", "serving", "pools", "scheduler", "events",
               "obs", "incidents", "resilience")

_SERVING_SVC_KEYS = ("arrived", "served", "shed", "p50_ms", "p99_ms",
                     "slo_ms", "slo_attainment")


def check_schema(report: dict) -> list[str]:
    """Structural lint of a campaign report; returns a list of problems
    (empty = OK).  Used by the CLI's ``--check-schema`` and CI."""
    problems = []
    if report.get("schema") != REPORT_SCHEMA:
        problems.append(f"schema != {REPORT_SCHEMA!r}: "
                        f"{report.get('schema')!r}")
    for k in SCHEMA_KEYS:
        if k not in report:
            problems.append(f"missing top-level key {k!r}")
    serving = report.get("serving")
    if serving is not None:
        if serving.get("schema") != SERVING_SCHEMA:
            problems.append(f"serving.schema != {SERVING_SCHEMA!r}: "
                            f"{serving.get('schema')!r}")
        for req in ("services", "total"):
            if req not in serving:
                problems.append(f"missing serving key {req!r}")
        for svc, row in sorted(serving.get("services", {}).items()):
            for k in _SERVING_SVC_KEYS:
                if k not in row:
                    problems.append(f"serving service {svc!r} missing {k!r}")
    obs = report.get("obs")
    if obs is not None:
        if obs.get("schema") != OBS_SCHEMA:
            problems.append(f"obs.schema != {OBS_SCHEMA!r}: "
                            f"{obs.get('schema')!r}")
        for req in ("metrics", "trace", "profile_phases"):
            if req not in obs:
                problems.append(f"missing obs key {req!r}")
        for section in ("metrics", "trace"):
            row = obs.get(section)
            if row is not None:
                for k in ("rows", "digest"):
                    if k not in row:
                        problems.append(f"obs.{section} missing {k!r}")
    incidents = report.get("incidents")
    if incidents is not None:
        if incidents.get("schema") != ALERTS_SCHEMA:
            problems.append(f"incidents.schema != {ALERTS_SCHEMA!r}: "
                            f"{incidents.get('schema')!r}")
        for req in ("rows", "digest", "rules", "windows", "total",
                    "open_end", "timeline"):
            if req not in incidents:
                problems.append(f"missing incidents key {req!r}")
    resilience = report.get("resilience")
    if resilience is not None:
        if resilience.get("schema") != CHAOS_SCHEMA:
            problems.append(f"resilience.schema != {CHAOS_SCHEMA!r}: "
                            f"{resilience.get('schema')!r}")
        for req in ("injected", "recovered", "unmatched",
                    "unmatched_by_kind", "open_end", "injected_by_kind",
                    "recovered_by_kind", "ladder"):
            if req not in resilience:
                problems.append(f"missing resilience key {req!r}")
    events = report.get("events")
    if isinstance(events, dict):
        for k in ("log_dropped", "sink_events", "sink_dropped"):
            if k not in events:
                problems.append(f"events summary missing {k!r}")
    return problems


class _HookAdapter(SimHooks):
    """Translates engine hook callbacks into bus events."""

    def __init__(self, cp: "ControlPlane"):
        self.cp = cp

    def on_job_start(self, sim, t, device, spec, share):
        self.cp.bus.emit(t, EventKind.JOB_START, device=device,
                         job=spec.job_id,
                         data=(("model", spec.model),
                               ("share", round(share, 4))))

    def on_job_finish(self, sim, t, device, spec, jct_s, wall_s, progress_s):
        self.cp.bus.emit(t, EventKind.JOB_FINISH, device=device,
                         job=spec.job_id,
                         data=(("jct_s", round(jct_s, 3)),
                               ("wall_s", round(wall_s, 3))))

    def on_job_evict(self, sim, t, device, spec, reason, progress_s,
                     checkpoint_s, requeued):
        self.cp.bus.emit(t, EventKind.JOB_EVICT, device=device,
                         job=spec.job_id,
                         data=(("reason", reason),
                               ("progress_s", round(progress_s, 3)),
                               ("checkpoint_s", round(checkpoint_s, 3)),
                               ("requeued", requeued)))

    def on_error(self, sim, t, device, handled):
        self.cp.bus.emit(t, EventKind.ERROR, device=device,
                         data=(("kind", handled.kind.value),
                               ("action", handled.action.value),
                               ("propagated", handled.propagated)))

    def on_device_fail(self, sim, t, device, until):
        self.cp.bus.emit(t, EventKind.DEVICE_FAIL, device=device,
                         data=(("until", round(until, 3)),))

    def on_schedule(self, sim, t, n_free, n_pending_before, n_assigned,
                    wall_s):
        # wall_s deliberately excluded: events must be bit-reproducible
        self.cp.bus.emit(t, EventKind.SCHEDULE,
                         data=(("free", n_free),
                               ("pending", n_pending_before),
                               ("assigned", n_assigned)))

    def on_tick_end(self, sim, t, telemetry):
        self.cp.last_telemetry = telemetry


class ControlPlane:
    """Discrete-event control plane over the vectorized engine."""

    def __init__(self, scenario: Scenario, predictor=None, obs=None):
        sc = scenario
        self.scenario = sc
        self.bus = EventBus(keep_log=sc.keep_event_log)
        self.fleet = FleetSpec(sc.n_devices, sc.pools) if sc.pools else None
        pol = resolve_policy(sc.policy)
        if predictor is None and pol.needs_predictor:
            # the policy owns predictor construction (SharingPolicy.
            # build_predictor): synthetic-model training by default,
            # measured-pair training for calibrated policies
            gpu_types = (self.fleet.gpu_types if self.fleet
                         else tuple(dict.fromkeys(sc.gpu_types)))
            predictor = pol.build_predictor(
                gpu_types, samples=sc.predictor_samples,
                epochs=sc.predictor_epochs, seed=0)
        cfg = SimConfig(
            policy=sc.policy, n_devices=sc.n_devices,
            horizon_s=sc.horizon_seconds(), tick_s=sc.tick_s,
            schedule_interval_s=sc.schedule_interval_s,
            checkpoint_interval_s=sc.checkpoint_interval_s,
            restart_delay_s=sc.restart_delay_s, trace=sc.trace,
            seed=sc.seed, gpu_types=tuple(sc.gpu_types),
            graceful_exit=sc.graceful_exit,
            error_rate_per_job_hour=sc.error_rate_per_job_hour,
            device_mtbf_h=sc.device_mtbf_h,
            device_repair_s=sc.device_repair_s,
            online_outage_s=sc.online_outage_s,
            memory_quota=sc.memory_quota, shard_size=sc.shard_size,
            predictor_cache_quantum=sc.predictor_cache_quantum,
            engine=sc.engine,
            incremental_matching=sc.incremental_matching)
        self.sim = ClusterSim(cfg, predictor, fleet=self.fleet,
                              hooks=_HookAdapter(self),
                              external_jobs=sc.external_jobs)
        # lifecycle tracking needs control-plane-submitted jobs (the engine's
        # internal trace mode never emits JOB_SUBMIT)
        self.job_manager = (JobManager(self.bus,
                                       restart_delay_s=cfg.restart_delay_s,
                                       strict=sc.strict_lifecycle)
                            if sc.external_jobs else None)
        # trace generated up here when jobs are control-plane-submitted;
        # same generator/seed the engine itself would use, so a scenario is
        # comparable against a plain ClusterSim run of the same config
        self.trace_jobs = (make_trace(sc.trace, sc.n_devices,
                                      cfg.horizon_s, sc.seed)
                           if sc.external_jobs else [])
        self._trace_i = 0
        # derived, decoupled seeds: campaign/agent randomness never touches
        # the engine's trace/failure RNG stream
        self.campaign = (FaultCampaign(sc.faults, self.sim,
                                       seed=sc.seed * 7919 + 1)
                         if sc.faults is not None else None)
        self.agents = (NodeAgentFleet(sc.n_devices, sc.agents,
                                      seed=sc.seed * 104729 + 2,
                                      bus=self.bus)
                       if sc.agents is not None else None)
        self.scalers: dict[str, Autoscaler] = {}
        self.autoscale_decisions: list[dict] = []
        if sc.autoscale:
            for si, svc in enumerate(SERVICES):
                n_svc = int((self.sim.service_idx == si).sum())
                if n_svc == 0:
                    continue
                self.scalers[svc] = Autoscaler(
                    AutoscalerConfig(min_replicas=max(1, n_svc // 4),
                                     max_replicas=n_svc),
                    replicas=max(1, int(n_svc * 0.6)),
                    qps_capacity_per_replica=(
                        ONLINE_SERVICE_PROFILES[svc]["qps_capacity"]))
        # request-level serving plane: lane seeds derive from the scenario
        # seed through a third decoupled stream (campaign and agents take
        # the first two) so request arrivals never perturb — and are never
        # perturbed by — the engine/campaign/agent RNG streams
        self.serving = None
        if sc.serving is not None:
            self.serving = ServingPlane.from_sim(
                self.sim, sc.serving, seed=sc.seed * 52361 + 3)
            self.sim.attach_serving(self.serving)
        # chaos plane: a fourth decoupled seed stream.  The campaign IS the
        # FaultInjector every seam consults — agents, serving lanes, the
        # scheduler round (via sim.chaos), and the durable event store
        # (wired by the durability runner).  None = every seam skips its
        # consult and the trajectory is byte-identical to pre-chaos builds.
        self.chaos = None
        if sc.chaos is not None:
            self.chaos = ChaosCampaign(sc.chaos, self.sim,
                                       seed=sc.seed * 15485863 + 4,
                                       bus=self.bus)
            self.chaos.serving = self.serving
            self.sim.chaos = self.chaos
            if self.agents is not None:
                self.agents.fault_injector = self.chaos
            if self.serving is not None:
                self.serving.fault_injector = self.chaos
        # observability plane: an ObsConfig, deliberately NOT a Scenario
        # field — output paths are machine-local and the scenario echo in
        # the report must stay byte-identical across machines.  Enabling
        # obs never changes the report outside its own "obs" section.
        self.obs = None
        if obs is not None and obs.enabled:
            self.obs = ObsPlane(obs, self.sim, bus=self.bus,
                                serving=self.serving)
        self.last_telemetry: dict = {}
        self.results = None
        self._t_end = 0.0

    # ------------------------------------------------------------------ run
    def run(self, *, start_tick: int = 0, start_t: float = 0.0,
            stop_tick: int | None = None, tick_callback=None):
        """Drive the scenario from ``start_tick`` (0 = a fresh run; the
        durability plane resumes from a snapshot's tick boundary with the
        snapshot's recorded ``start_t``); returns the engine's SimResults
        (the JSON report comes from :meth:`report`).

        ``stop_tick`` pauses the loop after that many completed ticks
        *without* finalizing (time-travel inspection peeks at the exact
        live state a running campaign had at that tick boundary); the
        return value is ``None`` for a paused run.

        ``tick_callback(ticks_done, t)`` fires after each completed tick —
        the durable runner's snapshot/WAL-flush seam.  It must not touch
        sim state (the tick trajectory has to be byte-identical with and
        without a callback attached)."""
        sc = self.scenario
        sim = self.sim
        t = start_t
        n_ticks = int(sc.horizon_seconds() / sc.tick_s)
        if stop_tick is not None:
            n_ticks = min(stop_tick, n_ticks)
        for i in range(start_tick, n_ticks):
            self._submit_due(t)
            if self.campaign is not None:
                self.campaign.inject(t, sc.tick_s)
            if self.chaos is not None:
                self.chaos.inject(t, sc.tick_s)
            if self.agents is not None:
                fresh = self.agents.observe(sim, t, self.last_telemetry)
                sim.set_schedulable_mask(fresh)
            if self.scalers:
                self._autoscale(t)
            t = sim.step(t)
            if tick_callback is not None:
                tick_callback(i + 1, t)
        self._t_end = t
        if stop_tick is not None:
            return None
        self.results = sim.finalize(t)
        if self.obs is not None:
            self.obs.finalize(t)
        return self.results

    def _submit_due(self, t: float) -> None:
        due = []
        while (self._trace_i < len(self.trace_jobs)
               and self.trace_jobs[self._trace_i].submit_s <= t):
            spec = self.trace_jobs[self._trace_i]
            self._trace_i += 1
            due.append(spec)
            self.bus.emit(t, EventKind.JOB_SUBMIT, job=spec.job_id,
                          data=(("model", spec.model),
                                ("duration_s", round(spec.duration_s, 3))))
        if due:
            self.sim.inject_jobs(due)

    def _autoscale(self, t: float) -> None:
        sim = self.sim
        qps = sim.tick_qps(t)       # memoized: the engine reads the same row
        for si, svc in enumerate(SERVICES):
            scaler = self.scalers.get(svc)
            if scaler is None:
                continue
            mask = sim.service_idx == si
            dec = scaler.observe(float(qps[mask].sum()), t)
            if dec is None:
                continue
            self.bus.emit(t, EventKind.AUTOSCALE,
                          data=(("service", svc),
                                ("replicas", dec.replicas),
                                ("delta", dec.delta),
                                ("reason", dec.reason)))
            self.autoscale_decisions.append(
                {"t": t, "service": svc, "replicas": dec.replicas,
                 "delta": dec.delta, "reason": dec.reason})
            if dec.delta > 0:
                # scale-up: online capacity wins — evict the offline
                # partners on this service's devices to free them
                busy = np.flatnonzero(mask & sim.state.has_job)
                for i in busy[:dec.delta]:
                    sim.evict_device(int(i), t, reason="autoscale")

    # --------------------------------------------------------------- report
    def report(self) -> dict:
        """Deterministic JSON-ready campaign report (no wall-clock fields)."""
        if self.results is None:
            raise RuntimeError("run() the scenario before report()")
        sc = self.scenario
        rep = {
            "schema": REPORT_SCHEMA,
            "scenario": sc.to_dict(),
            "sim": dataclasses.asdict(self.results),
            "jobs": (self.job_manager.summary()
                     if self.job_manager is not None else None),
            "faults": (self.campaign.summary()
                       if self.campaign is not None else None),
            "agents": (self.agents.summary()
                       if self.agents is not None else None),
            "autoscaler": ({"n_decisions": len(self.autoscale_decisions),
                            "decisions": self.autoscale_decisions,
                            "replicas": {svc: s.replicas for svc, s in
                                         sorted(self.scalers.items())}}
                           if self.scalers else None),
            "serving": (self.serving.summary()
                        if self.serving is not None else None),
            "pools": self.sim.pool_view(self._t_end),
            "scheduler": self._scheduler_telemetry(),
            "events": self.bus.summary(),
            "obs": (self.obs.summary()
                    if self.obs is not None else None),
            "incidents": (self.obs.incidents_summary()
                          if self.obs is not None else None),
            "resilience": (self.chaos.summary()
                           if self.chaos is not None else None),
        }
        return jsonify(rep)

    def _scheduler_telemetry(self) -> dict:
        """Deterministic scheduler-side counters: speed-predictor memo
        hit/miss/eviction stats and incremental-matcher shard reuse.  Both
        are pure functions of the (seeded) call sequence, so they are
        byte-identical across tick engines like the rest of the report."""
        sim = self.sim
        pred = sim.predictor
        return {
            "predictor_cache": (pred.stats()
                                if hasattr(pred, "stats") else None),
            "matching": (sim._matcher.stats()
                         if sim._matcher is not None else None),
        }


def jsonify(obj):
    """Recursively convert numpy scalars/arrays so json.dumps round-trips."""
    if isinstance(obj, dict):
        return {k: jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonify(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [jsonify(v) for v in obj.tolist()]
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    return obj


def run_scenario(name_or_scenario, predictor=None, obs=None,
                 **overrides) -> dict:
    """Build, run, and report a scenario in one call.

    ``name_or_scenario`` is a registry name or a :class:`Scenario`;
    ``overrides`` replace scenario fields (None values are ignored);
    ``obs`` is an optional :class:`repro.obs.ObsConfig` (metrics/trace/
    Prometheus outputs and phase profiling — never a scenario field)."""
    sc = (scenario_by_name(name_or_scenario)
          if isinstance(name_or_scenario, str) else name_or_scenario)
    sc = sc.with_overrides(**overrides)
    cp = ControlPlane(sc, predictor=predictor, obs=obs)
    cp.run()
    return cp.report()


def run_policy_scenario(policy, predictor=None, **sim_overrides):
    """Neutral passthrough for the figure benchmarks: run one policy through
    the control plane with every scenario feature off — the trajectory is
    identical to ``repro.core.simulator.run_policy`` (same engine, same RNG
    stream, trace-driven jobs, no campaign/agent/autoscale interference) but
    rides the ControlPlane entry point and yields its event stream.

    Policy resolution goes through the same ``build_sim_config`` path as
    ``run_policy`` itself, so name validation cannot drift between the two.
    One deliberate difference remains: for a ``needs_predictor`` policy with
    ``predictor=None``, ``run_policy`` raises while this entry point (like
    every scenario run) auto-builds a default predictor — pass the predictor
    explicitly when comparing trajectories against ``run_policy``."""
    cfg, pol = build_sim_config(policy, **sim_overrides)
    # every SimConfig knob maps onto a Scenario field — nothing the caller
    # passes can be silently dropped on the way into the ControlPlane
    sc = Scenario(
        name=f"policy:{pol.name}", policy=cfg.policy, n_devices=cfg.n_devices,
        hours=cfg.horizon_s / 3600.0, horizon_s=cfg.horizon_s,
        tick_s=cfg.tick_s,
        schedule_interval_s=cfg.schedule_interval_s,
        checkpoint_interval_s=cfg.checkpoint_interval_s,
        restart_delay_s=cfg.restart_delay_s, trace=cfg.trace,
        seed=cfg.seed, gpu_types=tuple(cfg.gpu_types),
        graceful_exit=cfg.graceful_exit,
        error_rate_per_job_hour=cfg.error_rate_per_job_hour,
        device_mtbf_h=cfg.device_mtbf_h,
        device_repair_s=cfg.device_repair_s,
        online_outage_s=cfg.online_outage_s, memory_quota=cfg.memory_quota,
        shard_size=cfg.shard_size,
        predictor_cache_quantum=cfg.predictor_cache_quantum,
        pools=(), faults=None, agents=None, autoscale=False,
        external_jobs=False, engine=cfg.engine,
        incremental_matching=cfg.incremental_matching)
    cp = ControlPlane(sc, predictor=predictor)
    return cp.run()
