"""Named, seeded, replayable scenario specs for the control plane.

A :class:`Scenario` composes everything a campaign needs — policy, fleet
pools, trace, fault campaign, agent behavior, autoscaling — into one
declarative record.  The same (scenario, seed) pair always produces the same
JSON report bit-for-bit; the registry holds the canonical campaigns the
benchmarks and CI run, and the CLI (``python -m repro.cluster.run``) can
override the headline knobs (devices/hours/seed/policy/graceful-exit).
"""
from __future__ import annotations

import dataclasses

from repro.chaos import ChaosConfig
from repro.cluster.agents import AgentConfig
from repro.cluster.faults import FaultCampaignConfig
from repro.cluster.fleet import GPUPool
from repro.policies import SharingPolicy, policy_name
from repro.serving_plane import ServingConfig


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str = ""
    # a repro.policies registry name or a SharingPolicy instance; reports
    # always carry the canonical name
    policy: str | SharingPolicy = "muxflow"
    n_devices: int = 200
    hours: float = 12.0
    horizon_s: float | None = None    # exact horizon; overrides hours when
                                      # hours*3600 would not round-trip
    tick_s: float = 30.0
    schedule_interval_s: float = 900.0
    trace: str = "B"
    seed: int = 0
    graceful_exit: bool = True
    error_rate_per_job_hour: float = 0.05
    device_mtbf_h: float = 4000.0
    device_repair_s: float = 1800.0
    checkpoint_interval_s: float = 300.0
    restart_delay_s: float = 90.0
    online_outage_s: float = 120.0
    memory_quota: float = 0.4
    gpu_types: tuple = ("T4", "T4", "T4", "A10")   # used when pools == ()
    shard_size: int = 256
    predictor_cache_quantum: float = 0.02
    predictor_samples: int = 300
    predictor_epochs: int = 12
    pools: tuple[GPUPool, ...] = ()         # () -> homogeneous default fleet
    faults: FaultCampaignConfig | None = None
    # chaos plane: infrastructure fault campaign (None -> the byte-identical
    # no-chaos path; GPU-side faults stay in `faults` above)
    chaos: ChaosConfig | None = None
    agents: AgentConfig | None = dataclasses.field(
        default_factory=AgentConfig)
    autoscale: bool = False
    # request-level serving plane (None -> curve-level accounting only)
    serving: ServingConfig | None = None
    external_jobs: bool = True              # submit via the control plane
    keep_event_log: bool = False
    strict_lifecycle: bool = True
    # the tick-engine backend is an execution detail — "numpy" and "xla"
    # produce byte-identical reports (CI diffs them), so it stays out of
    # to_dict().  incremental_matching is NOT neutral in that sense (the
    # warm-started matcher's shard deal differs from the cold compact
    # matcher's, so flipping it changes placements) and is therefore part
    # of the scenario echo like any other semantic knob.
    engine: str = "numpy"
    incremental_matching: bool = True

    def horizon_seconds(self) -> float:
        return (self.horizon_s if self.horizon_s is not None
                else self.hours * 3600.0)

    def with_overrides(self, **kw) -> "Scenario":
        kw = {k: v for k, v in kw.items() if v is not None}
        if "hours" in kw:
            # an hours override supersedes any exact-horizon pin
            kw["horizon_s"] = None
        return dataclasses.replace(self, **kw) if kw else self

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["policy"] = policy_name(self.policy)
        d["pools"] = [p.to_dict() for p in self.pools]
        # engine-invariant reports: the same campaign must produce the same
        # bytes whichever tick engine ran it (CI diffs the two)
        del d["engine"]
        return d


_HETERO_POOLS = (
    GPUPool("t4", "T4", weight=0.60, speed=1.0, hbm_gb=16.0),
    GPUPool("a10", "A10", weight=0.25, speed=1.35, hbm_gb=24.0),
    GPUPool("a100", "A100", weight=0.15, speed=2.60, hbm_gb=40.0),
)

_TIGHT_POOLS = (
    GPUPool("small-hbm", "T4", weight=0.5, speed=1.0, hbm_gb=12.0),
    GPUPool("t4", "T4", weight=0.3, speed=1.0, hbm_gb=16.0),
    GPUPool("a10", "A10", weight=0.2, speed=1.35, hbm_gb=24.0),
)

SCENARIOS: dict[str, Scenario] = {s.name: s for s in (
    Scenario(
        name="smoke",
        description="Tiny CI scenario: every control-plane feature on, "
                    "event log retained.",
        n_devices=64, hours=1.0, trace="C",
        pools=_HETERO_POOLS,
        faults=FaultCampaignConfig(rate_per_device_hour=0.5),
        agents=AgentConfig(drop_rate=0.05),
        autoscale=True, keep_event_log=True,
        predictor_samples=150, predictor_epochs=5),
    Scenario(
        name="diurnal-mixed",
        description="The flagship campaign: heterogeneous fleet under "
                    "diurnal online load with a moderate fault campaign, "
                    "flaky node agents, and online-pool autoscaling.",
        trace="B", pools=_HETERO_POOLS,
        faults=FaultCampaignConfig(
            rate_per_device_hour=0.02,
            pool_rates=(("a100", 0.05),)),       # new silicon fails more
        agents=AgentConfig(drop_rate=0.01),
        autoscale=True),
    Scenario(
        name="fault-storm",
        description="§4.2 propagation study: the campaign drives all "
                    "errors (engine's own error process off) at storm "
                    "rates; toggle --no-graceful-exit to reproduce the "
                    "unprotected baseline.",
        trace="B", error_rate_per_job_hour=0.0,
        faults=FaultCampaignConfig(rate_per_device_hour=1.0),
        agents=AgentConfig()),
    Scenario(
        name="hetero-fleet",
        description="Heavy trace-D load on a fleet with an HBM-starved "
                    "pool: per-pool memory feasibility shapes placement.",
        trace="D", pools=_TIGHT_POOLS,
        agents=AgentConfig()),
    Scenario(
        name="agent-churn",
        description="Flaky DeviceProbe/SysMonitor daemons: 15% heartbeat "
                    "drops shrink the schedulable set; measures lifecycle "
                    "impact of control-plane staleness.",
        trace="C",
        agents=AgentConfig(drop_rate=0.15, stale_after=2.0)),
    Scenario(
        name="tally-slice",
        description="Tally-style priority task-slicing on a heterogeneous "
                    "fleet: best-effort work rides priority-gated slack "
                    "slices — near-zero online slowdown, reduced offline "
                    "throughput.",
        policy="tally-priority", trace="B", pools=_HETERO_POOLS,
        agents=AgentConfig()),
    Scenario(
        name="calibrated",
        description="Measured-interference campaign: the muxflow-measured "
                    "policy replays the profiled speed matrix (executed "
                    "jax_pallas workload pairs) as engine ground truth and "
                    "schedules with a measured-trained predictor.",
        policy="muxflow-measured", trace="B", pools=_HETERO_POOLS,
        agents=AgentConfig()),
    Scenario(
        name="serving-slo",
        description="Request-level serving campaign: diurnal arrivals with "
                    "Philly-style skewed request sizes drive per-service "
                    "queues through continuous batching; deadline admission "
                    "sheds SLO-doomed requests; the report's 'serving' "
                    "section judges the run on p50/p99 and SLO attainment.",
        trace="B", pools=_HETERO_POOLS,
        faults=FaultCampaignConfig(rate_per_device_hour=0.02),
        agents=AgentConfig(drop_rate=0.01),
        autoscale=True,
        serving=ServingConfig(arrivals="diurnal", load=0.85,
                              request_size_sigma=0.8,
                              admission="deadline")),
    Scenario(
        name="chaos-storm",
        description="Chaos-plane verification campaign: agent crash/clock-"
                    "skew storms, transient WAL IO fault bursts, predictor "
                    "outages, matcher budget exhaustion, and serving "
                    "overload bursts — every fault answered by the "
                    "graceful-degradation ladder; the harness "
                    "(python -m repro chaos) asserts zero event loss, "
                    "byte-identical crash recovery, fault↔recovery "
                    "pairing, and the online SLO budget.",
        n_devices=48, hours=2.0, trace="C",
        pools=_HETERO_POOLS,
        faults=FaultCampaignConfig(rate_per_device_hour=0.1),
        agents=AgentConfig(drop_rate=0.02),
        serving=ServingConfig(arrivals="diurnal", load=0.8,
                              admission="deadline"),
        keep_event_log=True,
        predictor_samples=150, predictor_epochs=5,
        # every episode (max 900 s) closes well before the 7200 s horizon
        chaos=ChaosConfig(
            agent_crash_rate_per_hour=0.6, agent_restart_s=240.0,
            clock_skew_rate_per_hour=0.3, clock_skew_s=120.0,
            clock_skew_len_s=600.0,
            wal_fault_rate_per_hour=40.0, wal_fault_burst=2,
            predictor_outage_rate_per_hour=2.0, predictor_outage_s=900.0,
            matcher_budget_rate_per_hour=4.0,
            serving_burst_rate_per_hour=2.0, serving_burst_s=600.0,
            serving_burst_mult=2.5, brownout_shed_frac=0.10,
            end_s=5400.0)),
    Scenario(
        name="mig-partition",
        description="ParvaGPU-style static spatial partitioning under heavy "
                    "trace-D load: a fixed MIG-like SM split isolates every "
                    "pair; predictable offline slice, online capped at its "
                    "partition.",
        policy="static-partition", trace="D", pools=_TIGHT_POOLS,
        agents=AgentConfig()),
)}


def scenario_by_name(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(SCENARIOS)}"
        ) from None
