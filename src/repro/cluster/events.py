"""Typed control-plane events and a deterministic event bus.

Every discrete thing that happens in a scenario — a job placement, an
eviction, an injected fault, an agent going stale, an autoscaler decision —
flows through one :class:`EventBus` as an :class:`Event`.  The bus is
single-threaded and assigns a monotonically increasing sequence number at
emission, so under a fixed seed the full event stream is bit-reproducible;
``digest()`` hashes the canonical stream for replay/equality checks without
retaining every event object (at 20 000 devices a 12-hour campaign emits
hundreds of thousands of events).
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
from typing import Callable


class EventKind(str, enum.Enum):
    JOB_SUBMIT = "job_submit"
    JOB_START = "job_start"
    JOB_FINISH = "job_finish"
    JOB_EVICT = "job_evict"
    ERROR = "error"
    DEVICE_FAIL = "device_fail"
    SCHEDULE = "schedule"
    AGENT_STALE = "agent_stale"
    AGENT_FRESH = "agent_fresh"
    AUTOSCALE = "autoscale"
    # chaos plane: an injected infrastructure fault and the typed
    # degradation/recovery that answered it (data carries ("fault", kind)
    # and, for RECOVERY, ("action", ladder_rung))
    CHAOS_INJECT = "chaos_inject"
    RECOVERY = "recovery"


@dataclasses.dataclass(frozen=True)
class Event:
    seq: int
    t: float
    kind: EventKind
    device: int = -1          # -1: not device-scoped
    job: int = -1             # -1: not job-scoped
    data: tuple = ()          # small (key, value) pairs, hashable

    def key(self) -> tuple:
        """Canonical tuple — what the digest and determinism tests hash."""
        return (self.seq, round(self.t, 6), self.kind.value, self.device,
                self.job, self.data)


class EventBus:
    """Deterministic pub/sub: subscribers run synchronously in subscription
    order at ``emit`` time.  Keeps per-kind counts and a running SHA-256
    digest always; retains the raw event list only when ``keep_log`` is set
    (tests / small scenarios)."""

    def __init__(self, keep_log: bool = False, log_cap: int = 1_000_000):
        self._subs: dict[EventKind | None, list[Callable[[Event], None]]] = {}
        self.keep_log = keep_log
        self.log_cap = log_cap
        self.log: list[Event] = []
        self.dropped = 0                      # events not retained in `log`
        self.counts: dict[str, int] = {}
        self._seq = 0
        self._hash = hashlib.sha256()
        self._sinks: list[Callable[[Event], None]] = []
        self.sink_events = 0                  # events delivered to sinks
        self.sink_dropped = 0                 # contractually always 0

    def subscribe(self, fn: Callable[[Event], None],
                  kind: EventKind | None = None) -> None:
        """Subscribe to one kind, or to everything with ``kind=None``."""
        self._subs.setdefault(kind, []).append(fn)

    def attach_sink(self, fn: Callable[[Event], None]) -> None:
        """Attach a durable sink: called for EVERY event, before any
        subscriber, with no cap and no drop path (unlike ``keep_log``,
        which silently stops retaining past ``log_cap``).  A sink that
        raises aborts the emit — a write-ahead log must not fall behind
        the state it protects."""
        self._sinks.append(fn)

    def emit(self, t: float, kind: EventKind, device: int = -1,
             job: int = -1, data: tuple = ()) -> Event:
        ev = Event(self._seq, t, kind, device, job, data)
        self._seq += 1
        self.counts[kind.value] = self.counts.get(kind.value, 0) + 1
        self._hash.update(repr(ev.key()).encode())
        for fn in self._sinks:
            fn(ev)
            self.sink_events += 1
        if self.keep_log:
            if len(self.log) < self.log_cap:
                self.log.append(ev)
            else:
                self.dropped += 1
        for fn in self._subs.get(kind, ()):
            fn(ev)
        for fn in self._subs.get(None, ()):
            fn(ev)
        return ev

    @property
    def n_events(self) -> int:
        return self._seq

    def digest(self) -> str:
        """SHA-256 over the canonical event stream so far."""
        return self._hash.hexdigest()

    def summary(self) -> dict:
        """Counts + digest, plus backpressure counters: ``log_dropped`` is
        how many events the capped ``log`` silently omitted, while
        ``sink_events``/``sink_dropped`` account for the durable-sink seam
        (``sink_dropped`` is structurally zero — sinks run before any
        capping and have no drop path).  ``digest``/``counts`` always
        cover the full stream — only retention truncates."""
        return {"n_events": self._seq, "counts": dict(sorted(
            self.counts.items())), "digest": self.digest(),
            "log_dropped": self.dropped, "sink_events": self.sink_events,
            "sink_dropped": self.sink_dropped}
