"""The one CLI front door: ``python -m repro <command> ...``.

  PYTHONPATH=src python -m repro sim --scenario diurnal-mixed --seed 0
  PYTHONPATH=src python -m repro serve --scenario serving-slo --out rep.json
  PYTHONPATH=src python -m repro profile --suite smoke --out matrix.json
  PYTHONPATH=src python -m repro bench --json BENCH_sim.json --smoke

Commands share the reproducibility flags (``--seed`` / ``--engine`` /
``--out`` / ``--check-schema``) and the byte-determinism contract: the same
(command, flags, seed) always produces byte-identical artifacts, across
processes and across tick engines.  Wall-clock chatter goes to stderr only.

The historical entry points — ``python -m repro.cluster.run``,
``python -m repro.profiling.run``, ``python -m benchmarks.run`` — remain as
thin delegates (same stdout bytes, a deprecation note on stderr).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

_USAGE = """\
usage: python -m repro <command> [options]

commands:
  sim       run a cluster scenario -> deterministic JSON report
  serve     run a request-level serving scenario (serving-plane focus)
  profile   run a pair-profiling campaign -> speed-matrix artifact
  bench     run the figure/system benchmarks (CSV or JSON artifact)
  inspect   time-travel a durable run to a tick and summarize its state
  diff      pinpoint the first divergent WAL event between two runs
  chaos     run a chaos campaign and verify the survivability invariants

`python -m repro <command> --help` shows each command's flags.
"""


# --------------------------------------------------------------------- sim
def sim_main(argv=None, *, prog="python -m repro sim") -> int:
    """Scenario-runner (the historical ``repro.cluster.run`` CLI)."""
    from repro.cluster.control import check_schema, run_scenario
    from repro.cluster.scenario import SCENARIOS, scenario_by_name
    from repro.policies import available, resolve

    ap = argparse.ArgumentParser(
        prog=prog, description=sim_main.__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--scenario", default="smoke",
                    help="registry name (see --list)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--hours", type=float, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--policy", default=None,
                    help="sharing-policy override (see --list-policies)")
    ap.add_argument("--engine", default=None, choices=("numpy", "xla"),
                    help="tick-engine backend; reports are byte-identical "
                         "across engines (numpy is the faster one on CPU "
                         "today — see README 'Performance')")
    ap.add_argument("--tick", type=float, default=None)
    gx = ap.add_mutually_exclusive_group()
    gx.add_argument("--graceful-exit", dest="graceful", action="store_true",
                    default=None)
    gx.add_argument("--no-graceful-exit", dest="graceful",
                    action="store_false")
    ap.add_argument("--out", default=None, help="write report JSON here "
                    "(default: stdout)")
    _add_obs_flags(ap)
    _add_durability_flags(ap)
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--list-policies", action="store_true",
                    help="list registered sharing policies and exit")
    ap.add_argument("--check-schema", metavar="REPORT.json", default=None,
                    help="validate an existing report file and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, sc in sorted(SCENARIOS.items()):
            print(f"{name:16s} {sc.description}")
        return 0
    if args.list_alert_rules:
        return _list_alert_rules()
    if args.list_policies:
        for name in available():
            pol = resolve(name)
            tags = "".join(t for t, on in
                           (("[needs-predictor] ", pol.needs_predictor),
                            ("[no-scheduling] ", not pol.wants_scheduling))
                           if on)
            print(f"{name:18s} {tags}{pol.description}")
        return 0
    if args.check_schema:
        return _check_schema_file(args.check_schema, check_schema)
    if args.verify_manifest:
        return _verify_manifest_file(args.verify_manifest)

    t0 = time.perf_counter()
    if args.resume:
        report = _durable_resume(args.resume)
        if report is None:
            return 2
    else:
        sc = scenario_by_name(args.scenario)
        if args.durable:
            report = _durable_run(
                sc.with_overrides(
                    n_devices=args.devices, hours=args.hours,
                    seed=args.seed, policy=args.policy, tick_s=args.tick,
                    graceful_exit=args.graceful, engine=args.engine),
                args)
        else:
            report = run_scenario(
                sc, n_devices=args.devices, hours=args.hours,
                seed=args.seed, policy=args.policy, tick_s=args.tick,
                graceful_exit=args.graceful, engine=args.engine,
                obs=_obs_config(args))
            _emit_json(report, args.out)
    wall = time.perf_counter() - t0
    s = report["sim"]
    print(f"[{report['scenario']['name']}] {s['policy']} "
          f"n={report['scenario']['n_devices']} "
          f"{report['scenario']['hours']}h: finished "
          f"{s['n_finished']}/{s['n_jobs']} jobs, slowdown "
          f"{s['avg_slowdown']:.3f}x, errors {s['errors_propagated']}"
          f"/{s['errors_injected']} propagated, "
          f"{report['events']['n_events']} events "
          f"({wall:.1f}s wall)", file=sys.stderr)
    _emit_serving_note(report)
    _emit_obs_note(report)
    _emit_incidents_note(report)
    return 0


# ------------------------------------------------------------------- serve
def serve_main(argv=None) -> int:
    """Serving-plane runner: a scenario with request-level accounting.

    Same report pipeline as ``sim`` (full scenario report to stdout/--out),
    defaulting to the ``serving-slo`` scenario and exposing the serving
    knobs (arrival kind, load, admission policy, request-size skew) as
    flags.  A scenario without a serving section gets the default
    :class:`~repro.serving_plane.ServingConfig` attached.
    """
    import dataclasses

    from repro.cluster.control import check_schema, run_scenario
    from repro.cluster.scenario import scenario_by_name
    from repro.serving_plane import (ARRIVAL_KINDS, ServingConfig,
                                     admission_available)

    ap = argparse.ArgumentParser(
        prog="python -m repro serve", description=serve_main.__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--scenario", default="serving-slo")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--hours", type=float, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--engine", default=None, choices=("numpy", "xla"))
    ap.add_argument("--arrivals", default=None, choices=ARRIVAL_KINDS,
                    help="arrival-process kind override")
    ap.add_argument("--load", type=float, default=None,
                    help="target mean utilization vs nominal capacity")
    ap.add_argument("--admission", default=None,
                    help=f"admission policy ({admission_available()})")
    ap.add_argument("--request-size-sigma", type=float, default=None,
                    help="lognormal request-size skew (0 = uniform sizes)")
    ap.add_argument("--out", default=None, help="write report JSON here "
                    "(default: stdout)")
    _add_obs_flags(ap)
    _add_durability_flags(ap)
    ap.add_argument("--check-schema", metavar="REPORT.json", default=None,
                    help="validate an existing report file and exit")
    args = ap.parse_args(argv)

    if args.list_alert_rules:
        return _list_alert_rules()
    if args.check_schema:
        return _check_schema_file(args.check_schema, check_schema)
    if args.verify_manifest:
        return _verify_manifest_file(args.verify_manifest)

    t0 = time.perf_counter()
    if args.resume:
        report = _durable_resume(args.resume)
        if report is None:
            return 2
    else:
        sc = scenario_by_name(args.scenario)
        serving = sc.serving if sc.serving is not None else ServingConfig()
        overrides = {k: v for k, v in (
            ("arrivals", args.arrivals), ("load", args.load),
            ("admission", args.admission),
            ("request_size_sigma", args.request_size_sigma))
            if v is not None}
        if overrides:
            serving = dataclasses.replace(serving, **overrides)
        if args.durable:
            report = _durable_run(
                sc.with_overrides(
                    n_devices=args.devices, hours=args.hours,
                    seed=args.seed, engine=args.engine, serving=serving),
                args)
        else:
            report = run_scenario(
                sc, n_devices=args.devices, hours=args.hours,
                seed=args.seed, engine=args.engine, serving=serving,
                obs=_obs_config(args))
            _emit_json(report, args.out)
    wall = time.perf_counter() - t0
    _emit_serving_note(report)
    _emit_obs_note(report)
    _emit_incidents_note(report)
    print(f"[{report['scenario']['name']}] ({wall:.1f}s wall)",
          file=sys.stderr)
    return 0


# ----------------------------------------------------------------- profile
def profile_main(argv=None, *, prog="python -m repro profile") -> int:
    """Pair-profiling campaign (the historical ``repro.profiling.run`` CLI).

    Executes the workload catalog (Pallas kernels in interpret mode on
    CPU), profiles every online × offline pair across the suite's SM-share
    sweep, and writes the speed-matrix artifact.
    """
    from repro.profiling.harness import (SUITES, PairProfiler,
                                         build_speed_matrix)  # noqa: F401
    from repro.profiling.matrix import SpeedMatrix, check_schema
    from repro.profiling.workloads import build_catalog

    ap = argparse.ArgumentParser(
        prog=prog, description=profile_main.__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--suite", default="smoke", choices=sorted(SUITES),
                    help="profiling campaign (smoke: CI-sized; full: dense "
                         "share sweep)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the speed-matrix JSON here (default: stdout)")
    ap.add_argument("--no-interpret", dest="interpret", action="store_false",
                    default=None,
                    help="compile the kernels instead of interpret mode "
                         "(default: interpret off-TPU)")
    ap.add_argument("--list", action="store_true",
                    help="list the workload catalog and exit")
    ap.add_argument("--check-schema", metavar="MATRIX.json", default=None,
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, w in build_catalog().items():
            print(f"{name:16s} {w.role:8s} seed={w.seed:<4d} "
                  f"warmup={w.warmup} steps={w.steps} "
                  f"cost={w.cost_s() * 1e3:.4f}ms "
                  f"flops/step={w.flops_per_step:.3g}")
        return 0
    if args.check_schema:
        return _check_schema_file(args.check_schema, check_schema)

    t0 = time.perf_counter()
    sc = SUITES[args.suite]
    prof = PairProfiler(sc, seed=args.seed, interpret=args.interpret)
    records, grid = prof.run()
    matrix = SpeedMatrix.from_run(sc, args.seed, prof, records, grid)
    wall = time.perf_counter() - t0
    out = matrix.to_json()
    if args.out:
        with open(args.out, "w") as f:
            f.write(out)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(out, end="")
    for name, rec in records.items():
        print(f"[exec] {name:16s} {rec.steps_executed} steps, "
              f"{rec.wall_ms_per_step:.2f} ms/step wall, "
              f"checksum {rec.checksum}", file=sys.stderr)
    n_cells = sum(len(cells) for cells in grid.values())
    print(f"[{args.suite}] {len(records)} workloads, {len(grid)} pairs, "
          f"{n_cells} cells, quantum {prof.quantum_s() * 1e6:.2f}us "
          f"({wall:.1f}s wall)", file=sys.stderr)
    return 0


# ------------------------------------------------------------------- bench
def bench_main(argv=None, *, prog="python -m repro bench") -> int:
    """Benchmark harness (the historical ``benchmarks.run`` CLI): one
    module per paper figure/table plus the system benches.  Prints
    ``name,us_per_call,derived`` CSV rows, or with ``--json`` writes the
    schema-versioned perf-trajectory artifact CI diffs.
    """
    ap = argparse.ArgumentParser(
        prog=prog, description=bench_main.__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("suites", nargs="*", help="CSV-mode suite subset")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_sim.json perf artifact instead "
                         "of CSV rows")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI shapes for --json")
    args = ap.parse_args(argv)
    try:
        import benchmarks.run  # noqa: F401 — repo-root package, not in src/
    except ImportError:
        print("benchmarks package not importable — run from the repo root "
              "(it lives next to src/, not inside it)", file=sys.stderr)
        return 2
    if args.json:
        failures = _bench_json(args.json, smoke=args.smoke)
    else:
        failures = _bench_csv(set(args.suites))
    return 1 if failures else 0


#: (key, module) benchmark tables — the single home; benchmarks/run.py and
#: this CLI both read them
BENCH_SUITES = [
    ("fig4", "benchmarks.fig4_sharing"),
    ("fig10", "benchmarks.fig10_testbed"),
    ("fig11", "benchmarks.fig11_comparison"),
    ("fig12", "benchmarks.fig12_predictor"),
    ("fig13", "benchmarks.fig13_ablation"),
    ("fig14", "benchmarks.fig14_15_deployment"),
    ("overhead", "benchmarks.overhead_matching"),
    ("simscale", "benchmarks.bench_sim_scale"),
    ("kernels", "benchmarks.kernel_bench"),
]

# the perf-trajectory suites: every module here exposes run_json(smoke)
BENCH_JSON_SUITES = [
    ("bench_sim_scale", "benchmarks.bench_sim_scale"),
    ("overhead_matching", "benchmarks.overhead_matching"),
    ("kernel_bench", "benchmarks.kernel_bench"),
    ("obs_overhead", "benchmarks.obs_overhead"),
    ("durability_overhead", "benchmarks.durability_overhead"),
]


def _bench_csv(want: set) -> int:
    import importlib
    import traceback
    print("name,us_per_call,derived")
    t_all = time.time()
    failures = 0
    for key, mod_name in BENCH_SUITES:
        if want and key not in want:
            continue
        t0 = time.time()
        print(f"# === {mod_name} ===")
        try:
            mod = importlib.import_module(mod_name)
            mod.run()
        except Exception:  # noqa: BLE001 — report, continue
            failures += 1
            print(f"# FAILED {mod_name}")
            traceback.print_exc()
        print(f"# {mod_name} took {time.time()-t0:.1f}s")
    print(f"# total {time.time()-t_all:.1f}s, failures={failures}")
    return failures


def _bench_json(path: str, smoke: bool) -> int:
    import importlib
    import traceback

    from benchmarks.bench_schema import check_schema, make_artifact
    suites = {}
    failures = 0
    for key, mod_name in BENCH_JSON_SUITES:
        t0 = time.time()
        print(f"# === {mod_name} (json) ===", file=sys.stderr)
        try:
            suites[key] = importlib.import_module(mod_name).run_json(
                smoke=smoke)
        except Exception:  # noqa: BLE001 — report, continue
            failures += 1
            traceback.print_exc()
        print(f"# {mod_name} took {time.time()-t0:.1f}s", file=sys.stderr)
    doc = make_artifact(suites, smoke=smoke)
    problems = [] if failures else check_schema(doc)
    for p in problems:
        print(f"# SCHEMA: {p}", file=sys.stderr)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)
    return failures + len(problems)


# ----------------------------------------------------------------- inspect
def inspect_main(argv=None) -> int:
    """Time-travel inspection of a durable run: restore the newest snapshot
    at or before --tick, replay to exactly that tick, and print a
    deterministic state summary (byte-identical to a from-start replay and
    across tick engines).  ``--around-incident K`` jumps to the tick where
    incident K opened instead.
    """
    from repro.durability import dump_inspection, inspect_run
    from repro.durability.inspect import _fmt_table

    ap = argparse.ArgumentParser(
        prog="python -m repro inspect", description=inspect_main.__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("rundir", help="durable run directory (--durable output)")
    ap.add_argument("--tick", type=int, default=None,
                    help="tick to pause at (completed ticks)")
    ap.add_argument("--around-incident", type=int, default=None,
                    metavar="ID",
                    help="inspect at the tick incident ID opened")
    ap.add_argument("--from-start", action="store_true",
                    help="replay from tick 0 instead of the newest "
                         "snapshot (same bytes, slower — the CI check)")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here (default: stdout)")
    args = ap.parse_args(argv)
    if args.tick is None and args.around_incident is None:
        ap.error("need --tick or --around-incident")
    try:
        doc = inspect_run(args.rundir, args.tick,
                          around_incident=args.around_incident,
                          from_start=args.from_start)
    except (FileNotFoundError, ValueError) as exc:
        print(f"inspect: {exc}", file=sys.stderr)
        return 2
    text = dump_inspection(doc, args.out)
    if args.out:
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text, end="")
    print(_fmt_table(doc), file=sys.stderr)
    return 0


# -------------------------------------------------------------------- diff
def diff_main(argv=None) -> int:
    """WAL diff between two durable runs: bisect the per-segment sha256
    chains to the first mismatched segment, then report the exact first
    divergent event with surrounding context and each run's incident
    timeline at the divergence tick.  Exit 0 when the event streams are
    identical, 3 when they diverge.
    """
    from repro.durability import diff_runs, format_diff
    from repro.obs.export import canonical_json

    ap = argparse.ArgumentParser(
        prog="python -m repro diff", description=diff_main.__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("rundir_a", help="baseline durable run directory")
    ap.add_argument("rundir_b", help="comparison durable run directory")
    ap.add_argument("--context", type=int, default=3,
                    help="events of context around the divergence "
                         "(default: 3)")
    ap.add_argument("--out", default=None,
                    help="write the diff JSON here (default: stdout)")
    args = ap.parse_args(argv)
    try:
        doc = diff_runs(args.rundir_a, args.rundir_b, context=args.context)
    except (FileNotFoundError, ValueError) as exc:
        print(f"diff: {exc}", file=sys.stderr)
        return 2
    text = canonical_json(doc) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text, end="")
    print(format_diff(doc), file=sys.stderr)
    return 0 if doc["identical"] else 3


# ------------------------------------------------------------------- chaos
def chaos_main(argv=None) -> int:
    """Chaos verification: run a chaos-enabled scenario (baseline, durable
    chaos run, and a simulated SIGKILL + resume), then assert the
    survivability invariants — zero WAL event loss, every injected fault
    paired with a typed recovery, bounded-retry accounting, recovery
    byte-identity, snapshot skip-to-next-good, and SLO attainment within
    --slo-budget of the no-chaos baseline.  Prints the verdict JSON and
    exits nonzero when any invariant fails.
    """
    import tempfile

    from repro.chaos.harness import run_chaos_verification

    ap = argparse.ArgumentParser(
        prog="python -m repro chaos", description=chaos_main.__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--scenario", default="chaos-storm",
                    help="chaos-enabled scenario (default: chaos-storm)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--hours", type=float, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--engine", default=None, choices=("numpy", "xla"))
    ap.add_argument("--workdir", default=None,
                    help="where the durable run directories go (default: "
                         "a fresh temp directory)")
    ap.add_argument("--store", default="jsonl", choices=("jsonl", "sqlite"),
                    help="WAL backend for the durable runs")
    ap.add_argument("--slo-budget", type=float, default=0.25,
                    help="max allowed SLO-attainment drop vs the no-chaos "
                         "baseline (default: 0.25 — the storm's 2.5x "
                         "overload burst sheds by design, and shed counts "
                         "as missed)")
    ap.add_argument("--snapshot-every", type=float, default=900.0,
                    metavar="SECONDS",
                    help="snapshot cadence in sim seconds (default: 900)")
    ap.add_argument("--no-crash", dest="crash", action="store_false",
                    help="skip the SIGKILL + resume leg (faster)")
    ap.add_argument("--out", default=None,
                    help="write the verdict JSON here (default: stdout)")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    workdir = args.workdir or tempfile.mkdtemp(prefix="repro-chaos-")
    try:
        doc = run_chaos_verification(
            args.scenario, workdir=workdir, seed=args.seed,
            engine=args.engine, devices=args.devices, hours=args.hours,
            backend=args.store, slo_budget=args.slo_budget,
            crash=args.crash, snapshot_every_s=args.snapshot_every)
    except (KeyError, ValueError) as exc:
        print(f"chaos: {exc}", file=sys.stderr)
        return 2
    _emit_json(doc, args.out)
    for inv in doc["invariants"]:
        mark = "PASS" if inv["ok"] else "FAIL"
        print(f"[chaos] {mark} {inv['name']}: {inv['detail']}",
              file=sys.stderr)
    wall = time.perf_counter() - t0
    res = doc["resilience"]
    print(f"[chaos] {doc['scenario']} seed={doc['seed']} "
          f"store={doc['backend']}: {res['injected']} faults injected, "
          f"{res['recovered']} recovered — "
          + ("all invariants hold" if doc["ok"] else "INVARIANTS VIOLATED")
          + f" ({wall:.1f}s wall)", file=sys.stderr)
    return 0 if doc["ok"] else 1


# ----------------------------------------------------------------- helpers
def _add_obs_flags(ap) -> None:
    g = ap.add_argument_group(
        "observability (artifacts are byte-identical across same-seed "
        "runs and across tick engines; see README 'Observability')")
    g.add_argument("--metrics-out", default=None, metavar="METRICS.jsonl",
                   help="write windowed fleet-metrics JSONL here")
    g.add_argument("--trace-out", default=None, metavar="TRACE.jsonl",
                   help="write job/request/fault trace JSONL here")
    g.add_argument("--prom-out", default=None, metavar="METRICS.prom",
                   help="write a Prometheus text-format snapshot here")
    g.add_argument("--metrics-every", type=float, default=600.0,
                   metavar="SECONDS",
                   help="metrics rollup window in sim seconds "
                        "(default: 600)")
    g.add_argument("--profile-phases", action="store_true",
                   help="wall-clock engine phase profile to stderr "
                        "(quarantined: never enters artifacts)")
    g.add_argument("--alerts-out", default=None, metavar="INCIDENTS.jsonl",
                   help="evaluate the alert-rule catalog at every metrics "
                        "window boundary and write the alert/incident "
                        "lifecycle JSONL here")
    g.add_argument("--alert-rules", default=None, metavar="RULE[,RULE...]",
                   help="comma-separated rule subset (default: the full "
                        "catalog; see --list-alert-rules)")
    g.add_argument("--list-alert-rules", action="store_true",
                   help="list the registered alert rules and exit")


def _list_alert_rules() -> int:
    from repro.obs import default_alert_rules
    for r in default_alert_rules():
        gate = (f"> {r.threshold:g}"
                + (f" & slow{r.slow_windows}-mean > {r.slow_threshold:g}"
                   if r.kind == "burn_rate" and r.slow_threshold is not None
                   else ""))
        print(f"{r.name:22s} {r.severity:6s} {r.scope:8s} "
              f"{r.signal} {gate} for={r.for_windows} "
              f"clear={r.clear_windows}\n{'':22s} {r.description}")
    return 0


def _obs_config(args):
    if not (args.metrics_out or args.trace_out or args.prom_out
            or args.profile_phases or args.alerts_out):
        return None
    from repro.obs import ObsConfig
    rules = tuple(r for r in (args.alert_rules or "").split(",") if r)
    return ObsConfig(metrics_out=args.metrics_out,
                     trace_out=args.trace_out, prom_out=args.prom_out,
                     metrics_every_s=args.metrics_every,
                     profile_phases=args.profile_phases,
                     alerts_out=args.alerts_out, alert_rules=rules)


def _emit_obs_note(report: dict) -> None:
    obs = report.get("obs")
    if not obs:
        return
    m, tr = obs.get("metrics"), obs.get("trace")
    if m:
        print(f"[obs] metrics: {m['rows']} rows, {m['windows']} windows, "
              f"{m['series']} series, digest {m['digest'][:12]}",
              file=sys.stderr)
    if tr:
        kinds = ", ".join(f"{k}={v}" for k, v in tr["kinds"].items())
        print(f"[obs] trace: {tr['rows']} rows ({kinds}), "
              f"digest {tr['digest'][:12]}", file=sys.stderr)


def _emit_incidents_note(report: dict) -> None:
    inc = report.get("incidents")
    if not inc:
        return
    print(f"[alerts] {inc['windows']} windows evaluated, "
          f"{inc['transitions']} transitions, {inc['total']} incidents "
          f"({inc['open_end']} open at end), digest {inc['digest'][:12]}",
          file=sys.stderr)


def _emit_json(report: dict, out_path) -> None:
    out = json.dumps(report, indent=2, sort_keys=True)
    if out_path:
        with open(out_path, "w") as f:
            f.write(out + "\n")
        print(f"wrote {out_path}", file=sys.stderr)
    else:
        print(out)


def _emit_serving_note(report: dict) -> None:
    serving = report.get("serving")
    if not serving:
        return
    for svc, row in sorted(serving["services"].items()):
        print(f"[serving] {svc:10s} p50 {row['p50_ms']:.1f}ms "
              f"p99 {row['p99_ms']:.1f}ms slo {row['slo_ms']:.0f}ms "
              f"attain {row['slo_attainment']:.4f} "
              f"shed {row['shed']}/{row['arrived']}", file=sys.stderr)
    tot = serving["total"]
    print(f"[serving] total      p50 {tot['p50_ms']:.1f}ms "
          f"p99 {tot['p99_ms']:.1f}ms attain {tot['slo_attainment']:.4f} "
          f"shed {tot['shed']}/{tot['arrived']}", file=sys.stderr)


def _add_durability_flags(ap) -> None:
    g = ap.add_argument_group(
        "durability (write-ahead event log + snapshots; a resumed run's "
        "report is byte-identical to an uninterrupted one — see README "
        "'Durability & recovery')")
    g.add_argument("--durable", default=None, metavar="RUNDIR",
                   help="run with a write-ahead event log, periodic "
                        "snapshots, and a signed manifest in RUNDIR")
    g.add_argument("--resume", default=None, metavar="RUNDIR",
                   help="resume a crashed durable run from its newest "
                        "verified snapshot")
    g.add_argument("--snapshot-every", type=float, default=1800.0,
                   metavar="SECONDS",
                   help="snapshot cadence in sim seconds (default: 1800)")
    g.add_argument("--store", default="jsonl", choices=("jsonl", "sqlite"),
                   help="event-log backend (default: jsonl)")
    g.add_argument("--verify-manifest", default=None,
                   metavar="MANIFEST.json",
                   help="verify a run manifest (signature + artifact "
                        "hashes + WAL chain) and exit")


def _durable_run(sc, args) -> dict:
    from repro.durability import run_durable
    run = run_durable(sc, args.durable, obs=_obs_config(args), out=args.out,
                      snapshot_every_s=args.snapshot_every,
                      backend=args.store)
    _emit_json(run.report, run.out)
    run.finalize_manifest()
    print(f"[durable] {run.rundir}: {run.store.count()} events, "
          f"{run.snapshots_taken} snapshots, manifest signed",
          file=sys.stderr)
    return run.report


def _durable_resume(rundir: str) -> dict | None:
    """Resume a durable run; a broken run directory prints an actionable
    message (never a traceback) and returns None — callers exit 2."""
    import pickle

    from repro.durability import resume_run
    try:
        run = resume_run(rundir)
    except FileNotFoundError as exc:
        print(f"resume: {exc}\nresume: pass the directory given to "
              "--durable (it must contain run.json)", file=sys.stderr)
        return None
    except (ValueError, EOFError, pickle.UnpicklingError, OSError) as exc:
        print(f"resume: {exc}\nresume: the run directory is damaged beyond "
              "what snapshot fallback can absorb — re-run with --durable "
              "to start over, or restore the directory from backup",
              file=sys.stderr)
        return None
    for rel, reason in run.snapshot_skips:
        print(f"[durable] skipped corrupt snapshot {rel}: {reason}",
              file=sys.stderr)
    _emit_json(run.report, run.out)
    run.finalize_manifest()
    origin = ("tick 0 (no usable snapshot)"
              if run.resumed_from_tick is None
              else f"tick {run.resumed_from_tick}")
    print(f"[durable] resumed {run.rundir} from {origin}: "
          f"{run.store.count()} events, manifest signed", file=sys.stderr)
    return run.report


def _verify_manifest_file(path: str) -> int:
    import os

    from repro.durability import verify_rundir
    from repro.durability.manifest import KEY_ENV
    problems = verify_rundir(path)
    for p in problems:
        print(f"MANIFEST: {p}", file=sys.stderr)
        if "HMAC signature mismatch" in p and not os.environ.get(KEY_ENV):
            print(f"MANIFEST: note: {KEY_ENV} is not set, so the documented "
                  "development key was used — if this run was signed with a "
                  f"production key, export {KEY_ENV} and re-verify",
                  file=sys.stderr)
    print("manifest " + ("FAIL" if problems else "OK"), file=sys.stderr)
    return 1 if problems else 0


def _check_schema_file(path: str, checker) -> int:
    with open(path) as f:
        problems = checker(json.load(f))
    for p in problems:
        print(f"SCHEMA: {p}", file=sys.stderr)
    print("schema " + ("FAIL" if problems else "OK"), file=sys.stderr)
    return 1 if problems else 0


def deprecation_note(old: str, new: str) -> None:
    """The legacy entry points' stderr-only notice — stdout bytes stay
    identical to the new CLI's, so artifact pipelines are unaffected."""
    print(f"note: `{old}` is deprecated; use `{new}` "
          f"(same flags, same output bytes)", file=sys.stderr)


# ---------------------------------------------------------------- dispatch
COMMANDS = {
    "sim": sim_main,
    "serve": serve_main,
    "profile": profile_main,
    "bench": bench_main,
    "inspect": inspect_main,
    "diff": diff_main,
    "chaos": chaos_main,
}


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(_USAGE, end="")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    fn = COMMANDS.get(cmd)
    if fn is None:
        print(f"unknown command {cmd!r}; available: "
              f"{' '.join(sorted(COMMANDS))}", file=sys.stderr)
        return 2
    return int(fn(rest) or 0)
