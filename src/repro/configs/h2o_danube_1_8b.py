"""h2o-danube-1.8b [dense]: 24L d2560 32H(kv8) ff6912 v32000, llama+mistral mix,
sliding-window attention (4096).  [arXiv:2401.16818; hf]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000, pattern=(("attn", "dense"),),
    window=4096, rope_theta=10000.0, ffn_act="silu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window=16, vocab_pad_multiple=16, ssm_chunk=8,
)
