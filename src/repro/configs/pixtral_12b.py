"""pixtral-12b [vlm]: pixtral-ViT frontend (STUB: input_specs() provides
precomputed patch embeddings) + mistral-nemo-12b backbone: 40L d5120 32H(kv8)
ff14336 v131072.  [hf:mistralai/Pixtral-12B-2409; unverified]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, pattern=(("attn", "dense"),),
    frontend="patch", num_patches=1024, rope_theta=1_000_000.0, ffn_act="silu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, num_patches=4, vocab_pad_multiple=16, ssm_chunk=8,
)
