"""deepseek-v2-lite-16b [moe]: 27L d2048 16H MLA(kv_lora=512, rope_dim=64,
head_dim=128) expert_ff=1408 v102400, 64 routed experts top-6 + 2 shared.
[arXiv:2405.04434; hf]

Deviations (DESIGN.md): assignment line lists both "64e top-6" and "160
routed"; public V2-Lite is 64 routed + 2 shared, top-6 (160 belongs to full
V2) — we use 64.  Real V2-Lite uses a dense FFN on layer 0; we keep all 27
layers MoE so the layer stack scans uniformly (compile-size control)."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400, pattern=(("attn", "moe"),),
    attn_kind="mla", kv_lora_rank=512, rope_head_dim=64,
    num_experts=64, top_k=6, num_shared_experts=2, moe_d_ff=1408,
    rope_theta=10000.0, ffn_act="silu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    kv_lora_rank=32, rope_head_dim=8, d_ff=64, moe_d_ff=64, num_experts=8,
    top_k=2, num_shared_experts=1, vocab_size=256, vocab_pad_multiple=16,
    ssm_chunk=8,
)
