"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H(kv8) ff24576 v65536, MoE 16e
top-2, Mamba+attention 1:7 interleave.  [arXiv:2403.19887; hf]

Structure: 9 super-blocks of 8 layers — attention at in-block index 4, MoE on
odd in-block indices (period 2), Mamba elsewhere; d_inner=2*d_model,
d_state=16, conv=4, dt_rank=d_model/16=512."""
import dataclasses
from repro.models.model import ModelConfig

_PATTERN = (
    ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
    ("attn", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
)

FULL = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536, pattern=_PATTERN,
    num_experts=16, top_k=2, num_shared_experts=0, moe_d_ff=24576,
    ssm_d_inner=16384, ssm_state_dim=16, ssm_conv_dim=4, ssm_dt_rank=512,
    ssm_chunk=256, rope_theta=10000.0, ffn_act="silu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, num_experts=4, top_k=2, ssm_d_inner=128,
    ssm_dt_rank=8, ssm_chunk=8, vocab_size=256, vocab_pad_multiple=16,
)
