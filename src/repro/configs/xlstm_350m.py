"""xlstm-350m [ssm]: 24 mLSTM blocks, d1024 4 heads, v50304, d_ff=0 (the
block's pf=2 up-projection is the FFN).  [arXiv:2405.04517; unverified]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304, pattern=(("mlstm", "none"),),
    mlstm_proj_factor=2, ssm_conv_dim=4, ssm_chunk=256,
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, head_dim=16, vocab_size=256,
    vocab_pad_multiple=16, ssm_chunk=8,
)
