"""The assigned input shapes and per-(arch×shape) input specs.

Every shape maps to the step function it lowers:
  train_4k    -> train_step    (seq 4096,   global batch 256)
  prefill_32k -> prefill       (seq 32768,  global batch 32)
  decode_32k  -> decode_step   (1 new token, KV cache of 32768, batch 128)
  long_500k   -> decode_step   (1 new token, context 524288,    batch 1)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Applicability per the assignment: long_500k only for sub-quadratic
    context handling (SSM / hybrid / sliding-window)."""
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")) or (cfg.window is not None)
        if not sub_quadratic:
            return False, ("pure full-attention arch: 500k dense context is "
                           "quadratic; skipped per assignment (see DESIGN.md)")
    return True, ""


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the *data* inputs of train/prefill."""
    B, S = shape.global_batch, shape.seq_len
    specs: dict = {}
    if cfg.frontend == "patch":
        n_p = cfg.num_patches
        specs["patch_embeds"] = jax.ShapeDtypeStruct((B, n_p, cfg.d_model), cfg.dtype)
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - n_p), jnp.int32)
    elif cfg.frontend == "audio":
        specs["src_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for decode: cache + one token + position.

    The cache has capacity seq_len; the new token is written at pos=seq_len-1
    and attends over the full window — 'one new token with a KV cache of
    seq_len' per the assignment."""
    B, S = shape.global_batch, shape.seq_len
    src_len = S if cfg.enc_layers else 0
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S, src_len=src_len))
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": S - 1,
    }
