"""granite-moe-1b-a400m [moe]: 24L d1024 16H(kv8) expert_ff=512 v49155
(padded 49280), 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, pattern=(("attn", "moe"),),
    num_experts=32, top_k=8, num_shared_experts=0, moe_d_ff=512,
    rope_theta=10000.0, ffn_act="silu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, moe_d_ff=64, num_experts=8, top_k=2, vocab_size=250,
    vocab_pad_multiple=16, ssm_chunk=8,
)
