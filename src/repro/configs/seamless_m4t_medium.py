"""seamless-m4t-medium [audio]: enc-dec, 12L(+12L enc) d1024 16H(kv16) ff4096
v256206 (padded 256256).  Audio frontend is a STUB: input_specs() provides
precomputed frame embeddings per the assignment.  [arXiv:2308.11596; hf]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206, pattern=(("attn_cross", "dense"),),
    enc_layers=12, frontend="audio", rope_theta=10000.0, ffn_act="relu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, enc_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, vocab_pad_multiple=16, ssm_chunk=8,
)
