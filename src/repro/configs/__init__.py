from .registry import ARCH_IDS, all_configs, get_config  # noqa: F401
from .shapes import SHAPES, ShapeSpec, batch_specs, decode_specs, supports_shape  # noqa: F401
