"""h2o-danube-3-4b [dense]: 24L d3840 32H(kv8) ff10240 v32000, llama+mistral
mix, sliding-window attention.  [arXiv:2401.16818; unverified]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000, pattern=(("attn", "dense"),),
    window=4096, rope_theta=10000.0, ffn_act="silu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window=16, vocab_pad_multiple=16, ssm_chunk=8,
)
