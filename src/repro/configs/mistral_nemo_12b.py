"""mistral-nemo-12b [dense]: 40L d5120 32H(kv8) ff14336 v131072, 128k ctx,
head_dim=128.  [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, pattern=(("attn", "dense"),),
    rope_theta=1_000_000.0, ffn_act="silu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, vocab_pad_multiple=16, ssm_chunk=8,
)
