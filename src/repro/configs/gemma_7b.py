"""gemma-7b [dense]: 28L d3072 16H(kv16=MHA) ff24576 v256000, GeGLU,
head_dim=256.  [arXiv:2403.08295; hf]"""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000, pattern=(("attn", "dense"),),
    rope_theta=10000.0, ffn_act="gelu",
)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, vocab_pad_multiple=16, ssm_chunk=8,
)
