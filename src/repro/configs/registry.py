"""Architecture registry: `get_config(arch_id, smoke=False)`.

Each module in this package defines FULL (the exact assigned public config)
and SMOKE (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "h2o-danube-1.8b",
    "gemma-7b",
    "h2o-danube-3-4b",
    "mistral-nemo-12b",
    "seamless-m4t-medium",
    "deepseek-v2-lite-16b",
    "granite-moe-1b-a400m",
    "jamba-1.5-large-398b",
    "xlstm-350m",
    "pixtral-12b",
]

_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "gemma-7b": "gemma_7b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "xlstm-350m": "xlstm_350m",
    "pixtral-12b": "pixtral_12b",
}


def get_config(arch_id: str, smoke: bool = False, **overrides):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg = mod.SMOKE if smoke else mod.FULL
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}
