"""Opt-in wall-clock profiling of the engine tick's phases.

Attached to a :class:`ClusterSim` via ``attach_phases``, the profiler
accumulates wall time per phase of the tick pipeline::

    inputs      _tick_inputs (RNG draws, profile arrays, policy surfaces)
    predict     build_weight_grid_arrays (speed-predictor weight grid)
    match       solve_matching (Kuhn-Munkres / incremental shards)
    dense_core  the numpy tick core or the compiled xla kernel call
    account     the engine-agnostic epilogue (minus the serving slice)
    serving     the serving plane's lane stepping inside _account

QUARANTINED: these numbers are wall clock and therefore never enter any
deterministic artifact — they surface only in ``BENCH_sim.json`` (the
``obs_overhead`` suite) and on stderr (``--profile-phases``).  The report's
``obs`` section records *that* profiling ran, never its timings.
"""
from __future__ import annotations

import contextlib
import time

PHASES = ("inputs", "predict", "match", "dense_core", "account", "serving")


class PhaseProfiler:
    """Accumulates ``(wall_s, calls)`` per named phase."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.totals: dict[str, float] = {}
        self.calls: dict[str, int] = {}

    @contextlib.contextmanager
    def phase(self, name: str, exclude: tuple = ()):
        """Time a block under ``name``.  ``exclude`` subtracts the growth of
        other phases timed *inside* the block (e.g. ``account`` excludes the
        nested ``serving`` slice so the two don't double-count)."""
        t0 = self.clock()
        pre = [self.totals.get(x, 0.0) for x in exclude]
        try:
            yield
        finally:
            dt = self.clock() - t0
            for x, p in zip(exclude, pre):
                dt -= self.totals.get(x, 0.0) - p
            self.add(name, dt)

    def add(self, name: str, dt: float) -> None:
        self.totals[name] = self.totals.get(name, 0.0) + dt
        self.calls[name] = self.calls.get(name, 0) + 1

    def total(self, name: str) -> float:
        return self.totals.get(name, 0.0)

    def summary(self) -> dict:
        """Wall-clock phase table (for BENCH_sim.json / stderr ONLY)."""
        return {"phases": {n: {"wall_s": round(self.totals[n], 6),
                               "calls": self.calls[n]}
                           for n in sorted(self.totals)},
                "total_s": round(sum(self.totals.values()), 6)}

    def format_table(self) -> str:
        total = sum(self.totals.values()) or 1.0
        lines = [f"[phases] {'phase':12s} {'wall_s':>10s} {'share':>7s} "
                 f"{'calls':>9s}"]
        order = [p for p in PHASES if p in self.totals]
        order += [p for p in sorted(self.totals) if p not in PHASES]
        for n in order:
            w = self.totals[n]
            lines.append(f"[phases] {n:12s} {w:10.3f} {w / total:7.1%} "
                         f"{self.calls[n]:9d}")
        lines.append(f"[phases] {'total':12s} {total:10.3f}")
        return "\n".join(lines)
