"""Deterministic exporters: canonical JSONL and Prometheus text format.

Every observability artifact follows the repo's byte-identity discipline:
rows carry **sim time only** (never wall clock), floats are rounded to a
fixed precision, JSON keys are sorted, and writers keep a running SHA-256
digest of exactly the bytes they emit — so "same seed ⇒ same bytes" is
checkable without re-reading files (the report's ``obs`` section carries the
digests, CI ``cmp``s the files across processes and across tick engines).

Writers stream: a row is serialized, hashed, and written immediately, so a
20k-GPU × 12 h run never holds its timeseries in memory.
"""
from __future__ import annotations

import hashlib
import json
import math
import re

_NDIGITS = 9            # float rounding in canonical rows (< 1 ns of sim time)

METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _canon(obj):
    """Recursively round floats (rejecting non-finite values — they have no
    canonical JSON form) and normalize ``-0.0`` so equal values serialize to
    equal bytes.

    Dispatches on exact type first: rows are overwhelmingly flat dicts of
    ``str``/``int``/``float``, and this runs once per streamed row (hundreds
    of thousands of trace rows in a full campaign).  Exact-type checks also
    sidestep the bool-is-an-int subclass trap (``type(True) is bool``)."""
    t = type(obj)
    if t is str or t is int:
        return obj
    if t is float:
        if not math.isfinite(obj):
            raise ValueError(f"non-finite value in canonical row: {obj!r}")
        return round(obj, _NDIGITS) + 0.0
    if t is dict:
        return {k: _canon(v) for k, v in obj.items()}
    if t is list or t is tuple:
        return [_canon(v) for v in obj]
    if obj is None or t is bool:
        return obj
    # subclasses (e.g. numpy float64) fall through to the general path
    if isinstance(obj, bool):
        return bool(obj)
    if isinstance(obj, float):
        return _canon(float(obj))
    if isinstance(obj, int):
        return int(obj)
    if isinstance(obj, dict):
        return {k: _canon(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    return obj


def canonical_json(row) -> str:
    """One canonical line: sorted keys, compact separators, rounded floats.
    Equal rows produce equal bytes on every platform."""
    return json.dumps(_canon(row), sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def rfloat(v):
    """Pre-round one value to the canonical float precision — the producer
    half of the :meth:`JsonlWriter.write_flat` contract.  Non-floats
    (ints, strings, ``None``) pass through."""
    return round(v, _NDIGITS) + 0.0 if isinstance(v, float) else v


class JsonlWriter:
    """Streaming canonical-JSONL writer with a running stream digest.

    ``path=None`` is a digest-only sink: rows are hashed and counted but
    written nowhere (used when only the Prometheus snapshot was requested —
    the report still records what *would* have been emitted).

    Lines are buffered and hashed/written in chunks: one ``sha256.update``
    per ~512 rows instead of per row (the digest over the concatenated
    stream is identical), which matters at ~10⁵ trace rows per campaign."""

    _CHUNK = 512

    def __init__(self, path: str | None):
        self.path = path
        self._f = open(path, "w") if path else None
        self.rows = 0
        self._hash = hashlib.sha256()
        self._buf: list[str] = []

    def write(self, row: dict) -> None:
        self._buf.append(canonical_json(row) + "\n")
        self.rows += 1
        if len(self._buf) >= self._CHUNK:
            self._flush()

    def write_flat(self, row: dict) -> None:
        """Fast path for rows the producer guarantees canonical already:
        flat primitives with floats pre-rounded via :func:`rfloat`.  Skips
        the :func:`_canon` pass — this runs once per trace row, and a full
        campaign streams ~10⁵ of them (``allow_nan=False`` still rejects
        non-finite floats at serialization time)."""
        self._buf.append(json.dumps(row, sort_keys=True,
                                    separators=(",", ":"),
                                    allow_nan=False) + "\n")
        self.rows += 1
        if len(self._buf) >= self._CHUNK:
            self._flush()

    def _flush(self) -> None:
        if not self._buf:
            return
        chunk = "".join(self._buf)
        self._buf.clear()
        self._hash.update(chunk.encode())
        if self._f is not None:
            self._f.write(chunk)

    def digest(self) -> str:
        """SHA-256 over every emitted line so far."""
        self._flush()
        return self._hash.hexdigest()

    def close(self) -> None:
        self._flush()
        if self._f is not None:
            self._f.close()
            self._f = None


# ------------------------------------------------------- prometheus text
def _fmt_value(v) -> str:
    """Canonical sample-value text: fixed rounding, shortest repr."""
    v = float(v)
    if not math.isfinite(v):
        raise ValueError(f"non-finite sample value: {v!r}")
    return repr(round(v, _NDIGITS) + 0.0)


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _label_str(labels: dict, extra: tuple = ()) -> str:
    items = sorted(labels.items()) + list(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape_label(v)}"' for k, v in items)
    return "{" + body + "}"


def prometheus_text(registry) -> str:
    """Render a :class:`~repro.obs.metrics.MetricsRegistry` snapshot in the
    Prometheus text exposition format (families sorted by name, children by
    label values — deterministic byte-for-byte)."""
    out = []
    for fam in registry.collect():
        out.append(f"# HELP {fam.name} {fam.help}")
        out.append(f"# TYPE {fam.name} {fam.kind}")
        for labels, child in fam.samples():
            lab = dict(labels)
            if fam.kind == "histogram":
                acc = 0
                for ub, c in zip(fam.buckets, child.bucket_counts):
                    acc += c
                    out.append(f"{fam.name}_bucket"
                               f"{_label_str(lab, (('le', repr(float(ub))),))}"
                               f" {acc}")
                out.append(f"{fam.name}_bucket"
                           f"{_label_str(lab, (('le', '+Inf'),))}"
                           f" {child.count}")
                out.append(f"{fam.name}_sum{_label_str(lab)} "
                           f"{_fmt_value(child.sum)}")
                out.append(f"{fam.name}_count{_label_str(lab)} {child.count}")
            else:
                out.append(f"{fam.name}{_label_str(lab)} "
                           f"{_fmt_value(child.value)}")
    return "\n".join(out) + "\n" if out else ""


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^{}]*\})?"
    r" (?P<value>\S+)$")
_LABEL_PAIR_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')
_TYPES = ("counter", "gauge", "histogram", "summary", "untyped")


def lint_prometheus(text: str) -> list[str]:
    """Format lint of a Prometheus text exposition; returns problems
    (empty = OK).  Checks line grammar, label syntax, value parseability,
    TYPE declarations, and histogram invariants (``+Inf`` bucket present,
    cumulative bucket monotonicity, ``_count`` == ``+Inf``)."""
    problems: list[str] = []
    typed: dict[str, str] = {}
    hist: dict[str, dict] = {}          # base name+labels -> bucket state
    for i, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                problems.append(f"line {i}: malformed comment {line!r}")
            elif parts[1] == "TYPE":
                if len(parts) < 4 or parts[3] not in _TYPES:
                    problems.append(f"line {i}: bad TYPE {line!r}")
                else:
                    typed[parts[2]] = parts[3]
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            problems.append(f"line {i}: unparseable sample {line!r}")
            continue
        name, labels, value = m.group("name", "labels", "value")
        try:
            float(value)
        except ValueError:
            problems.append(f"line {i}: bad value {value!r}")
        lab_items: list[tuple[str, str]] = []
        if labels:
            for pair in _split_labels(labels[1:-1]):
                if not _LABEL_PAIR_RE.match(pair):
                    problems.append(f"line {i}: bad label {pair!r}")
                else:
                    k, v = pair.split("=", 1)
                    lab_items.append((k, v[1:-1]))
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in typed:
                base = name[:-len(suffix)]
                break
        if base not in typed:
            problems.append(f"line {i}: sample {name!r} has no # TYPE")
            continue
        if typed[base] == "histogram" and name == base + "_bucket":
            le = dict(lab_items).get("le")
            if le is None:
                problems.append(f"line {i}: _bucket without le label")
                continue
            key = (base, tuple(sorted(p for p in lab_items
                                      if p[0] != "le")))
            st = hist.setdefault(key, {"last": -1.0, "inf": None})
            c = float(value)
            if c < st["last"]:
                problems.append(f"line {i}: non-monotonic buckets for "
                                f"{base}")
            st["last"] = c
            if le == "+Inf":
                st["inf"] = c
        elif typed[base] == "histogram" and name == base + "_count":
            key = (base, tuple(sorted(lab_items)))
            st = hist.get(key)
            if st is None or st["inf"] is None:
                problems.append(f"line {i}: histogram {base} missing "
                                f"+Inf bucket before _count")
            elif float(value) != st["inf"]:
                problems.append(f"line {i}: {base}_count != +Inf bucket")
    return problems


def _split_labels(body: str) -> list[str]:
    """Split ``k1="v1",k2="v2"`` respecting escaped quotes."""
    parts, cur, in_str, esc = [], [], False, False
    for ch in body:
        if esc:
            cur.append(ch)
            esc = False
        elif ch == "\\":
            cur.append(ch)
            esc = True
        elif ch == '"':
            cur.append(ch)
            in_str = not in_str
        elif ch == "," and not in_str:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def main(argv=None) -> int:
    """``python -m repro.obs.export --lint FILE``: Prometheus format lint."""
    import argparse
    import sys
    ap = argparse.ArgumentParser(prog="python -m repro.obs.export",
                                 description=main.__doc__)
    ap.add_argument("--lint", metavar="METRICS.prom", required=True,
                    help="validate a Prometheus text-format file and exit")
    args = ap.parse_args(argv)
    with open(args.lint) as f:
        problems = lint_prometheus(f.read())
    for p in problems:
        print(f"PROM: {p}", file=sys.stderr)
    print("prometheus format " + ("FAIL" if problems else "OK"),
          file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":          # pragma: no cover
    raise SystemExit(main())
