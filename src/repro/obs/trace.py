"""Lifecycle traces: job spans from the EventBus, request spans from lanes.

Two tracers share one streaming :class:`TraceWriter`:

* :class:`EventBusTracer` subscribes to the control plane's bus and folds
  ``job_submit → job_start → job_finish/evict`` into one ``job_span`` row
  per placement segment (re-placements after requeue increment ``seg``),
  carrying queue-wait and completion/eviction attributes.  Every other
  event kind (errors, device failures, schedule rounds, autoscale
  decisions, agent staleness) passes through as a point ``event`` row in
  bus order.  Spans still open at ``finalize`` flush with ``end="open"``.
* :class:`RequestTracer` hangs off the serving plane's lanes and emits one
  ``request_batch`` row per continuous-batching drain (arrival→batch→
  complete with queue-age, batch-id, wait/service/latency attributes) and
  one ``request_shed`` row per admission shed.

Rows carry sim time only; ordering follows the deterministic bus/lane
sequence, so trace files are byte-identical across same-seed runs and
across tick engines.  No event objects are retained — a span's open state
is a small dict per in-flight job.

Performance contract: a flagship campaign streams ~10⁵ trace rows, so row
construction pre-rounds floats (:func:`~repro.obs.export.rfloat`) and
writes through :meth:`~repro.obs.export.JsonlWriter.write_flat`, skipping
the recursive canonicalization pass while producing identical bytes.
"""
from __future__ import annotations

from repro.obs.export import _NDIGITS, JsonlWriter, rfloat

TRACE_SCHEMA = "repro.obs.trace/v1"


class TraceWriter:
    """A kind-counting facade over :class:`JsonlWriter`.

    ``row`` takes ownership of ``fields`` (it is mutated and must be a flat
    dict of primitives with floats pre-rounded via :func:`rfloat` — the
    ``write_flat`` contract)."""

    def __init__(self, writer: JsonlWriter):
        self.writer = writer
        self.kinds: dict[str, int] = {}
        writer.write({"kind": "header", "schema": TRACE_SCHEMA})

    def row(self, kind: str, fields: dict) -> None:
        self.kinds[kind] = self.kinds.get(kind, 0) + 1
        fields["kind"] = kind
        self.writer.write_flat(fields)

    def close(self) -> None:
        self.writer.close()

    def summary(self) -> dict:
        return {"schema": TRACE_SCHEMA, "rows": self.writer.rows,
                "kinds": dict(sorted(self.kinds.items())),
                "digest": self.writer.digest()}


class EventBusTracer:
    """Folds bus events into job spans + point rows (see module doc).

    Matches on ``Event.kind.value`` strings (no import of the cluster
    package — the dependency points control-plane → obs only)."""

    def __init__(self, tw: TraceWriter):
        self.tw = tw
        self._submit: dict[int, float] = {}     # job -> queue-entry time
        self._open: dict[int, dict] = {}        # job -> open span fields
        self._segments: dict[int, int] = {}     # job -> placements so far

    def install(self, bus) -> None:
        bus.subscribe(self._on_event)

    # ------------------------------------------------------------- dispatch
    # Hot path: runs once per bus event (~2·10⁵ per flagship campaign).
    # ``ev.data`` tuples are scanned in place instead of dict()-ed, and
    # ``ev.t`` (always a plain float) is rounded inline.
    def _on_event(self, ev) -> None:
        k = ev.kind.value
        if k == "job_submit":
            self._submit[ev.job] = ev.t
            return
        if k == "job_start":
            model = share = None
            for dk, dv in ev.data:
                if dk == "model":
                    model = dv
                elif dk == "share":
                    share = dv
            seg = self._segments.get(ev.job, 0)
            self._segments[ev.job] = seg + 1
            t_sub = self._submit.pop(ev.job, None)
            t = round(ev.t, _NDIGITS) + 0.0
            self._open[ev.job] = {
                "job": ev.job, "seg": seg, "device": ev.device,
                "t_submit": None if t_sub is None
                else round(t_sub, _NDIGITS) + 0.0,
                "t_start": t,
                "queue_wait_s": (None if t_sub is None
                                 else round(ev.t - t_sub, _NDIGITS) + 0.0),
                "model": model, "share": rfloat(share)}
        elif k == "job_finish":
            span = self._open.pop(ev.job, None)
            if span is not None:
                jct = wall = None
                for dk, dv in ev.data:
                    if dk == "jct_s":
                        jct = dv
                    elif dk == "wall_s":
                        wall = dv
                span["t_end"] = round(ev.t, _NDIGITS) + 0.0
                span["end"] = "finish"
                span["jct_s"] = rfloat(jct)
                span["wall_s"] = rfloat(wall)
                self.tw.row("job_span", span)
        elif k == "job_evict":
            data = dict(ev.data)
            span = self._open.pop(ev.job, None)
            if span is not None:
                span.update(t_end=round(ev.t, _NDIGITS) + 0.0, end="evict",
                            reason=data.get("reason"),
                            requeued=data.get("requeued"),
                            progress_s=rfloat(data.get("progress_s")),
                            checkpoint_s=rfloat(data.get("checkpoint_s")))
                self.tw.row("job_span", span)
            if data.get("requeued"):
                # the requeued segment's queue wait starts at eviction
                self._submit[ev.job] = ev.t
        else:
            self.tw.row("event", {
                "event": k, "t": round(ev.t, _NDIGITS) + 0.0,
                "device": ev.device, "job": ev.job,
                "data": {dk: rfloat(dv) for dk, dv in ev.data}})

    def finalize(self, t_end: float) -> None:
        for job in sorted(self._open):
            span = self._open[job]
            span.update(t_end=None, end="open")
            self.tw.row("job_span", span)
        self._open.clear()


class RequestTracer:
    """Request-lifecycle spans from the serving lanes (see module doc).
    Attached via :meth:`ServingPlane.attach_tracer`; lanes call back per
    batch drain and per shed, in deterministic lane/tick order."""

    def __init__(self, tw: TraceWriter):
        self.tw = tw

    def batch(self, service: str, batch: int, t: float, t_enqueue: float,
              n: int, work: float, wait_ms: float, service_ms: float,
              lat_ms: float) -> None:
        self.tw.row("request_batch", {
            "service": service, "batch": batch, "t": rfloat(t),
            "t_enqueue": rfloat(t_enqueue),
            "queue_age_s": rfloat(t - t_enqueue), "n": n,
            "work": rfloat(work), "wait_ms": rfloat(wait_ms),
            "service_ms": rfloat(service_ms), "lat_ms": rfloat(lat_ms)})

    def shed(self, service: str, t: float, t_enqueue: float,
             n: int) -> None:
        self.tw.row("request_shed", {
            "service": service, "t": rfloat(t),
            "t_enqueue": rfloat(t_enqueue),
            "queue_age_s": rfloat(t - t_enqueue), "n": n})
