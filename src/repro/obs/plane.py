"""The observability plane: config + orchestrator behind one seam.

:class:`ObsConfig` is what callers (the CLI's ``--metrics-out`` /
``--trace-out`` / ``--prom-out`` / ``--metrics-every`` / ``--profile-phases``
/ ``--alerts-out`` flags, or ``run_scenario(obs=...)``) hand to the control
plane.  It is
deliberately **not** a Scenario field: output paths are machine-local and the
scenario echo in the report must stay byte-identical across machines —
enabling observability never changes the report outside its own ``obs``
section (a test pins this neutrality).

:class:`ObsPlane` wires the pieces to a built sim/bus/serving-plane:

* metrics  → :class:`FleetMetricsRecorder` on the sim's obs seam
  (``ClusterSim.attach_obs`` → called at the end of ``_account``);
* alerts   → :class:`AlertEngine` fed by the recorder at every window
  boundary (rules over the same accumulators; ``incidents.jsonl``);
* traces   → :class:`EventBusTracer` subscribed to the bus and a
  :class:`RequestTracer` attached to the serving lanes;
* phases   → :class:`PhaseProfiler` on the sim's phase seam (wall clock,
  quarantined: stderr + BENCH_sim.json only).

All seams are ``None`` checks in the engine — zero cost when disabled.
"""
from __future__ import annotations

import dataclasses
import hashlib
import sys

from repro.obs.alerts import AlertEngine, resolve_alert_rules
from repro.obs.export import JsonlWriter, prometheus_text
from repro.obs.metrics import FleetMetricsRecorder
from repro.obs.phases import PhaseProfiler
from repro.obs.trace import EventBusTracer, RequestTracer, TraceWriter

OBS_SCHEMA = "repro.obs/v1"


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """What to record and where.  All outputs default off."""
    metrics_out: str | None = None      # windowed fleet metrics JSONL
    trace_out: str | None = None        # job/request/fault trace JSONL
    prom_out: str | None = None         # Prometheus text snapshot
    metrics_every_s: float = 600.0      # rollup window (sim seconds)
    profile_phases: bool = False        # wall-clock tick-phase profile
    alerts_out: str | None = None       # alert/incident lifecycle JSONL
    alert_rules: tuple = ()             # rule-name subset ((): full catalog)

    @property
    def enabled(self) -> bool:
        return bool(self.metrics_out or self.trace_out or self.prom_out
                    or self.profile_phases or self.alerts_out)


class ObsPlane:
    """One scenario run's observability surfaces (see module doc)."""

    def __init__(self, cfg: ObsConfig, sim, *, bus=None, serving=None):
        self.cfg = cfg
        self.metrics: FleetMetricsRecorder | None = None
        self.trace: TraceWriter | None = None
        self.phases: PhaseProfiler | None = None
        self._bus_tracer: EventBusTracer | None = None
        self._prom_digest: str | None = None
        self.alerts: AlertEngine | None = None
        if cfg.metrics_out or cfg.prom_out or cfg.alerts_out:
            # prom-only / alerts-only still run the recorder (digest-only
            # JSONL sink): the snapshot needs the registry, alerting needs
            # the window accumulators, and the report records what the
            # JSONL stream would have been
            self.metrics = FleetMetricsRecorder(
                sim, JsonlWriter(cfg.metrics_out),
                every_s=cfg.metrics_every_s, serving=serving)
            sim.attach_obs(self)
            if cfg.alerts_out:
                rules = (resolve_alert_rules(cfg.alert_rules)
                         if cfg.alert_rules else None)
                self.alerts = AlertEngine(
                    JsonlWriter(cfg.alerts_out), rules,
                    window_s=self.metrics.window_s)
                self.metrics.alerts = self.alerts
        if cfg.trace_out:
            self.trace = TraceWriter(JsonlWriter(cfg.trace_out))
            self._bus_tracer = EventBusTracer(self.trace)
            if bus is not None:
                self._bus_tracer.install(bus)
            if serving is not None:
                serving.attach_tracer(RequestTracer(self.trace))
        if cfg.profile_phases:
            self.phases = PhaseProfiler()
            sim.attach_phases(self.phases)

    # ------------------------------------------------------------- per-tick
    def on_tick(self, sim, inp: dict, core: dict) -> None:
        self.metrics.on_tick(sim, inp, core)

    # ------------------------------------------------------------ lifecycle
    def finalize(self, t_end: float) -> None:
        """Flush partial windows and open spans, write the Prometheus
        snapshot, close files, print the (quarantined) phase table."""
        if self.metrics is not None:
            self.metrics.finalize(t_end)
            if self.alerts is not None:
                self.alerts.finalize(t_end)
                self.alerts.writer.close()
            if self.cfg.prom_out:
                text = prometheus_text(self.metrics.registry)
                with open(self.cfg.prom_out, "w") as f:
                    f.write(text)
                self._prom_digest = hashlib.sha256(
                    text.encode()).hexdigest()
            self.metrics.writer.close()
        if self._bus_tracer is not None:
            self._bus_tracer.finalize(t_end)
            self.trace.close()
        if self.phases is not None:
            print(self.phases.format_table(), file=sys.stderr)

    def summary(self) -> dict:
        """The report's ``obs`` section: stream digests and row counts —
        deterministic identifiers of what was emitted, never paths or
        wall-clock values."""
        metrics = None
        if self.metrics is not None:
            metrics = self.metrics.summary()
            metrics["prom_digest"] = self._prom_digest
        return {"schema": OBS_SCHEMA,
                "metrics": metrics,
                "trace": (self.trace.summary()
                          if self.trace is not None else None),
                "profile_phases": bool(self.phases is not None)}

    def incidents_summary(self) -> dict | None:
        """The report's top-level ``"incidents"`` section (``None`` when
        alerting is off — the section key is always present in report/v4)."""
        return self.alerts.summary() if self.alerts is not None else None
