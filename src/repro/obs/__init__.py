"""Deterministic observability: metrics, traces, phase profiling, exporters.

See the submodule docstrings for the contracts; the short version:

* every artifact (metrics JSONL, trace JSONL, Prometheus text) is
  byte-identical across same-seed runs, across processes, and across the
  numpy/xla tick engines — sim time only, canonical JSON, sorted keys;
* emission streams per window/row, so fleet-scale runs stay O(window) in
  memory;
* wall-clock phase profiling is quarantined to stderr + BENCH_sim.json;
* alerting (`repro.obs.alerts`) evaluates a deterministic rule catalog at
  metrics-window boundaries — incidents.jsonl inherits the byte-identity
  contract.
"""
from repro.obs.alerts import (ALERT_RULES, ALERTS_SCHEMA, Alert, AlertEngine,
                              AlertRule, Incident, alert_rules_available,
                              default_alert_rules, incidents_open_at,
                              read_incidents, register_alert_rule,
                              resolve_alert_rules)
from repro.obs.export import (JsonlWriter, canonical_json, lint_prometheus,
                              prometheus_text)
from repro.obs.metrics import (METRICS_SCHEMA, FleetMetricsRecorder,
                               MetricsRegistry)
from repro.obs.phases import PHASES, PhaseProfiler
from repro.obs.plane import OBS_SCHEMA, ObsConfig, ObsPlane
from repro.obs.trace import (TRACE_SCHEMA, EventBusTracer, RequestTracer,
                             TraceWriter)

__all__ = [
    "OBS_SCHEMA", "METRICS_SCHEMA", "TRACE_SCHEMA", "PHASES",
    "ALERTS_SCHEMA", "ALERT_RULES",
    "ObsConfig", "ObsPlane",
    "MetricsRegistry", "FleetMetricsRecorder",
    "Alert", "AlertEngine", "AlertRule", "Incident",
    "alert_rules_available", "default_alert_rules", "resolve_alert_rules",
    "register_alert_rule", "read_incidents", "incidents_open_at",
    "TraceWriter", "EventBusTracer", "RequestTracer",
    "PhaseProfiler",
    "JsonlWriter", "canonical_json", "prometheus_text", "lint_prometheus",
]
