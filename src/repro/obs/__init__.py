"""Deterministic observability: metrics, traces, phase profiling, exporters.

See the submodule docstrings for the contracts; the short version:

* every artifact (metrics JSONL, trace JSONL, Prometheus text) is
  byte-identical across same-seed runs, across processes, and across the
  numpy/xla tick engines — sim time only, canonical JSON, sorted keys;
* emission streams per window/row, so fleet-scale runs stay O(window) in
  memory;
* wall-clock phase profiling is quarantined to stderr + BENCH_sim.json.
"""
from repro.obs.export import (JsonlWriter, canonical_json, lint_prometheus,
                              prometheus_text)
from repro.obs.metrics import (METRICS_SCHEMA, FleetMetricsRecorder,
                               MetricsRegistry)
from repro.obs.phases import PHASES, PhaseProfiler
from repro.obs.plane import OBS_SCHEMA, ObsConfig, ObsPlane
from repro.obs.trace import (TRACE_SCHEMA, EventBusTracer, RequestTracer,
                             TraceWriter)

__all__ = [
    "OBS_SCHEMA", "METRICS_SCHEMA", "TRACE_SCHEMA", "PHASES",
    "ObsConfig", "ObsPlane",
    "MetricsRegistry", "FleetMetricsRecorder",
    "TraceWriter", "EventBusTracer", "RequestTracer",
    "PhaseProfiler",
    "JsonlWriter", "canonical_json", "prometheus_text", "lint_prometheus",
]
