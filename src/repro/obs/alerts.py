"""Deterministic alerting: burn-rate rules, alert state machines, incidents.

The operator loop MuxFlow's §5 deployment story implies: *watch* the
metrics stream for online harm (SLO burn, error storms, device-disable
spikes, broken slowdown guarantees) and attribute it to a pool or service.
Everything here is evaluated at metrics-window boundaries from per-window
inputs the :class:`~repro.obs.metrics.FleetMetricsRecorder` already
accumulates, so alerting inherits the plane's determinism contract: the
``incidents.jsonl`` stream is byte-identical across same-seed runs, across
processes, and across the numpy/xla tick engines.

Pieces:

* :class:`AlertRule` — one declarative rule: a window signal, a scope
  (``fleet`` / ``pool`` / ``service``), a strict ``>`` threshold, and the
  multi-window burn-rate extension (fast window catches the spike, the
  trailing ``slow_windows`` mean filters blips).  Rules live in a string
  registry (:func:`register_alert_rule` / :func:`resolve_alert_rules`) like
  policies and admission controllers.
* :class:`AlertEngine` — per (rule, target) state machines
  (``inactive → pending → firing → resolved``) producing typed
  :class:`Alert` transition rows and an :class:`Incident` lifecycle,
  streamed through the canonical JSONL exporter.
* :func:`read_incidents` — parse an ``incidents.jsonl`` back into
  :class:`Incident` timelines (what ``inspect``/``diff`` report at a tick).
"""
from __future__ import annotations

import dataclasses
import json

ALERTS_SCHEMA = "repro.obs.alerts/v1"

#: SLO error-budget objective the burn-rate signal is normalized against:
#: ``burn = (1 - window attainment) / (1 - objective)`` — burn 1.0 spends
#: the budget exactly at the sustainable rate, 14.4 exhausts a 30-day
#: budget in 2 days (the classic page threshold).
ATTAINMENT_OBJECTIVE = 0.99

SEVERITIES = ("page", "ticket")
SCOPES = ("fleet", "pool", "service")
RULE_KINDS = ("threshold", "burn_rate")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One deterministic rule evaluated at every metrics-window boundary.

    A window *breaches* when its signal value is strictly above
    ``threshold``; ``burn_rate`` rules additionally require the trailing
    ``slow_windows``-window mean to exceed ``slow_threshold``.
    ``for_windows`` consecutive breaches arm → fire (opening an
    :class:`Incident`); ``clear_windows`` consecutive clean windows
    resolve it.
    """
    name: str
    signal: str                   # window-signal key within the scope
    scope: str                    # "fleet" | "pool" | "service"
    threshold: float
    severity: str = "ticket"      # "page" | "ticket"
    kind: str = "threshold"       # "threshold" | "burn_rate"
    for_windows: int = 1
    clear_windows: int = 1
    slow_windows: int = 1
    slow_threshold: float | None = None
    description: str = ""

    def __post_init__(self):
        if self.scope not in SCOPES:
            raise ValueError(f"rule {self.name!r}: scope {self.scope!r} "
                             f"not in {SCOPES}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"rule {self.name!r}: severity "
                             f"{self.severity!r} not in {SEVERITIES}")
        if self.kind not in RULE_KINDS:
            raise ValueError(f"rule {self.name!r}: kind {self.kind!r} "
                             f"not in {RULE_KINDS}")
        if self.for_windows < 1 or self.clear_windows < 1:
            raise ValueError(f"rule {self.name!r}: for_windows and "
                             "clear_windows must be >= 1")
        if self.slow_windows < 1:
            raise ValueError(f"rule {self.name!r}: slow_windows must "
                             "be >= 1")

    def breach(self, value: float, slow_mean: float) -> bool:
        """Strict ``>`` so breach counts are monotone non-increasing in the
        threshold (a property test pins this)."""
        if self.kind == "burn_rate" and self.slow_threshold is not None:
            return value > self.threshold and slow_mean > self.slow_threshold
        return value > self.threshold


@dataclasses.dataclass(frozen=True)
class Alert:
    """A typed alert transition — one ``kind="alert"`` JSONL row."""
    t: float
    rule: str
    target: str
    state: str           # pending | firing | inactive | resolved
    value: float
    threshold: float
    severity: str

    def row(self) -> dict:
        return {"kind": "alert", "t": self.t, "rule": self.rule,
                "target": self.target, "state": self.state,
                "value": self.value, "threshold": self.threshold,
                "severity": self.severity}


@dataclasses.dataclass
class Incident:
    """One open → firing → resolved lifecycle for a (rule, target)."""
    id: int
    rule: str
    target: str
    severity: str
    opened_t: float
    resolved_t: float | None = None
    windows: int = 0              # breach windows attributed to the incident
    peak: float = 0.0             # worst signal value while open

    def open_at(self, t: float) -> bool:
        return self.opened_t <= t and (self.resolved_t is None
                                       or t < self.resolved_t)

    def row(self) -> dict:
        return {"kind": "incident", "id": self.id, "rule": self.rule,
                "target": self.target, "severity": self.severity,
                "opened_t": self.opened_t, "resolved_t": self.resolved_t,
                "windows": self.windows, "peak": self.peak}


# ------------------------------------------------------------------ registry
ALERT_RULES: dict[str, AlertRule] = {}


def register_alert_rule(rule: AlertRule) -> AlertRule:
    """Add a rule to the catalog (names are unique, like policies)."""
    if rule.name in ALERT_RULES:
        raise ValueError(f"alert rule {rule.name!r} already registered")
    ALERT_RULES[rule.name] = rule
    return rule


def alert_rules_available() -> tuple:
    return tuple(sorted(ALERT_RULES))


def default_alert_rules() -> tuple:
    """The full catalog, sorted by name (the engine's evaluation order)."""
    return tuple(ALERT_RULES[n] for n in sorted(ALERT_RULES))


def resolve_alert_rules(names) -> tuple:
    """A named subset of the catalog, sorted by name; unknown names raise
    with the available catalog in the message."""
    out = []
    for n in sorted(set(names)):
        rule = ALERT_RULES.get(n)
        if rule is None:
            raise ValueError(f"unknown alert rule {n!r}; available: "
                             f"{', '.join(alert_rules_available())}")
        out.append(rule)
    return tuple(out)


# The default catalog.  Thresholds are tuned so the quiet `smoke` scenario
# stays incident-free (a property test pins this) while `fault-storm`
# (campaign at 1.0 errors/device-hour) reliably opens error-rate incidents.
register_alert_rule(AlertRule(
    "slo-burn-fast", signal="burn_rate", scope="service", threshold=14.4,
    severity="page", kind="burn_rate", slow_windows=6, slow_threshold=6.0,
    clear_windows=2,
    description="fast SLO burn: one window burning >14.4x budget while the "
                "6-window mean burns >6x — page before the budget is gone"))
register_alert_rule(AlertRule(
    "slo-burn-slow", signal="burn_rate", scope="service", threshold=3.0,
    severity="ticket", kind="burn_rate", slow_windows=6, slow_threshold=1.0,
    for_windows=2, clear_windows=3,
    description="slow SLO burn: sustained >3x budget spend with the "
                "6-window mean above sustainable — ticket-grade erosion"))
register_alert_rule(AlertRule(
    "serving-p99", signal="p99_slo_ratio", scope="service", threshold=1.0,
    severity="ticket", for_windows=2, clear_windows=2,
    description="window p99 latency above the service SLO for two "
                "consecutive windows"))
register_alert_rule(AlertRule(
    "error-rate", signal="errors_per_device_hour", scope="fleet",
    threshold=0.25, severity="ticket", for_windows=2, clear_windows=2,
    description="offline-container error rate above 0.25/device-hour for "
                "two consecutive windows (fig7 error-mix storm)"))
register_alert_rule(AlertRule(
    "incident-spike", signal="online_incidents", scope="fleet",
    threshold=2.5, severity="page",
    description="three or more errors propagated to the online service in "
                "one window — the §4.2 guarantee is broken"))
register_alert_rule(AlertRule(
    "device-disable-spike", signal="device_disables_per_1k_hour",
    scope="pool", threshold=700.0, severity="ticket", for_windows=2,
    clear_windows=2,
    description="SysMonitor healthy->non-schedulable transitions above "
                "700 per 1k device-hours in a pool for two consecutive "
                "windows (background agent churn stays below this)"))
register_alert_rule(AlertRule(
    "online-slowdown", signal="busy_slowdown", scope="pool", threshold=1.2,
    severity="page", for_windows=4, clear_windows=2,
    description="window-mean online slowdown on shared devices above the "
                "1.2x guarantee for four consecutive windows — transient "
                "co-location spikes decay faster than this"))
# Chaos-plane rules: their signals only exist when a ChaosCampaign is wired
# in (the engine skips missing signals), so quiet runs stay incident-free.
register_alert_rule(AlertRule(
    "chaos-unrecovered", signal="chaos_open_faults", scope="fleet",
    threshold=0.5, severity="page", for_windows=3, clear_windows=1,
    description="an injected fault has stayed open (no paired recovery "
                "event) for three consecutive windows — a degradation-"
                "ladder rung failed to engage"))
register_alert_rule(AlertRule(
    "wal-retry-storm", signal="chaos_store_retries", scope="fleet",
    threshold=8.0, severity="ticket", for_windows=2, clear_windows=2,
    description="more than eight WAL IO retries per window for two "
                "consecutive windows — the bounded-retry rung is masking "
                "a persistent storage fault"))
register_alert_rule(AlertRule(
    "chaos-brownout", signal="chaos_brownout_shed", scope="fleet",
    threshold=0.5, severity="ticket", clear_windows=2,
    description="the serving brownout rung shed requests this window — "
                "overload protection engaged at the cost of SLO budget"))


# ------------------------------------------------------------------- engine
class _RuleState:
    """One (rule, target) state machine."""
    __slots__ = ("state", "breaches", "clears", "peak", "ring", "incident")

    def __init__(self):
        self.state = "inactive"
        self.breaches = 0            # consecutive breach windows
        self.clears = 0              # consecutive clean windows while firing
        self.peak = 0.0              # worst value over the current breach run
        self.ring: list[float] = []  # trailing values (slow-window mean)
        self.incident: Incident | None = None


class AlertEngine:
    """Evaluates the rule catalog at every metrics-window boundary.

    ``on_window(t, signals)`` consumes one deterministic per-window signal
    document (built by the metrics recorder from its existing accumulators)
    and advances every (rule, target) state machine; transitions and
    incident open/resolve rows stream through the canonical writer, and
    ``finalize`` appends one ``kind="incident"`` summary row per incident —
    the timeline ``inspect``/``diff`` read back.
    """

    def __init__(self, writer, rules=None, *, window_s: float):
        self.writer = writer
        rules = tuple(rules) if rules else default_alert_rules()
        self.rules = tuple(sorted(rules, key=lambda r: r.name))
        names = [r.name for r in self.rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names: {names}")
        self.window_s = float(window_s)
        self.windows = 0
        self.breach_windows = 0      # (rule, target, window) breach count
        self.transitions = 0
        self.incidents: list[Incident] = []
        self._next_id = 0
        self._states: dict[tuple, _RuleState] = {}
        writer.write({"kind": "header", "schema": ALERTS_SCHEMA,
                      "window_s": self.window_s,
                      "objective": ATTAINMENT_OBJECTIVE, "rules": names})

    # ------------------------------------------------------------ per-window
    def on_window(self, t: float, signals: dict) -> None:
        """Evaluate every rule against one window's signals.  Rules iterate
        sorted by name and targets sorted by key, so row order (and hence
        the stream digest) is deterministic."""
        for rule in self.rules:
            scope = signals.get(rule.scope)
            if scope is None:
                continue
            if rule.scope == "fleet":
                items = (("fleet", scope),)
            else:
                items = tuple((k, scope[k]) for k in sorted(scope))
            for target, vals in items:
                value = vals.get(rule.signal)
                if value is None:
                    continue
                self._eval(t, rule, target, float(value))
        self.windows += 1

    def _eval(self, t: float, rule: AlertRule, target: str,
              value: float) -> None:
        key = (rule.name, target)
        st = self._states.get(key)
        if st is None:
            st = self._states[key] = _RuleState()
        st.ring.append(value)
        if len(st.ring) > rule.slow_windows:
            del st.ring[0]
        slow_mean = sum(st.ring) / len(st.ring)
        if rule.breach(value, slow_mean):
            self.breach_windows += 1
            st.clears = 0
            st.breaches += 1
            st.peak = value if st.breaches == 1 else max(st.peak, value)
            if st.state == "firing":
                st.incident.windows += 1
                if value > st.incident.peak:
                    st.incident.peak = value
            elif st.breaches >= rule.for_windows:
                st.state = "firing"
                self._transition(t, rule, target, "firing", value)
                inc = Incident(self._next_id, rule.name, target,
                               rule.severity, t, windows=st.breaches,
                               peak=st.peak)
                self._next_id += 1
                st.incident = inc
                self.incidents.append(inc)
                self.writer.write({"kind": "incident_open", "t": t,
                                   "id": inc.id, "rule": rule.name,
                                   "target": target,
                                   "severity": rule.severity})
            elif st.state == "inactive":
                st.state = "pending"
                self._transition(t, rule, target, "pending", value)
        else:
            st.breaches = 0
            if st.state == "pending":
                st.state = "inactive"
                self._transition(t, rule, target, "inactive", value)
            elif st.state == "firing":
                st.clears += 1
                if st.clears >= rule.clear_windows:
                    st.state = "inactive"
                    st.clears = 0
                    self._transition(t, rule, target, "resolved", value)
                    inc = st.incident
                    inc.resolved_t = t
                    st.incident = None
                    self.writer.write({"kind": "incident_resolve", "t": t,
                                       "id": inc.id, "rule": rule.name,
                                       "target": target})

    def _transition(self, t: float, rule: AlertRule, target: str,
                    state: str, value: float) -> None:
        self.transitions += 1
        self.writer.write(Alert(t, rule.name, target, state, value,
                                rule.threshold, rule.severity).row())

    # ------------------------------------------------------------ lifecycle
    def finalize(self, t_end: float) -> None:
        """Append the incident timeline (one summary row per incident, id
        order — open incidents keep ``resolved_t: null``) and a footer."""
        for inc in self.incidents:
            self.writer.write(inc.row())
        self.writer.write({"kind": "footer", "t_end": t_end,
                           "windows": self.windows,
                           "breach_windows": self.breach_windows,
                           "incidents": len(self.incidents),
                           "open_end": self.open_count()})

    def open_count(self) -> int:
        return sum(1 for i in self.incidents if i.resolved_t is None)

    def summary(self) -> dict:
        """The report's ``"incidents"`` section: stream identity plus a
        compact timeline (deterministic — never paths or wall clock)."""
        by_rule: dict[str, int] = {}
        by_sev: dict[str, int] = {}
        for inc in self.incidents:
            by_rule[inc.rule] = by_rule.get(inc.rule, 0) + 1
            by_sev[inc.severity] = by_sev.get(inc.severity, 0) + 1
        return {"schema": ALERTS_SCHEMA, "rows": self.writer.rows,
                "digest": self.writer.digest(),
                "rules": [r.name for r in self.rules],
                "windows": self.windows,
                "breach_windows": self.breach_windows,
                "transitions": self.transitions,
                "total": len(self.incidents),
                "open_end": self.open_count(),
                "by_rule": dict(sorted(by_rule.items())),
                "by_severity": dict(sorted(by_sev.items())),
                "timeline": [inc.row() for inc in self.incidents[:200]]}


# ------------------------------------------------------------------ readers
def read_incidents(path: str) -> list[Incident]:
    """Parse the ``kind="incident"`` timeline rows out of an
    ``incidents.jsonl`` (written at finalize, id order)."""
    out: list[Incident] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if row.get("kind") != "incident":
                continue
            out.append(Incident(
                id=row["id"], rule=row["rule"], target=row["target"],
                severity=row["severity"], opened_t=row["opened_t"],
                resolved_t=row["resolved_t"], windows=row["windows"],
                peak=row["peak"]))
    return out


def incidents_open_at(incidents, t: float) -> list[Incident]:
    """The sub-timeline open at sim time ``t`` (id order preserved)."""
    return [inc for inc in incidents if inc.open_at(t)]
