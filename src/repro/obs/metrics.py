"""Typed metric families and the per-tick fleet rollup recorder.

:class:`MetricsRegistry` holds counter/gauge/histogram families keyed by
``name`` + label names, Prometheus-style; children are keyed by label
values.  :class:`FleetMetricsRecorder` drives it from the engine-agnostic
accounting epilogue (``ClusterSim._account`` → ``obs.on_tick``), folding the
per-tick arrays into per-pool window accumulators and emitting one JSONL
sample row per (metric, labelset) per window — the timeseries the paper's
deployment figures (fig14/15: fleet gpu_util / SM activity / memory climbing
under sharing) are drawn from, here reproduced from the sim's own telemetry.

Determinism: the recorder consumes only per-tick arrays that are
bitwise-identical across the numpy and xla tick engines — including the
post-tick ``has_job``/``mstate`` snapshots the cores export specifically for
this purpose (reading live monitor state would see block-end values in xla
block mode).  Window boundaries count ticks, not wall time.
"""
from __future__ import annotations

import numpy as np

from repro.obs.export import LABEL_NAME_RE, METRIC_NAME_RE, JsonlWriter

METRICS_SCHEMA = "repro.obs.metrics/v1"

#: default histogram buckets for slowdown-like ratios (1.0 = no slowdown)
SLOWDOWN_BUCKETS = (1.0, 1.02, 1.05, 1.1, 1.15, 1.2, 1.3, 1.5, 2.0, 3.0)
DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
                   5.0, 10.0)


class _Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


class _Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class _Histogram:
    __slots__ = ("_buckets", "bucket_counts", "sum", "count")

    def __init__(self, buckets: tuple):
        self._buckets = buckets
        self.bucket_counts = [0] * len(buckets)   # per-bucket (non-cumulative)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += float(v)
        self.count += 1
        for i, ub in enumerate(self._buckets):
            if v <= ub:
                self.bucket_counts[i] += 1
                return
        # above the last bound: counted only in the implicit +Inf bucket


_KINDS = {"counter": _Counter, "gauge": _Gauge, "histogram": _Histogram}


class _Family:
    """One metric: a kind, a help string, label names, and children keyed
    by label values."""

    def __init__(self, kind: str, name: str, help: str,
                 label_names: tuple, buckets: tuple | None = None):
        if not METRIC_NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in label_names:
            if not LABEL_NAME_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name!r}")
        self.kind = kind
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self.buckets = tuple(float(b) for b in (buckets or ())) or None
        if kind == "histogram" and self.buckets is not None:
            if list(self.buckets) != sorted(self.buckets):
                raise ValueError(f"histogram {name!r} buckets not sorted")
        self._children: dict[tuple, object] = {}

    def labels(self, **kv):
        """The child for one label-value assignment (created on demand)."""
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[ln]) for ln in self.label_names)
        child = self._children.get(key)
        if child is None:
            child = (_Histogram(self.buckets) if self.kind == "histogram"
                     else _KINDS[self.kind]())
            self._children[key] = child
        return child

    # label-less convenience: the family acts as its own single child
    def _solo(self):
        return self.labels()

    def inc(self, v: float = 1.0) -> None:
        self._solo().inc(v)

    def set(self, v: float) -> None:
        self._solo().set(v)

    def observe(self, v: float) -> None:
        self._solo().observe(v)

    def samples(self):
        """``(labels, child)`` pairs sorted by label values — the canonical
        export order."""
        for key in sorted(self._children):
            yield (tuple(zip(self.label_names, key)), self._children[key])


class MetricsRegistry:
    """A namespace of metric families.  Re-registering a name returns the
    existing family (kind and labels must match — drift is a bug)."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _register(self, kind: str, name: str, help: str, labels: tuple,
                  buckets: tuple | None = None) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind} "
                    f"with labels {fam.label_names}")
            return fam
        fam = _Family(kind, name, help, tuple(labels), buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "", labels: tuple = ()):
        return self._register("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", labels: tuple = ()):
        return self._register("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "", labels: tuple = (),
                  buckets: tuple = DEFAULT_BUCKETS):
        return self._register("histogram", name, help, labels, buckets)

    def collect(self):
        """Families sorted by name — the canonical export order."""
        for name in sorted(self._families):
            yield self._families[name]

    @property
    def n_series(self) -> int:
        return sum(len(f._children) for f in self._families.values())


class FleetMetricsRecorder:
    """Windowed per-pool fleet rollups from the tick epilogue.

    One JSONL ``sample`` row per (metric, labelset) lands at each window
    boundary (``every_s`` of sim time, counted in ticks); gauges carry
    window means, counters carry run-cumulative totals, histograms carry
    run-cumulative buckets.  A trailing partial window flushes at
    ``finalize``.
    """

    def __init__(self, sim, writer: JsonlWriter, *, every_s: float = 600.0,
                 serving=None):
        from repro.core.sysmonitor import S_HEALTHY
        self._healthy = S_HEALTHY
        self.registry = MetricsRegistry()
        self.writer = writer
        self.serving = serving
        self._sim = sim
        self.pools = list(sim.pool_names)
        self._pool_of = sim.pool_of
        P = len(self.pools)
        self._pool_n = np.bincount(sim.pool_of, minlength=P).astype(
            np.float64)
        self.every_ticks = max(1, int(round(every_s / sim.cfg.tick_s)))
        self.window_s = self.every_ticks * sim.cfg.tick_s
        self._tick_i = 0
        self._win_ticks = 0
        self.windows = 0
        # per-device window accumulators, one row per rollup key; the pool
        # reduction (bincount) runs once per *window*, not per tick — the
        # per-tick cost is a handful of in-place vector adds.  slow_busy
        # and disable ride the same array, so alerting adds no per-tick
        # allocation.
        self._keys = ("act", "busy", "sched", "util", "sm", "mem",
                      "on_sm", "off_share", "qps", "slow_busy", "disable")
        n = int(sim.cfg.n_devices)
        self._n_dev = float(n)
        self._tick_s = float(sim.cfg.tick_s)
        self._dev_acc = np.zeros((len(self._keys), n), np.float64)
        self._tmp = np.empty(n, np.float64)      # per-tick scratch buffer
        self._tmpb = np.empty(n, bool)
        self._tmpb2 = np.empty(n, bool)
        self._prev_healthy = np.zeros(n, bool)   # devices start S_INIT
        self._prev_totals: dict[str, float] = {}
        self.alerts = None                       # optional AlertEngine
        r = self.registry
        pool = ("pool",)
        self.g_devices = r.gauge(
            "fleet_devices", "devices in the pool", pool)
        self.g_active = r.gauge(
            "fleet_active_frac", "window-mean fraction of devices alive "
            "(not failed)", pool)
        self.g_busy = r.gauge(
            "fleet_busy_frac", "window-mean fraction of devices running an "
            "offline co-located job", pool)
        self.g_sched = r.gauge(
            "fleet_schedulable_frac", "window-mean fraction of devices the "
            "SysMonitor reports Healthy (schedulable)", pool)
        self.g_util = r.gauge(
            "fleet_gpu_util", "window-mean DCGM-style gpu_util over active "
            "devices (fig14)", pool)
        self.g_sm = r.gauge(
            "fleet_sm_activity", "window-mean SM activity over active "
            "devices (fig15)", pool)
        self.g_mem = r.gauge(
            "fleet_mem_used_frac", "window-mean memory-used fraction over "
            "active devices (fig15)", pool)
        self.g_on_sm = r.gauge(
            "fleet_online_sm_activity", "window-mean online-share SM "
            "activity over active devices", pool)
        self.g_off_sm = r.gauge(
            "fleet_offline_sm_share", "window-mean achieved offline SM "
            "share over active devices", pool)
        self.g_qps = r.gauge(
            "fleet_qps", "window-mean offered online QPS", pool)
        self.g_busy_slow = r.gauge(
            "fleet_busy_slowdown", "window-mean online slowdown over busy "
            "shared device-ticks (1.0 when none busy)", pool)
        self.g_disables = r.gauge(
            "fleet_device_disables_window", "SysMonitor healthy -> "
            "non-schedulable transitions this window", pool)
        self.c_started = r.counter(
            "jobs_started_total", "offline job placements")
        self.c_finished = r.counter(
            "jobs_finished_total", "offline jobs completed")
        self.c_evicted = r.counter(
            "jobs_evicted_total", "offline jobs evicted (counted evictions)")
        self.c_errors = r.counter(
            "errors_injected_total", "injected offline container errors")
        self.c_incidents = r.counter(
            "online_incidents_total", "errors that propagated to the online "
            "service")
        # per-window deltas alongside the cumulative counters, so burn-rate
        # rules (and dashboards) never difference cumulative series
        self.g_started_w = r.gauge(
            "jobs_started_window", "offline job placements this window")
        self.g_finished_w = r.gauge(
            "jobs_finished_window", "offline jobs completed this window")
        self.g_evicted_w = r.gauge(
            "jobs_evicted_window", "offline jobs evicted this window")
        self.g_errors_w = r.gauge(
            "errors_injected_window", "injected offline container errors "
            "this window")
        self.g_incidents_w = r.gauge(
            "online_incidents_window", "errors propagated to the online "
            "service this window")
        self.h_slow = r.histogram(
            "tick_online_slowdown", "per-tick busy-mean online slowdown",
            buckets=SLOWDOWN_BUCKETS)
        for p, name in enumerate(self.pools):
            self.g_devices.labels(pool=name).set(float(self._pool_n[p]))
        if serving is not None:
            svc = ("service",)
            self.c_req_arrived = r.counter(
                "serving_requests_arrived_total", "requests entering the "
                "lane queue", svc)
            self.c_req_served = r.counter(
                "serving_requests_served_total", "requests drained by "
                "continuous batching", svc)
            self.c_req_shed = r.counter(
                "serving_requests_shed_total", "requests shed by admission",
                svc)
            self.g_req_queue = r.gauge(
                "serving_queue_depth", "requests queued at the window "
                "boundary", svc)
            self.g_att_w = r.gauge(
                "serving_window_attainment", "SLO attainment over this "
                "window's served+shed requests (1.0 when idle)", svc)
            self.g_p99_w = r.gauge(
                "serving_window_p99_ms", "p99 latency over this window's "
                "served requests (4 ms quantized)", svc)
        writer.write({"kind": "header", "schema": METRICS_SCHEMA,
                      "window_s": self.window_s, "tick_s": sim.cfg.tick_s,
                      "pools": self.pools,
                      "n_devices": int(sim.cfg.n_devices)})

    # ------------------------------------------------------------- per-tick
    # Hot path: ~15 vector passes over the fleet per tick.  Masked products
    # go through one reused scratch buffer so no per-tick temporaries are
    # allocated (a flagship campaign is 1440 ticks × 20k devices).
    def on_tick(self, sim, inp: dict, core: dict) -> None:
        d = self._dev_acc
        tmp, tmpb = self._tmp, self._tmpb
        act = core["act"]
        busy = core["busy"]
        d[0] += act
        d[1] += busy
        np.equal(core["mstate"], self._healthy, out=tmpb)
        d[2] += tmpb
        # healthy -> non-schedulable transitions (device-disable spikes)
        np.greater(self._prev_healthy, tmpb, out=self._tmpb2)
        d[10] += self._tmpb2
        np.copyto(self._prev_healthy, tmpb)
        np.multiply(core["tele_util"], act, out=tmp)
        d[3] += tmp
        np.multiply(core["tele_sm"], act, out=tmp)
        d[4] += tmp
        np.multiply(core["tele_mem"], act, out=tmp)
        d[5] += tmp
        np.multiply(inp["on"]["sm_activity"], act, out=tmp)
        d[6] += tmp
        np.logical_and(core["has_job"], act, out=tmpb)
        np.multiply(inp["used_min"], tmpb, out=tmp)
        d[7] += tmp
        d[8] += inp["qps"]
        # busy-weighted slowdown through the scratch buffer (no fancy-index
        # temporary); the same row feeds the online-slowdown alert rule
        np.multiply(core["slowdown"], busy, out=tmp)
        d[9] += tmp
        n_busy = np.count_nonzero(busy)
        if n_busy:
            self.h_slow.observe(float(tmp.sum()) / n_busy)
        self._tick_i += 1
        self._win_ticks += 1
        if self._win_ticks >= self.every_ticks:
            self._emit(inp["t"])

    # --------------------------------------------------------------- window
    def _emit(self, t: float) -> None:
        po = self._pool_of
        P = len(self.pools)
        acc = {k: np.bincount(po, weights=self._dev_acc[i], minlength=P)
               for i, k in enumerate(self._keys)}
        ticks = self._win_ticks
        win_h = ticks * self._tick_s / 3600.0
        # pool/service/fleet signal docs for the alert engine, built from
        # the same accumulators (and only when alerting is on — the metric
        # bytes themselves never depend on whether alerts are enabled)
        pool_sig = {} if self.alerts is not None else None
        for p, name in enumerate(self.pools):
            dev = self._pool_n[p] * ticks
            act = acc["act"][p]
            frac = lambda x: float(x / dev) if dev else 0.0  # noqa: E731
            over_act = lambda x: float(x / act) if act else 0.0  # noqa: E731
            lab = {"pool": name}
            self.g_active.labels(**lab).set(frac(acc["act"][p]))
            self.g_busy.labels(**lab).set(frac(acc["busy"][p]))
            self.g_sched.labels(**lab).set(frac(acc["sched"][p]))
            self.g_util.labels(**lab).set(over_act(acc["util"][p]))
            self.g_sm.labels(**lab).set(over_act(acc["sm"][p]))
            self.g_mem.labels(**lab).set(over_act(acc["mem"][p]))
            self.g_on_sm.labels(**lab).set(over_act(acc["on_sm"][p]))
            self.g_off_sm.labels(**lab).set(over_act(acc["off_share"][p]))
            self.g_qps.labels(**lab).set(float(acc["qps"][p] / ticks))
            busy_t = acc["busy"][p]
            busy_slow = float(acc["slow_busy"][p] / busy_t) if busy_t else 1.0
            self.g_busy_slow.labels(**lab).set(busy_slow)
            disables = float(acc["disable"][p])
            self.g_disables.labels(**lab).set(disables)
            if pool_sig is not None:
                pool_h = self._pool_n[p] * win_h
                pool_sig[name] = {
                    "busy_slowdown": busy_slow,
                    "device_disables": disables,
                    "device_disables_per_1k_hour": (
                        disables / pool_h * 1e3 if pool_h else 0.0),
                    "unschedulable_frac": frac(
                        acc["act"][p] - acc["sched"][p]),
                }
        fleet_delta: dict[str, float] = {}
        for fam, win_gauge, total in self._sim_totals():
            prev = self._prev_totals.get(fam.name, 0.0)
            delta = total - prev
            fam.inc(delta)
            win_gauge.set(delta)
            self._prev_totals[fam.name] = total
            fleet_delta[fam.name] = delta
        svc_sig = {} if self.alerts is not None else None
        if self.serving is not None:
            from repro.obs.alerts import ATTAINMENT_OBJECTIVE
            for lane in self.serving.lanes:
                lab = {"service": lane.service}
                for fam, total in (
                        (self.c_req_arrived, float(lane.arrived)),
                        (self.c_req_served, float(lane.served)),
                        (self.c_req_shed, float(lane.shed))):
                    key = f"{fam.name}:{lane.service}"
                    prev = self._prev_totals.get(key, 0.0)
                    fam.labels(**lab).inc(total - prev)
                    self._prev_totals[key] = total
                self.g_req_queue.labels(**lab).set(
                    float(sum(c[1] for c in lane.queue)))
                win = lane.window_snapshot()
                done = win["served"] + win["shed"]
                attain = win["within_slo"] / done if done else 1.0
                self.g_att_w.labels(**lab).set(attain)
                self.g_p99_w.labels(**lab).set(win["p99_ms"])
                if svc_sig is not None:
                    svc_sig[lane.service] = {
                        "attainment": attain,
                        "burn_rate": ((1.0 - attain)
                                      / (1.0 - ATTAINMENT_OBJECTIVE)),
                        "p99_ms": win["p99_ms"],
                        "p99_slo_ratio": win["p99_ms"] / lane.slo_ms,
                        "arrived": float(win["arrived"]),
                        "shed": float(win["shed"]),
                        "shed_frac": (win["shed"] / win["arrived"]
                                      if win["arrived"] else 0.0),
                    }
        self._write_samples(t)
        if self.alerts is not None:
            fleet_sig = {
                "errors": fleet_delta["errors_injected_total"],
                "errors_per_device_hour": (
                    fleet_delta["errors_injected_total"]
                    / (self._n_dev * win_h) if win_h else 0.0),
                "online_incidents": fleet_delta[
                    "online_incidents_total"],
                "evictions": fleet_delta["jobs_evicted_total"],
            }
            chaos = getattr(self._sim, "chaos", None)
            if chaos is not None:
                fleet_sig.update(chaos.window_signals())
            self.alerts.on_window(t, {
                "t": t, "window_s": ticks * self._tick_s,
                "fleet": fleet_sig,
                "pool": pool_sig,
                "service": svc_sig,
            })
        self.windows += 1
        self._win_ticks = 0
        self._dev_acc[:] = 0.0

    def _sim_totals(self):
        sim = self._sim
        return ((self.c_started, self.g_started_w, float(sim.executions)),
                (self.c_finished, self.g_finished_w,
                 float(len(sim.finished))),
                (self.c_evicted, self.g_evicted_w, float(sim.evictions)),
                (self.c_errors, self.g_errors_w,
                 float(sim.errors_injected)),
                (self.c_incidents, self.g_incidents_w,
                 float(sim.online_incidents)))

    def _write_samples(self, t: float) -> None:
        w = self.writer
        for fam in self.registry.collect():
            for labels, child in fam.samples():
                row = {"kind": "sample", "t": t, "name": fam.name,
                       "labels": dict(labels)}
                if fam.kind == "histogram":
                    row["count"] = child.count
                    row["sum"] = child.sum
                    row["le"] = list(fam.buckets)
                    row["buckets"] = list(child.bucket_counts)
                else:
                    row["value"] = child.value
                w.write(row)

    # ------------------------------------------------------------ lifecycle
    def finalize(self, t_end: float) -> None:
        if self._win_ticks:
            self._emit(t_end)

    def summary(self) -> dict:
        return {"schema": METRICS_SCHEMA, "rows": self.writer.rows,
                "windows": self.windows, "window_s": self.window_s,
                "series": self.registry.n_series,
                "digest": self.writer.digest()}
