"""Offline-training driver: the `train_step` workload MuxFlow schedules.

Runs a real training loop on the current backend (CPU smoke configs through
full pod configs), with: sharded params/optimizer via the rules engine,
deterministic data pipeline, async atomic checkpointing, graceful-exit signal
handling (checkpoint on SIGTERM — the §4.2 mechanism), heartbeats, and
optional gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpointing import AsyncCheckpointer, latest_step, restore
from repro.configs import ARCH_IDS, get_config
from repro.core.errors import GracefulExit
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.models import init_params, make_train_step
from repro.optim.optimizer import AdamW, AdamWConfig
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.sharding.context import activation_mesh
from repro.sharding.rules import batch_sharding, opt_state_sharding, param_sharding


def run(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
        seq: int = 64, lr: float = 3e-3, ckpt_dir: str | None = None,
        ckpt_every: int = 20, microbatches: int = 1, mesh_shape=None,
        log_every: int = 10, resume: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    devs = len(jax.devices())
    if mesh_shape is None:
        mesh_shape, axes = (devs, 1), ("data", "model")
    else:
        axes = ("data", "model")
    mesh = make_mesh(mesh_shape, axes)

    key = jax.random.PRNGKey(0)
    opt = AdamW(AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                            total_steps=steps))
    with mesh, activation_mesh(mesh):
        params = init_params(key, cfg)
        p_sh = param_sharding(mesh, params, mode="train")
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = opt.init(params)
        o_sh = opt_state_sharding(mesh, p_sh, opt_state)
        opt_state = jax.tree.map(jax.device_put, opt_state, o_sh)

        pipe = TokenPipeline(DataConfig(cfg.vocab_size, seq, batch))
        step_fn = jax.jit(make_train_step(cfg, opt, microbatches=microbatches),
                          donate_argnums=(0, 1), out_shardings=(p_sh, o_sh, None))

        start = 0
        ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        if ckpt_dir and resume and latest_step(ckpt_dir) is not None:
            (params, opt_state), start = restore(
                ckpt_dir, (params, opt_state), shardings=(p_sh, o_sh))
            print(f"[train] resumed from step {start}")

        hb = HeartbeatMonitor(1)
        losses = []
        interrupted = False

        def on_checkpoint():
            nonlocal interrupted
            interrupted = True

        gex = GracefulExit(on_checkpoint=on_checkpoint)
        t0 = time.time()
        with gex:
            for step in range(start, steps):
                b_sh = batch_sharding(mesh, pipe.batch_at(step))
                data = {k: jax.device_put(v, b_sh[k])
                        for k, v in pipe.batch_at(step).items()}
                params, opt_state, metrics = step_fn(params, opt_state, data)
                loss = float(metrics["loss"])
                losses.append(loss)
                hb.heartbeat(0, step_time=time.time() - t0)
                if step % log_every == 0:
                    print(f"[train] step {step} loss {loss:.4f} "
                          f"({(time.time()-t0)/(step-start+1)*1e3:.0f} ms/step)",
                          flush=True)
                if ckpt and (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, (params, opt_state))
                if interrupted:
                    print("[train] SIGTERM/SIGINT: graceful exit, checkpointing")
                    break
        if ckpt:
            # graceful exit persists progress before releasing the device
            ckpt.wait()
            if interrupted or steps % ckpt_every:
                ckpt.save(steps if not interrupted else step + 1,
                          (params, opt_state))
                ckpt.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "steps_done": len(losses), "interrupted": interrupted}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="xlstm-350m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = run(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
              seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, microbatches=args.microbatches)
    print(f"[train] done: {out['steps_done']} steps, "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
