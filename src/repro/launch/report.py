"""Render the dry-run JSON records into the EXPERIMENTS.md §Dry-run and
§Roofline tables.

  PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def load(mesh: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, f"*__{mesh}.json"))):
        if mesh == "16x16" and "2x16x16" in os.path.basename(f):
            continue
        recs.append(json.load(open(f)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return recs


def _improvement_hint(r: dict) -> str:
    dom = r["dominant"]
    shape = r["shape"]
    if dom == "collective":
        if "moe" in r["arch"] or "deepseek" in r["arch"] or "jamba" in r["arch"]:
            return ("replace GSPMD partial-sum MoE combine with shard_map "
                    "all-to-all EP dispatch")
        return "reduce-scatter gradients / overlap FSDP gathers with compute"
    if dom == "memory":
        if shape == "train_4k":
            return ("cut fp32 score/loss traffic: chunked attention + fused "
                    "cross-entropy; tune remat policy")
        if shape in ("decode_32k", "long_500k"):
            return ("eliminate per-step cache copies and fp32 cache converts; "
                    "fuse decode attention (flash-decode kernel)")
        return "stream KV chunks (flash) to cut score materialization traffic"
    return "increase arithmetic intensity (larger per-device batch/tiles)"


def dryrun_section(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        f"### Mesh {mesh} ({'512 chips, 2 pods' if mesh == '2x16x16' else '256 chips, 1 pod'})",
        "",
        "| arch | shape | status | compile_s | peak GiB/dev | HLO GFLOPs/dev | HBM GB/dev | link GB/dev | collectives |",
        "|---|---|---|---:|---:|---:|---:|---:|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} | | | | | | "
                         f"{r.get('reason', r.get('error', ''))[:60]} |")
            continue
        h = r["hlo"]
        br = ", ".join(f"{k}:{v/1e9:.1f}GB" for k, v in
                       sorted(h["collective_breakdown"].items(),
                              key=lambda kv: -kv[1])[:3])
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} "
            f"| {r['memory']['peak_device_bytes']/2**30:.1f} "
            f"| {h['dot_flops']/1e9:.0f} | {h['bytes']/1e9:.0f} "
            f"| {h['collective_bytes']/1e9:.1f} | {br} |")
    return "\n".join(lines)


def roofline_section() -> str:
    recs = load("16x16")
    lines = [
        "Terms per device-step (TPU v5e model: 197 TFLOP/s bf16, 819 GB/s HBM, "
        "50 GB/s/link; ring-model collective factors):",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS/dev | useful (MF/HLO) | roofline frac | what would move the dominant term |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} "
            f"| {t['memory_s']:.4f} | {t['collective_s']:.4f} | {r['dominant']} "
            f"| {r['model_flops']:.3g} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} | {_improvement_hint(r)} |")
    return "\n".join(lines)


def main() -> None:
    print("## §Dry-run\n")
    print(dryrun_section("16x16"))
    print()
    print(dryrun_section("2x16x16"))
    print("\n## §Roofline\n")
    print(roofline_section())


if __name__ == "__main__":
    main()
