"""Post-SPMD HLO analysis: FLOPs, bytes, and collective traffic with correct
while-loop (lax.scan) trip-count accounting.

XLA's `compiled.cost_analysis()` visits a while body ONCE, so a model scanned
over layers under-reports by the repeat factor (verified empirically).  This
module parses `compiled.as_text()`:

  * builds the computation graph and a per-computation execution multiplier
    (entry=1; a while body/condition inherits parent multiplier × trip count,
    where the trip count is recovered from the loop-condition constant),
  * FLOPs: exact for dot/convolution (2 · prod(out) · contraction), the
    dominant terms; elementwise ops are counted at 1 flop/output element
    from fusion outputs (secondary),
  * bytes: fusion-boundary accounting (operands + outputs of top-level ops,
    skipping free ops: tuple/gte/bitcast/parameter/constant),
  * collectives: per-device link bytes with ring-model factors
      all-reduce 2(n−1)/n · B, all-gather (n−1)/n · B_result,
      reduce-scatter (n−1) · B_result, all-to-all (n−1)/n · B,
      collective-permute 1 · B,
    n = replica-group size parsed per op.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "e4m3": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")

FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "partition-id", "replica-id", "iota", "broadcast",
            "reshape", "custom-call", "while", "conditional", "call",
            "opt-barrier"}

COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start", "ragged-all-to-all"}


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str           # everything after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict        # instr name -> type_str


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, op, rest = m.groups()
            cur.instrs.append(Instr(name, type_str, op, rest))
            cur.shapes[name] = type_str
    return comps


def _entry_name(comps, text):
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    # fallback: computation that is not referenced by any other
    referenced = set()
    for c in comps.values():
        for ins in c.instrs:
            for ref in re.findall(r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)", ins.rest):
                referenced.add(ref)
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def _trip_count(cond: Computation) -> int:
    """Recover a scan trip count from the loop condition's compare constant."""
    consts = []
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def computation_multipliers(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    """Execution count per computation (entry=1; while bodies × trip count)."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS through call edges
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            if ins.op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if body and cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                    for target, k in ((body.group(1), trips), (cond.group(1), trips + 1)):
                        mult[target] += m * k
                        if target not in seen:
                            seen.add(target)
                            order.append(target)
            elif ins.op == "conditional":
                for target in re.findall(r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w.\-,% ]+)", ins.rest):
                    for t in re.split(r"[,\s%]+", target):
                        if t in comps:
                            mult[t] += m
                            if t not in seen:
                                seen.add(t)
                                order.append(t)
            else:
                for attr in ("calls", "to_apply"):
                    mm = re.search(rf"{attr}=%?([\w.\-]+)", ins.rest)
                    if mm and mm.group(1) in comps:
                        mult[mm.group(1)] += m
                        if mm.group(1) not in seen:
                            seen.add(mm.group(1))
                            order.append(mm.group(1))
    return dict(mult)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 · prod(output dims) · prod(contracting dims of lhs)."""
    out_elems = shape_elems(ins.type_str)
    # some XLA versions print operand types inline: dot(f32[16,32] %lhs, ...)
    m_inline = re.match(r"\s*(\w+\[[\d,]*\])", ins.rest)
    if m_inline:
        lhs_type = m_inline.group(1)
    else:
        m = re.match(r"\s*%?([\w.\-]+)", ins.rest)
        if not m:
            return 0.0
        lhs_type = comp.shapes.get(m.group(1))
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if lhs_type is None or cd is None:
        return 2.0 * out_elems  # conservative
    dims_m = _SHAPE_RE.search(lhs_type)
    if not dims_m:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in dims_m.group(2).split(",")] if dims_m.group(2) else []
    k = 1
    for idx in cd.group(1).split(","):
        if idx != "" and int(idx) < len(lhs_dims):
            k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _group_size(rest: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return total_devices


_RING = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-reduce-start": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "all-gather-start": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "ragged-all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
    "collective-permute-start": lambda n: 1.0,
}


def _dus_fusion_slice_bytes(ins: Instr, comps: dict) -> float | None:
    """If `ins` is a fusion performing an in-place dynamic-update-slice of a
    same-shaped accumulator (the scan-carried stack pattern), return the
    updated-slice bytes; else None.  Matches any DUS inside the fusion whose
    result extents equal the fusion output's extents (dtype ignored: XLA
    sometimes interleaves converts)."""
    m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
    if not m or m.group(1) not in comps:
        return None
    comp = comps[m.group(1)]
    out_dims = _SHAPE_RE.search(ins.type_str)
    out_sig = out_dims.group(2) if out_dims else None
    if out_sig is None:
        return None
    for inner in comp.instrs:
        if inner.op != "dynamic-update-slice":
            continue
        dims = _SHAPE_RE.search(inner.type_str)
        if dims and dims.group(2) == out_sig:
            mm = re.match(r"\s*%?([\w.\-]+),\s*%?([\w.\-]+)", inner.rest)
            if mm and mm.group(2) in comp.shapes:
                return 2.0 * shape_bytes(comp.shapes[mm.group(2)])
    return None


def _fusion_operand_bytes(ins: Instr, comp: Computation, comps: dict) -> float:
    """Operand traffic of a fusion, charging dynamic-slice-only params at the
    slice size (in-place stack reads)."""
    m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
    refs = [r for r in re.findall(r"%?([\w.\-]+)", ins.rest.split(")")[0])
            if r in comp.shapes]
    if not m or m.group(1) not in comps:
        return float(sum(shape_bytes(comp.shapes[r]) for r in refs))
    called = comps[m.group(1)]
    # map parameter index -> (uses_total, dynamic-slice output bytes)
    param_names = {}
    for inner in called.instrs:
        if inner.op == "parameter":
            pm = re.match(r"(\d+)", inner.rest)
            if pm:
                param_names[int(pm.group(1))] = inner.name
    total = 0.0
    for i, ref in enumerate(refs):
        full = shape_bytes(comp.shapes[ref])
        pname = param_names.get(i)
        if pname is None:
            total += full
            continue
        ds_bytes, other_uses = 0, 0
        pat = re.compile(rf"%{re.escape(pname)}\b")
        for inner in called.instrs:
            if inner.name == pname:
                continue
            if pat.search(inner.rest):
                if inner.op == "dynamic-slice":
                    ds_bytes += shape_bytes(inner.type_str)
                else:
                    other_uses += 1
        total += full if (other_uses or not ds_bytes) else min(ds_bytes, full)
    return total


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0              # per-device matmul(+conv) flops
    elementwise_flops: float = 0.0
    bytes_accessed: float = 0.0     # per-device HBM traffic (fusion boundary)
    collective_bytes: float = 0.0   # per-device link bytes (ring model)
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    loop_multipliers: dict = dataclasses.field(default_factory=dict)


def analyze(text: str, total_devices: int = 1) -> HloStats:
    comps = parse_hlo(text)
    entry = _entry_name(comps, text)
    mult = computation_multipliers(comps, entry)
    stats = HloStats(loop_multipliers={k: v for k, v in mult.items() if v > 1})
    # computations reachable only via fusion `calls` should not double-count
    fused = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if m:
                    fused.add(m.group(1))
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        in_fusion = cname in fused
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                stats.flops += m * _dot_flops(ins, comp)
            elif op == "convolution":
                # rare here; approximate via output*2*prod(kernel spatial*Cin)
                stats.flops += m * 2.0 * shape_elems(ins.type_str)
            if in_fusion:
                continue  # bytes counted at the fusion boundary
            if op in COLLECTIVES:
                n = _group_size(ins.rest, total_devices)
                b = shape_bytes(ins.type_str)
                link = m * _RING.get(op, lambda n: 1.0)(n) * b
                stats.collective_bytes += link
                stats.collective_breakdown[op.replace("-start", "")] = \
                    stats.collective_breakdown.get(op.replace("-start", ""), 0.0) + link
                stats.collective_count += int(m)
                stats.bytes_accessed += m * b
                continue
            if op in FREE_OPS or op.endswith("-done"):
                continue
            out_b = shape_bytes(ins.type_str)
            opnd_b = 0
            for ref in re.findall(r"%?([\w.\-]+)", ins.rest.split(")")[0]):
                if ref in comp.shapes:
                    opnd_b += shape_bytes(comp.shapes[ref])
            if op == "fusion":
                # in-place dynamic-update-slice fusions touch only the updated
                # slice, not the whole accumulator (XLA updates in place);
                # charge slice read+write + the non-accumulator operands.
                slice_b = _dus_fusion_slice_bytes(ins, comps)
                if slice_b is not None:
                    opnd_b = sum(
                        shape_bytes(comp.shapes[ref])
                        for ref in re.findall(r"%?([\w.\-]+)", ins.rest.split(")")[0])
                        if ref in comp.shapes
                        and comp.shapes[ref] != ins.type_str)
                    out_b = slice_b
                else:
                    # operands consumed ONLY via dynamic-slice inside the
                    # fusion (reading one layer's slice from a scan-carried
                    # stack) are charged at the slice size, not the stack.
                    opnd_b = _fusion_operand_bytes(ins, comp, comps)
            stats.bytes_accessed += m * (out_b + opnd_b)
            if op == "fusion":
                stats.elementwise_flops += m * shape_elems(ins.type_str)
    return stats
