"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, record memory / cost / roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod, all cells
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
Results are cached per-cell in experiments/dryrun/*.json (--force to redo).
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices.  These two
# lines MUST precede every other import — jax locks the device count on init.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, batch_specs, decode_specs, supports_shape
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import init_cache, init_params, make_decode_step, make_prefill, make_train_step
from repro.optim.optimizer import AdamW, AdamWConfig
from repro.sharding.context import activation_mesh
from repro.sharding.rules import (batch_sharding, cache_sharding,
                                  opt_state_sharding, param_sharding)

# TPU v5e-like hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Gradient-accumulation factors for cells whose activations exceed HBM at
# full global batch (production practice for very large models).
TRAIN_MICROBATCHES = {
    "jamba-1.5-large-398b": 16,
}

# Beyond-paper optimized variant (§Perf): per-arch config overrides applied
# with --variant opt.  The baseline records stay untouched.
OPT_OVERRIDES = {
    "deepseek-v2-lite-16b": {"moe_impl": "a2a"},
    "granite-moe-1b-a400m": {"moe_impl": "a2a"},
    "jamba-1.5-large-398b": {"moe_impl": "a2a"},
}

# §Perf: the opt variant amortizes FSDP gathers / grad reduce-scatters over
# fewer, larger microbatches (jamba iteration 3: 16 -> 8).
OPT_MICROBATCHES = {
    "jamba-1.5-large-398b": 8,
}


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / n_devices


def build_cell(cfg, shape, mesh, *, serve_mode: str | None = None,
               microbatches: dict | None = None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if serve_mode is None:
        # big models cannot replicate across the data axis in serving:
        # TP-only leaves param_bytes/TP per device; above ~6 GiB switch to
        # 2D (FSDP x TP) weight sharding (weight-gathered serving).
        pbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
        serve_mode = "serve_big" if pbytes / mesh.shape["model"] > 6 * 2**30 else "serve"
    p_mode = "train" if shape.kind == "train" else serve_mode
    p_sh = param_sharding(mesh, params, mode=p_mode)
    params = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                          params, p_sh)

    if shape.kind == "train":
        opt = AdamW(AdamWConfig(master_weights=False))
        mb = (microbatches or TRAIN_MICROBATCHES).get(cfg.name, 1)
        step_fn = make_train_step(cfg, opt, microbatches=mb)
        opt_state = jax.eval_shape(opt.init, params)
        o_sh = opt_state_sharding(mesh, p_sh, opt_state)
        opt_state = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                                 opt_state, o_sh)
        batch = batch_specs(cfg, shape)
        b_sh = batch_sharding(mesh, batch)
        batch = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                             batch, b_sh)
        fn = jax.jit(step_fn, donate_argnums=(0, 1),
                     out_shardings=(p_sh, o_sh, None))
        return fn, (params, opt_state, batch)

    if shape.kind == "prefill":
        prefill = make_prefill(cfg)
        batch = batch_specs(cfg, shape)
        b_sh = batch_sharding(mesh, batch)
        batch = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                             batch, b_sh)
        fn = jax.jit(prefill)
        return fn, (params, batch)

    # decode
    decode = make_decode_step(cfg)
    specs = decode_specs(cfg, shape)
    c_sh = cache_sharding(mesh, specs["cache"])
    cache = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                         specs["cache"], c_sh)
    tokens = jax.ShapeDtypeStruct(specs["tokens"].shape, specs["tokens"].dtype,
                                  sharding=NamedSharding(mesh, P()))
    dp = dp_axes(mesh)
    dp_spec = dp[0] if len(dp) == 1 else dp
    B = shape.global_batch
    import numpy as np
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    logit_spec = P(dp_spec if B % dp_size == 0 else None, "model")
    fn = jax.jit(decode, donate_argnums=(1,),
                 out_shardings=(NamedSharding(mesh, logit_spec), c_sh))
    pos = jnp.asarray(specs["pos"], jnp.int32)
    return fn, (params, cache, tokens, pos)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, dump_hlo=None,
             variant: str = "base", overrides=None) -> dict:
    cfg = get_config(arch)
    if variant == "opt":
        cfg = get_config(arch, **OPT_OVERRIDES.get(arch, {}))
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    ok, reason = supports_shape(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    try:
        mbs = dict(TRAIN_MICROBATCHES)
        if variant == "opt":
            mbs.update(OPT_MICROBATCHES)
        with mesh, activation_mesh(mesh):
            fn, args = build_cell(cfg, shape, mesh, microbatches=mbs)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            print(ma)
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per device
                ca = ca[0] if ca else {}
            print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
            text = compiled.as_text()
            if dump_hlo:
                with open(dump_hlo, "w") as f:
                    f.write(text)
            st = analyze(text, total_devices=n_dev)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to record
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec

    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
    }
    mem["peak_device_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                                + mem["temp_bytes"] - mem["alias_bytes"])
    mf = model_flops_per_device(cfg, shape, n_dev)
    compute_s = st.flops / PEAK_FLOPS
    memory_s = st.bytes_accessed / HBM_BW
    collective_s = st.collective_bytes / LINK_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, collective_s)
    # decode is bandwidth-bound by nature: its roofline fraction is measured
    # against the *minimal* per-step HBM traffic (params + cache read once)
    model_bytes = None
    if shape.kind == "decode":
        cache_bytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree.leaves(args[1]))
        pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(args[0]))
        model_bytes = (cache_bytes + pb * (cfg.active_param_count()
                                           / max(cfg.param_count(), 1))) / n_dev
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem,
        cost_analysis={"flops": ca.get("flops"), "bytes": ca.get("bytes accessed")},
        hlo={"dot_flops": st.flops, "elementwise_flops": st.elementwise_flops,
             "bytes": st.bytes_accessed, "collective_bytes": st.collective_bytes,
             "collective_count": st.collective_count,
             "collective_breakdown": st.collective_breakdown},
        terms={"compute_s": compute_s, "memory_s": memory_s,
               "collective_s": collective_s},
        dominant=dominant,
        model_flops=mf,
        useful_ratio=(mf / st.flops if st.flops else 0.0),
        roofline_fraction=(((model_bytes / HBM_BW) / bound)
                           if (model_bytes and bound) else
                           ((mf / PEAK_FLOPS) / bound if bound else 0.0)),
        model_bytes=model_bytes,
    )
    return rec


def cell_path(arch, shape_name, multi_pod, variant="base"):
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = "" if variant == "base" else f"__{variant}"
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--dump-hlo")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    cells = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    for arch, shape_name in cells:
        path = cell_path(arch, shape_name, args.multi_pod, args.variant)
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {arch} × {shape_name}")
            continue
        print(f"=== {arch} × {shape_name} ({'multi' if args.multi_pod else 'single'}-pod, "
              f"{args.variant}) ===", flush=True)
        rec = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                       dump_hlo=args.dump_hlo, variant=args.variant)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            t = rec["terms"]
            print(f"  ok: compile={rec['compile_s']}s peak_mem="
                  f"{rec['memory']['peak_device_bytes']/2**30:.2f}GiB "
                  f"terms(c/m/coll)={t['compute_s']:.4f}/{t['memory_s']:.4f}/"
                  f"{t['collective_s']:.4f}s dominant={rec['dominant']} "
                  f"roofline={rec['roofline_fraction']:.3f}", flush=True)
        else:
            print(f"  {rec['status']}: {rec.get('reason') or rec.get('error')}",
                  flush=True)


if __name__ == "__main__":
    main()
