"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the pod axis is an
outer data-parallel axis in training and a replica axis in serving.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default anyway
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Generic mesh for tests/examples (e.g. (1,1) on CPU)."""
    return _mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"
