"""Online-serving driver: the `decode_step` workload MuxFlow protects —
optionally space-shared with an offline train step through the multiplexer.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --smoke \
      --requests 200 --qps 40 --share
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.multiplexer import Multiplexer, MuxConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import (init_cache, init_params, make_decode_step,
                          make_train_step)
from repro.optim.optimizer import AdamW, AdamWConfig


def run(arch: str, *, smoke: bool = True, requests: int = 200,
        qps: float = 40.0, share: bool = False, slo: float = 1.25,
        seed: int = 0, batch: int = 4, kv_cap: int = 128) -> dict:
    cfg = get_config(arch, smoke=smoke)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    decode = jax.jit(make_decode_step(cfg))
    cache = init_cache(cfg, batch, kv_cap,
                       src_len=kv_cap if cfg.enc_layers else 0)
    toks = jnp.zeros((batch, 1), jnp.int32)
    # warm up + measure base step
    logits, cache = decode(params, cache, toks, 0)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for i in range(1, 6):
        logits, cache = decode(params, cache, toks, i)
    jax.block_until_ready(logits)
    base_step = (time.perf_counter() - t0) / 5
    pos = [6]

    def online_fn(bs: int) -> float:
        t = time.perf_counter()
        out, _ = decode(params, cache, toks, pos[0] % (kv_cap - 1))
        jax.block_until_ready(out)
        pos[0] += 1
        return time.perf_counter() - t

    state = {}
    if share:
        opt = AdamW(AdamWConfig(lr=1e-3, total_steps=10_000))
        tparams = init_params(jax.random.PRNGKey(1), cfg)
        topt = opt.init(tparams)
        train = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
        pipe = TokenPipeline(DataConfig(cfg.vocab_size, 32, 4))
        state = {"p": tparams, "o": topt, "step": 0}
        # measure offline microstep
        p, o, _ = train(state["p"], state["o"], pipe.batch_at(0))
        jax.block_until_ready(jax.tree.leaves(p)[0])
        t0 = time.perf_counter()
        p, o, _ = train(p, o, pipe.batch_at(1))
        jax.block_until_ready(jax.tree.leaves(p)[0])
        off_step = time.perf_counter() - t0
        state.update(p=p, o=o, step=2)

        def offline_fn() -> float:
            t = time.perf_counter()
            state["p"], state["o"], _ = train(state["p"], state["o"],
                                              pipe.batch_at(state["step"]))
            jax.block_until_ready(jax.tree.leaves(state["p"])[0])
            state["step"] += 1
            return time.perf_counter() - t
    else:
        off_step = 1.0

        def offline_fn() -> float:
            return off_step

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=requests)).tolist()
    horizon = arrivals[-1] + 1.0
    mux = Multiplexer(online_fn, offline_fn, base_step, off_step,
                      MuxConfig(slo_slowdown=slo),
                      offline_state_bytes=0)
    stats = mux.run(arrivals, horizon,
                    max_offline_steps=None if share else 0)
    return {"base_ms": base_step * 1e3, "p50_ms": stats.p50_ms,
            "p99_ms": stats.p99_ms, "served": stats.served,
            "offline_steps": stats.offline_steps,
            "offline_duty": stats.offline_duty, "oversold": stats.oversold,
            "train_steps_done": state.get("step", 0)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="xlstm-350m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--qps", type=float, default=40.0)
    ap.add_argument("--share", action="store_true")
    ap.add_argument("--slo", type=float, default=1.25)
    args = ap.parse_args()
    out = run(args.arch, smoke=args.smoke, requests=args.requests,
              qps=args.qps, share=args.share, slo=args.slo)
    print(f"[serve] base={out['base_ms']:.2f}ms p50={out['p50_ms']:.2f}ms "
          f"p99={out['p99_ms']:.2f}ms served={out['served']} "
          f"offline_steps={out['offline_steps']} oversold={out['oversold']:.2f}")


if __name__ == "__main__":
    main()
