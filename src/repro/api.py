"""repro.api — the curated public surface.

One import target for examples, notebooks, and downstream tooling, so they
stop deep-importing private module paths (which this project treats as free
to move between PRs).  Everything exported here is covered by tests and
kept stable; anything not exported is an implementation detail.

Groups:

* **Policies** — :class:`SharingPolicy`, ``register`` / ``resolve`` /
  ``available`` (the string-keyed sharing-policy registry);
* **Engine** — ``build_sim_config`` (validated :class:`SimConfig` +
  resolved policy), ``run_policy`` for bare engine runs, and the
  Algorithm-1 pieces (``schedule``, ``OnlineSlot``, ``OfflineJob``,
  ``dynamic_sm``, ``build_speed_predictor``, profile tables) the
  quickstart composes by hand;
* **Cluster** — the scenario registry (``Scenario``, ``SCENARIOS``,
  ``scenario_by_name``) and runners (``run_scenario`` → JSON report,
  ``run_policy_scenario`` → SimResults), plus ``check_schema`` /
  ``REPORT_SCHEMA``;
* **Serving** — :class:`ArrivalProcess` (the shared workload definition),
  :class:`ServingConfig` / :class:`ServingPlane`, and the admission-policy
  registry;
* **Observability** — :class:`ObsConfig` (pass as ``run_scenario(obs=...)``),
  :class:`MetricsRegistry` / :class:`PhaseProfiler` for standalone use, and
  the exporter helpers (``canonical_json``, ``prometheus_text``,
  ``lint_prometheus``);
* **Alerting** — the alert-rule registry (``AlertRule``,
  ``register_alert_rule`` / ``resolve_alert_rules`` /
  ``alert_rules_available`` / ``default_alert_rules``), the window-boundary
  :class:`AlertEngine` with its :class:`Incident` lifecycle, and the
  ``incidents.jsonl`` readers (``read_incidents``, ``incidents_open_at``);
* **Forensics** — ``inspect_run`` (time-travel a durable run to a tick and
  summarize its state) and ``diff_runs`` (pinpoint the first divergent WAL
  event between two runs via chain bisection).
"""
from __future__ import annotations

from repro.cluster.control import (REPORT_SCHEMA, check_schema, run_scenario,
                                   run_policy_scenario)
from repro.durability import (DIFF_SCHEMA, INSPECT_SCHEMA, diff_runs,
                              inspect_run)
from repro.obs import (ALERTS_SCHEMA, OBS_SCHEMA, AlertEngine, AlertRule,
                       Incident, MetricsRegistry, ObsConfig, ObsPlane,
                       PhaseProfiler, alert_rules_available, canonical_json,
                       default_alert_rules, incidents_open_at,
                       lint_prometheus, prometheus_text, read_incidents,
                       register_alert_rule, resolve_alert_rules)
from repro.cluster.scenario import SCENARIOS, Scenario, scenario_by_name
from repro.core.dynamic_sm import dynamic_sm
from repro.core.interference import (OFFLINE_MODEL_PROFILES,
                                     ONLINE_SERVICE_PROFILES, online_profile)
from repro.core.predictor import build_speed_predictor
from repro.core.scheduler import OfflineJob, OnlineSlot, schedule
from repro.core.simulator import (SimConfig, SimResults, build_sim_config,
                                  run_policy)
from repro.policies import (SharingPolicy, available, register, resolve)
from repro.serving_plane import (ARRIVAL_KINDS, AdmissionPolicy,
                                 ArrivalProcess, ServingConfig, ServingPlane,
                                 admission_available, register_admission,
                                 resolve_admission)

__all__ = [
    # policies
    "SharingPolicy", "available", "register", "resolve",
    # engine
    "SimConfig", "SimResults", "build_sim_config", "run_policy",
    "schedule", "OnlineSlot", "OfflineJob", "dynamic_sm",
    "build_speed_predictor", "online_profile",
    "OFFLINE_MODEL_PROFILES", "ONLINE_SERVICE_PROFILES",
    # cluster
    "Scenario", "SCENARIOS", "scenario_by_name",
    "run_scenario", "run_policy_scenario",
    "check_schema", "REPORT_SCHEMA",
    # serving
    "ARRIVAL_KINDS", "ArrivalProcess", "AdmissionPolicy",
    "ServingConfig", "ServingPlane",
    "admission_available", "register_admission", "resolve_admission",
    # observability
    "ObsConfig", "ObsPlane", "OBS_SCHEMA",
    "MetricsRegistry", "PhaseProfiler",
    "canonical_json", "prometheus_text", "lint_prometheus",
    # alerting
    "ALERTS_SCHEMA", "AlertRule", "AlertEngine", "Incident",
    "register_alert_rule", "resolve_alert_rules",
    "alert_rules_available", "default_alert_rules",
    "read_incidents", "incidents_open_at",
    # forensics
    "INSPECT_SCHEMA", "inspect_run", "DIFF_SCHEMA", "diff_runs",
]
