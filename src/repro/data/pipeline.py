"""Deterministic synthetic token pipeline with per-host sharding.

Production shape: an infinite, seekable stream — `batch_at(step)` is a pure
function of (seed, step), so restart-from-checkpoint replays the exact data
order with no state files, and each host materializes only its slice of the
global batch (`host_slice`).  Sequences are Zipf-distributed token ids with
Markov structure so losses are non-trivial (the model can learn).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3


class TokenPipeline:
    def __init__(self, cfg: DataConfig, *, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.host_batch = cfg.global_batch // n_hosts
        # fixed Zipf unigram table + a shift-register mixing rule
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(p / p.sum())

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for `step` (this host's slice)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_id]))
        u = rng.random((self.host_batch, cfg.seq_len))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        # Markov-ish structure: every other token correlates with its left
        # neighbour, so next-token prediction has learnable signal
        toks[:, 1::2] = (toks[:, 0::2][:, : toks[:, 1::2].shape[1]]
                         * 31 + 7) % cfg.vocab_size
        return {"tokens": toks}

    def host_slice(self, step: int) -> dict:
        return self.batch_at(step)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def global_batch_to_device(batch: dict, sharding=None) -> dict:
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding[k] if isinstance(sharding, dict)
                              else sharding) for k, v in batch.items()}
