"""Profiling-campaign CLI.

  PYTHONPATH=src python -m repro.profiling.run --list
  PYTHONPATH=src python -m repro.profiling.run --suite smoke \
      --out speed_matrix_smoke.json
  PYTHONPATH=src python -m repro.profiling.run --suite full --seed 1
  PYTHONPATH=src python -m repro.profiling.run \
      --check-schema speed_matrix_smoke.json

Executes the workload catalog (Pallas kernels in interpret mode on CPU),
profiles every online×offline pair across the suite's SM-share sweep, and
writes the speed-matrix artifact.  Artifacts are canonical JSON with no
wall-clock fields: the same (suite, seed) always produces byte-identical
output (CI ``cmp``s two runs).  Wall-time execution stats go to stderr.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.profiling.harness import SUITES, PairProfiler, build_speed_matrix
from repro.profiling.matrix import SpeedMatrix, check_schema
from repro.profiling.workloads import build_catalog


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.profiling.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--suite", default="smoke", choices=sorted(SUITES),
                    help="profiling campaign (smoke: CI-sized; full: dense "
                         "share sweep)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the speed-matrix JSON here (default: stdout)")
    ap.add_argument("--no-interpret", dest="interpret", action="store_false",
                    default=None,
                    help="compile the kernels instead of interpret mode "
                         "(default: interpret off-TPU)")
    ap.add_argument("--list", action="store_true",
                    help="list the workload catalog and exit")
    ap.add_argument("--check-schema", metavar="MATRIX.json", default=None,
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, w in build_catalog().items():
            print(f"{name:16s} {w.role:8s} seed={w.seed:<4d} "
                  f"warmup={w.warmup} steps={w.steps} "
                  f"cost={w.cost_s() * 1e3:.4f}ms "
                  f"flops/step={w.flops_per_step:.3g}")
        return 0
    if args.check_schema:
        with open(args.check_schema) as f:
            problems = check_schema(json.load(f))
        for p in problems:
            print(f"SCHEMA: {p}", file=sys.stderr)
        print("schema " + ("FAIL" if problems else "OK"), file=sys.stderr)
        return 1 if problems else 0

    t0 = time.perf_counter()
    sc = SUITES[args.suite]
    prof = PairProfiler(sc, seed=args.seed, interpret=args.interpret)
    records, grid = prof.run()
    matrix = SpeedMatrix.from_run(sc, args.seed, prof, records, grid)
    wall = time.perf_counter() - t0
    out = matrix.to_json()
    if args.out:
        with open(args.out, "w") as f:
            f.write(out)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(out, end="")
    for name, rec in records.items():
        print(f"[exec] {name:16s} {rec.steps_executed} steps, "
              f"{rec.wall_ms_per_step:.2f} ms/step wall, "
              f"checksum {rec.checksum}", file=sys.stderr)
    n_cells = sum(len(cells) for cells in grid.values())
    print(f"[{args.suite}] {len(records)} workloads, {len(grid)} pairs, "
          f"{n_cells} cells, quantum {prof.quantum_s() * 1e6:.2f}us "
          f"({wall:.1f}s wall)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
