"""Deprecated profiling-campaign entry point.

``python -m repro.profiling.run`` is now a thin delegate of the unified CLI
— ``python -m repro profile`` (see :mod:`repro.cli`).  Flags and stdout
bytes (the speed-matrix artifact) are unchanged; a deprecation note goes to
stderr.
"""
from __future__ import annotations

import sys

from repro.cli import deprecation_note, profile_main


def main(argv=None) -> int:
    deprecation_note("python -m repro.profiling.run",
                     "python -m repro profile")
    return profile_main(argv, prog="python -m repro.profiling.run")


if __name__ == "__main__":
    sys.exit(main())
