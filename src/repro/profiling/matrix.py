"""The speed-matrix artifact: versioned, schema-checked, byte-reproducible.

A speed matrix is what the profiling harness measured: for every
online×offline workload pair, the online slowdown and normalized offline
throughput across a sweep of assigned SM shares, plus each workload's
separate-execution profile and execution checksum.  It is the measured
counterpart of the closed-form model in :mod:`repro.core.interference` — the
calibration layer (:mod:`repro.profiling.calibrate`) turns it into a drop-in
interference provider and a predictor training set.

Serialization is canonical: floats rounded to 9 places, keys sorted, no
wall-clock fields — two same-seed runs produce byte-identical files (CI
``cmp``s them).
"""
from __future__ import annotations

import dataclasses
import json

SCHEMA = "repro.profiling.speed_matrix/v1"

_WORKLOAD_KEYS = ("role", "flops_per_step", "bytes_per_step", "cost_ms",
                  "cost_quanta", "steps_executed", "checksum", "profile")
_PROFILE_KEYS = ("gpu_util", "sm_activity", "sm_occupancy", "mem_bw",
                 "exec_time_ms", "mem_bytes_frac")
_PAIR_KEYS = ("online", "offline", "shares", "online_slowdown",
              "offline_tput", "achieved_share", "online_p99_ms",
              "n_online", "n_offline", "monitor_healthy_frac")


def _rounded(obj, ndigits: int = 9):
    """Recursively round floats so serialization is canonical."""
    if isinstance(obj, float):
        return round(obj, ndigits)
    if isinstance(obj, dict):
        return {k: _rounded(v, ndigits) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_rounded(v, ndigits) for v in obj]
    return obj


@dataclasses.dataclass
class SpeedMatrix:
    """In-memory form of the artifact; ``data`` is the schema-shaped dict."""
    data: dict

    # ------------------------------------------------------------- assembly
    @classmethod
    def from_run(cls, suite, seed: int, profiler, records,
                 grid) -> "SpeedMatrix":
        """Assemble from a :class:`~repro.profiling.harness.PairProfiler`
        run.  Wall-time stats in the execution records are deliberately
        dropped here — only deterministic fields enter the artifact."""
        workloads = {}
        for name, rec in records.items():
            w = rec.workload
            p = rec.profile
            workloads[name] = {
                "role": w.role,
                "flops_per_step": float(w.flops_per_step),
                "bytes_per_step": float(w.bytes_per_step),
                "cost_ms": w.cost_s() * 1e3,
                "cost_quanta": profiler.cost_quanta(w),
                "steps_executed": rec.steps_executed,
                "checksum": rec.checksum,
                "profile": {k: float(getattr(p, k)) for k in _PROFILE_KEYS},
            }
        pairs = []
        for (on, off), cells in sorted(grid.items()):
            pairs.append({
                "online": on, "offline": off,
                "shares": [c.share for c in cells],
                "online_slowdown": [c.online_slowdown for c in cells],
                "offline_tput": [c.offline_tput for c in cells],
                "achieved_share": [c.achieved_share for c in cells],
                "online_p99_ms": [c.online_p99_ms for c in cells],
                "n_online": [c.n_online for c in cells],
                "n_offline": [c.n_offline for c in cells],
                "monitor_healthy_frac": [c.monitor_healthy_frac
                                         for c in cells],
            })
        return cls({
            "schema": SCHEMA,
            "suite": suite.name,
            "seed": seed,
            "cost_model": "roofline-v1",
            "quantum_ms": profiler.quantum_s() * 1e3,
            "horizon_quanta": suite.horizon_quanta,
            "telemetry_window": suite.telemetry_window,
            "workloads": workloads,
            "pairs": pairs,
        })

    # -------------------------------------------------------------- access
    @property
    def workloads(self) -> dict:
        return self.data["workloads"]

    @property
    def pairs(self) -> list[dict]:
        return self.data["pairs"]

    def pair(self, online: str, offline: str) -> dict:
        for p in self.pairs:
            if p["online"] == online and p["offline"] == offline:
                return p
        raise KeyError(f"no measured pair ({online!r}, {offline!r})")

    # ------------------------------------------------------- serialization
    def to_json(self) -> str:
        return json.dumps(_rounded(self.data), indent=2, sort_keys=True) + "\n"

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "SpeedMatrix":
        with open(path) as f:
            data = json.load(f)
        problems = check_schema(data)
        if problems:
            raise ValueError(f"invalid speed matrix {path}: "
                             + "; ".join(problems))
        return cls(data)


def check_schema(data: dict) -> list[str]:
    """Validate the v1 artifact shape and value contracts; returns a list of
    problems (empty = valid)."""
    problems: list[str] = []
    if data.get("schema") != SCHEMA:
        problems.append(f"schema != {SCHEMA!r}: {data.get('schema')!r}")
    for key in ("suite", "seed", "cost_model", "quantum_ms",
                "horizon_quanta", "telemetry_window", "workloads", "pairs"):
        if key not in data:
            problems.append(f"missing key {key!r}")
    workloads = data.get("workloads") or {}
    if not workloads:
        problems.append("workloads missing or empty")
    roles = {"online": [], "offline": []}
    for name, w in workloads.items():
        for key in _WORKLOAD_KEYS:
            if key not in w:
                problems.append(f"workload {name!r} missing {key!r}")
        prof = w.get("profile") or {}
        for key in _PROFILE_KEYS:
            if key not in prof:
                problems.append(f"workload {name!r} profile missing {key!r}")
        if w.get("role") in roles:
            roles[w["role"]].append(name)
        else:
            problems.append(f"workload {name!r} has bad role {w.get('role')!r}")
    pairs = data.get("pairs")
    if not isinstance(pairs, list) or not pairs:
        problems.append("pairs missing or empty")
        return problems
    for p in pairs:
        tag = f"pair ({p.get('online')!r}, {p.get('offline')!r})"
        for key in _PAIR_KEYS:
            if key not in p:
                problems.append(f"{tag} missing {key!r}")
        if p.get("online") not in roles["online"]:
            problems.append(f"{tag}: online not a cataloged online workload")
        if p.get("offline") not in roles["offline"]:
            problems.append(f"{tag}: offline not a cataloged offline workload")
        shares = p.get("shares") or []
        if shares != sorted(shares):
            problems.append(f"{tag}: shares not sorted")
        if any(not 0.0 <= s <= 1.0 for s in shares):
            problems.append(f"{tag}: share outside [0, 1]")
        n = len(shares)
        for key in ("online_slowdown", "offline_tput", "achieved_share",
                    "online_p99_ms", "n_online", "n_offline",
                    "monitor_healthy_frac"):
            vals = p.get(key)
            if not isinstance(vals, list) or len(vals) != n:
                problems.append(f"{tag}: {key} length != len(shares)")
        if any(s < 1.0 - 1e-9 for s in p.get("online_slowdown") or []):
            problems.append(f"{tag}: online_slowdown < 1")
        if any(not 0.0 <= v <= 1.0 for v in p.get("offline_tput") or []):
            problems.append(f"{tag}: offline_tput outside [0, 1]")
    return problems
