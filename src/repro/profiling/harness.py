"""Pair-profiling harness: measure online×offline co-location on one device.

What DCGM measures on a real MuxFlow node, reproduced as a deterministic
discrete-event emulation over *executed* workloads:

  * Every catalog workload is first **executed for real** (:func:`
    repro.profiling.workloads.execute`) — Pallas kernels in interpret mode on
    CPU — which yields an output checksum (artifact-stable proof of
    execution) and the roofline step costs the virtual clock runs on.
  * Each (online, offline, SM-share) cell then runs a quantum-level device
    loop: online requests arrive on a seeded Poisson process and have strict
    priority; offline steps are non-preemptive and gated by the *actual*
    :class:`repro.core.protection.KernelThrottle` + PID duty controller —
    the §4.1 xCUDA seam — whose setpoint is the assigned SM share (duty-cycle
    throttling is the share emulation, as on hardware without MPS).
  * DCGM-style telemetry is sampled every window into the scalar
    :class:`repro.core.sysmonitor.SysMonitor` state machine, on a
    :class:`repro.core.protection.VirtualClock`, so the protection stack sees
    the same metrics stream it would in production.

The measured cell outputs — online slowdown (vs a paired offline-free
baseline run with the same arrival process), normalized offline throughput,
achieved share, p99 latency — populate the speed-matrix artifact
(:mod:`repro.profiling.matrix`).  Everything is a pure function of
(catalog, suite, seed): artifacts are byte-identical across runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.protection import (DeviceTelemetry, KernelThrottle, PIDConfig,
                                   PIDController, VirtualClock)
from repro.core.sysmonitor import GPUState, SysMonitor, SysMonitorConfig
from repro.profiling.workloads import (ExecutionRecord, Workload,
                                       build_catalog, catalog_by_role,
                                       execute)

MAX_COST_QUANTA = 250


@dataclasses.dataclass(frozen=True)
class SuiteConfig:
    """One named profiling campaign."""
    name: str
    shares: tuple[float, ...]
    horizon_quanta: int
    telemetry_window: int = 50


SUITES: dict[str, SuiteConfig] = {
    "smoke": SuiteConfig("smoke", (0.2, 0.5, 0.8), 4000),
    "full": SuiteConfig(
        "full", tuple(round(0.1 * k, 1) for k in range(1, 10)), 16000),
}


@dataclasses.dataclass
class CellResult:
    """One measured (online, offline, share) co-location cell."""
    online: str
    offline: str
    share: float
    online_slowdown: float        # mean latency / offline-free mean latency
    offline_tput: float           # completed steps / steps running alone
    achieved_share: float         # offline busy quanta / horizon
    online_p99_ms: float
    n_online: int
    n_offline: int
    monitor_healthy_frac: float


@dataclasses.dataclass
class _LoopStats:
    latencies: list
    off_done: int
    off_busy_total: int
    healthy_windows: int
    windows: int


def _arrivals(online: Workload, on_cost: int, horizon: int,
              seed: int) -> np.ndarray:
    """Seeded Poisson arrival times (quanta).  Seeded by the online workload
    only, so every cell of a pair sweep sees the same request stream and the
    slowdown comparison is paired.

    Rides the shared :class:`ArrivalProcess` (``mean_gap`` passed through,
    same ``SeedSequence``, same gap-batch size) — bit-for-bit the stream
    this function historically sampled inline, so speed-matrix artifacts
    are unchanged by the unification (CI ``cmp``s them)."""
    from repro.serving_plane import ArrivalProcess
    mean_gap = on_cost / max(online.target_util, 0.05)
    process = ArrivalProcess.poisson(mean_gap=mean_gap,
                                     seed=[seed, online.seed])
    return process.times(horizon).astype(np.int64)


def _device_loop(on: Workload, off: Workload | None, on_cost: int,
                 off_cost: int, share: float | None, arrivals: np.ndarray,
                 suite: SuiteConfig, quantum_s: float) -> _LoopStats:
    """The quantum-level device loop; ``share=None`` disables the offline
    partner (the baseline cell)."""
    window = suite.telemetry_window
    window_s = window * quantum_s
    clock = VirtualClock()    # stamps SysMonitor telemetry; the PID steps
    # once per window with a dimensionless dt=1.0 (window quanta are far
    # below a virtual second, so clock-derived dt would freeze the loop)
    throttle = KernelThrottle(
        PIDController(PIDConfig(setpoint=share or 0.0, kp=0.5, ki=0.2,
                                kd=0.0, out_min=0.0, out_max=1.0),
                      initial=share or 0.0))
    monitor = SysMonitor(
        SysMonitorConfig(init_duration_s=2 * window_s,
                         readmit_base_s=10 * window_s,
                         overlimit_window_s=400 * window_s),
        now=0.0)
    on_prof = on.profile()
    off_prof = off.profile() if off is not None else None
    queue: list[int] = []
    lat: list[int] = []
    ai = 0
    on_left = off_left = 0
    cur_arrival = 0
    off_done = off_busy_total = 0
    on_busy_w = off_busy_w = 0
    healthy_windows = windows = 0
    for t in range(suite.horizon_quanta):
        while ai < arrivals.size and arrivals[ai] <= t:
            queue.append(int(arrivals[ai]))
            ai += 1
        if on_left == 0 and off_left == 0:
            if queue:
                cur_arrival = queue.pop(0)
                on_left = on_cost
            elif share is not None and throttle.should_launch(1.0):
                off_left = off_cost
        if on_left > 0:
            on_left -= 1
            on_busy_w += 1
            if on_left == 0:
                lat.append(t + 1 - cur_arrival)
        elif off_left > 0:
            off_left -= 1
            off_busy_w += 1
            off_busy_total += 1
            if off_left == 0:
                off_done += 1
        if (t + 1) % window == 0:
            clock.advance(window_s)
            occ_off = off_busy_w / window
            util = (on_busy_w + off_busy_w) / window
            if share is not None:
                throttle.duty = throttle.pid.update(occ_off, dt=1.0)
            sm_act = (on_busy_w * on_prof.sm_activity
                      + off_busy_w * (off_prof.sm_activity if off_prof
                                      else 0.0)) / window
            mem = on_prof.mem_bytes_frac + (off_prof.mem_bytes_frac
                                            if off_prof else 0.0)
            clk = 1590.0 - 440.0 * max(0.0, util - 0.85) / 0.15
            state, _ = monitor.update(
                DeviceTelemetry(ts=clock.time(), gpu_util=util,
                                sm_activity=sm_act, sm_clock=clk,
                                mem_used_frac=min(mem, 1.0)),
                now=clock.time())
            windows += 1
            healthy_windows += state == GPUState.HEALTHY
            on_busy_w = off_busy_w = 0
    return _LoopStats(lat, off_done, off_busy_total, healthy_windows, windows)


@dataclasses.dataclass
class PairProfiler:
    """Profiles every online×offline catalog pair across a share sweep."""
    suite: SuiteConfig
    seed: int = 0
    interpret: bool | None = None
    catalog: dict[str, Workload] | None = None

    def __post_init__(self):
        self.catalog = self.catalog or build_catalog()
        self.records: dict[str, ExecutionRecord] = {}

    # ------------------------------------------------------------ execution
    def ensure_executed(self) -> dict[str, ExecutionRecord]:
        for name, w in self.catalog.items():
            if name not in self.records:
                self.records[name] = execute(w, interpret=self.interpret)
        return self.records

    def quantum_s(self) -> float:
        """The virtual-clock quantum: the cheapest catalog step's cost."""
        return min(w.cost_s() for w in self.catalog.values())

    def cost_quanta(self, w: Workload) -> int:
        q = self.quantum_s()
        return int(np.clip(round(w.cost_s() / q), 1, MAX_COST_QUANTA))

    # ------------------------------------------------------------ profiling
    def profile_pair(self, online: Workload,
                     offline: Workload) -> list[CellResult]:
        """Baseline + one cell per share for a pair; slowdowns are relative
        to the pair's own offline-free baseline under identical arrivals."""
        q = self.quantum_s()
        on_cost = self.cost_quanta(online)
        off_cost = self.cost_quanta(offline)
        arrivals = _arrivals(online, on_cost, self.suite.horizon_quanta,
                             self.seed)
        base = _device_loop(online, None, on_cost, off_cost, None, arrivals,
                            self.suite, q)
        base_lat = float(np.mean(base.latencies)) if base.latencies else 1.0
        alone = max(self.suite.horizon_quanta // off_cost, 1)
        cells = []
        for share in self.suite.shares:
            st = _device_loop(online, offline, on_cost, off_cost, share,
                              arrivals, self.suite, q)
            mean_lat = float(np.mean(st.latencies)) if st.latencies else base_lat
            p99 = (float(np.percentile(st.latencies, 99)) * q * 1e3
                   if st.latencies else 0.0)
            cells.append(CellResult(
                online=online.name, offline=offline.name, share=float(share),
                online_slowdown=max(1.0, mean_lat / max(base_lat, 1e-9)),
                offline_tput=float(np.clip(st.off_done / alone, 0.0, 1.0)),
                achieved_share=st.off_busy_total / self.suite.horizon_quanta,
                online_p99_ms=p99,
                n_online=len(st.latencies), n_offline=st.off_done,
                monitor_healthy_frac=st.healthy_windows / max(st.windows, 1)))
        return cells

    def run(self) -> tuple[dict[str, ExecutionRecord],
                           dict[tuple[str, str], list[CellResult]]]:
        """Execute the catalog, then profile the full online×offline grid."""
        self.ensure_executed()
        onlines, offlines = catalog_by_role(self.catalog)
        grid = {}
        for on in onlines:
            for off in offlines:
                grid[(on.name, off.name)] = self.profile_pair(on, off)
        return self.records, grid


def build_speed_matrix(suite: str = "smoke", seed: int = 0,
                       interpret: bool | None = None):
    """Execute + profile + assemble the versioned speed-matrix artifact."""
    from repro.profiling.matrix import SpeedMatrix
    sc = SUITES[suite]
    prof = PairProfiler(sc, seed=seed, interpret=interpret)
    records, grid = prof.run()
    return SpeedMatrix.from_run(sc, seed, prof, records, grid)
