"""repro.profiling — measured workload profiling & simulator calibration.

The subsystem that grounds the simulator in executed jax_pallas kernels:

  * :mod:`repro.profiling.workloads` — the catalog of real executables
    (flash-attention prefill, decode-attention serving, SSM scan, LM train
    step) as named, seeded, role-tagged :class:`Workload` records, plus the
    single metrics-sampling path (absorbing the old ``core/profiler.py``).
  * :mod:`repro.profiling.harness` — the pair-profiling harness: executed
    workloads co-located under emulated SM shares (duty-cycle throttling via
    the ``protection.py`` PID seam, telemetry through ``SysMonitor``).
  * :mod:`repro.profiling.matrix` — the versioned, schema-checked,
    byte-reproducible speed-matrix artifact.
  * :mod:`repro.profiling.calibrate` — :class:`MeasuredInterferenceProvider`
    (drop-in for the analytic ``shared_performance_arrays``), measured
    predictor training, and the ``muxflow-measured`` sharing policy behind
    the ``calibrated`` cluster scenario.

CLI: ``python -m repro.profiling.run --suite smoke`` (see ``--help``).
"""
from repro.profiling.calibrate import (MeasuredInterferenceProvider,
                                       build_measured_predictor,
                                       default_matrix, make_measured_dataset,
                                       predict_share_curve,
                                       register_measured_policy,
                                       workload_profile)
from repro.profiling.harness import (SUITES, PairProfiler, SuiteConfig,
                                     build_speed_matrix)
from repro.profiling.matrix import SCHEMA, SpeedMatrix, check_schema
from repro.profiling.workloads import (ExecutionRecord, ProfileStore,
                                       Workload, build_catalog,
                                       catalog_by_role, execute,
                                       profile_from_trace, profile_step_fn)

MEASURED_MUXFLOW = register_measured_policy()

__all__ = [
    "SUITES", "SCHEMA", "ExecutionRecord", "MeasuredInterferenceProvider",
    "PairProfiler", "ProfileStore", "SpeedMatrix", "SuiteConfig", "Workload",
    "build_catalog", "build_measured_predictor", "build_speed_matrix",
    "catalog_by_role", "check_schema", "default_matrix", "execute",
    "make_measured_dataset", "predict_share_curve", "profile_from_trace",
    "profile_step_fn", "register_measured_policy", "workload_profile",
    "MEASURED_MUXFLOW",
]
