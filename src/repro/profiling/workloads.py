"""The workload catalog: the repo's real jax_pallas executables as named,
seeded, role-tagged :class:`Workload` records the pair-profiling harness can
run.

This module is the single metrics-sampling path (it absorbed the seed's
53-line ``core/profiler.py``, whose deprecation shim has since been
removed).  A profile has two sources of truth, kept deliberately separate:

  * **Execution** — :func:`execute` really runs the step function (Pallas
    kernels in interpret mode on CPU, compiled on TPU) and records an output
    checksum plus wall-time stats.  Wall time is *measurement-only*: it
    proves the workload runs and how fast, but it never enters a speed-matrix
    artifact, because artifacts must be byte-identical across runs.
  * **Cost model** — deterministic per-step cost from the declared analytic
    FLOP/byte counts against T4-class peaks (``roofline-v1``).  The harness's
    virtual clock runs on these costs, so co-location measurements are exact
    functions of (catalog, suite, seed).

The four catalog entries cover the repo's serving and training hot paths:
flash-attention prefill and decode-attention (online role — the workloads
MuxFlow protects) and the SSM scan plus a real LM train step (offline role —
the best-effort work MuxFlow packs in).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.interference import OFFLINE_MODEL_PROFILES, WorkloadProfile

# roofline-v1 device model (T4-class, matching the paper's testbed GPU)
PEAK_FLOPS = 8.1e12        # fp32 FLOP/s
PEAK_BW = 300e9            # HBM bytes/s
DEVICE_BYTES = 16 << 30    # 16 GiB HBM
COST_MODEL = "roofline-v1"

ROLE_ONLINE = "online"
ROLE_OFFLINE = "offline"


@dataclasses.dataclass(frozen=True)
class Workload:
    """One named, seeded, role-tagged executable.

    ``build(interpret)`` returns a zero-argument step function whose float
    return value feeds the execution checksum.  ``flops_per_step`` /
    ``bytes_per_step`` are analytic counts for the roofline cost model;
    ``mem_bytes`` is the resident footprint (inputs + params) for
    memory-quota feasibility.  ``target_util`` is the online role's duty
    cycle in the harness (offline workloads run dense).
    """
    name: str
    role: str                          # ROLE_ONLINE | ROLE_OFFLINE
    seed: int
    warmup: int
    steps: int
    flops_per_step: float
    bytes_per_step: float
    mem_bytes: int
    build: Callable[[bool], Callable[[], float]]
    target_util: float = 0.5

    def cost_s(self) -> float:
        """Deterministic roofline step cost (compute + memory phases)."""
        return self.flops_per_step / PEAK_FLOPS + self.bytes_per_step / PEAK_BW

    def profile(self) -> WorkloadProfile:
        """Separate-execution profile derived from the cost model.

        The 'SM activity' analogue is the compute fraction of the roofline
        cost, 'memory bandwidth' the byte fraction (they sum to 1 by
        construction, floored at 0.05 like the seed profiler did)."""
        cost = max(self.cost_s(), 1e-12)
        compute_frac = (self.flops_per_step / PEAK_FLOPS) / cost
        bw_frac = (self.bytes_per_step / PEAK_BW) / cost
        util = self.target_util if self.role == ROLE_ONLINE else 0.95
        return WorkloadProfile(
            name=self.name, gpu_util=util,
            sm_activity=max(compute_frac, 0.05),
            sm_occupancy=0.35 + 0.3 * max(compute_frac, 0.05),
            mem_bw=max(bw_frac, 0.05),
            exec_time_ms=cost * 1e3,
            mem_bytes_frac=self.mem_bytes / DEVICE_BYTES)


@dataclasses.dataclass
class ExecutionRecord:
    """What one :func:`execute` run measured."""
    workload: Workload
    steps_executed: int
    checksum: float              # deterministic (seeded inputs, CPU/TPU math)
    wall_ms_per_step: float      # measured; NEVER serialized into artifacts
    profile: WorkloadProfile = dataclasses.field(init=False)

    def __post_init__(self):
        self.profile = self.workload.profile()


def execute(workload: Workload, *, interpret: bool | None = None,
            clock=time.perf_counter) -> ExecutionRecord:
    """Run ``workload`` for real: warmup, then ``steps`` timed iterations.

    Returns the execution record with an output checksum (rounded so the
    float is stable) and wall stats.  ``interpret`` defaults to True off-TPU
    so the Pallas kernels discharge on CPU."""
    import jax
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    step_fn = workload.build(interpret)
    for _ in range(workload.warmup):
        step_fn()
    acc = 0.0
    t0 = clock()
    for _ in range(workload.steps):
        acc += step_fn()
    wall = (clock() - t0) / max(workload.steps, 1)
    return ExecutionRecord(
        workload=workload, steps_executed=workload.steps,
        checksum=float(round(acc, 6)), wall_ms_per_step=wall * 1e3)


# ---------------------------------------------------------------------------
# Catalog builders (imports deferred so the module stays cheap to import)
# ---------------------------------------------------------------------------

def _build_flash_prefill(interpret: bool) -> Callable[[], float]:
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention
    B, Sq, H, Hk, d = 1, 128, 4, 2, 64
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (B, Sq, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, Hk, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, Hk, d), jnp.float32)

    def step() -> float:
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=interpret)
        return float(jnp.sum(out.astype(jnp.float32)))
    return step


def _build_decode_serve(interpret: bool) -> Callable[[], float]:
    import jax
    import jax.numpy as jnp
    from repro.kernels.decode_attention import decode_attention
    B, Skv, H, Hk, d, kv_len = 4, 256, 4, 2, 64, 224
    key = jax.random.PRNGKey(23)
    q = jax.random.normal(key, (B, 1, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Skv, Hk, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Skv, Hk, d), jnp.float32)

    def step() -> float:
        out = decode_attention(q, k, v, kv_len, block_k=128,
                               interpret=interpret)
        return float(jnp.sum(out.astype(jnp.float32)))
    return step


def _build_ssm_scan(interpret: bool) -> Callable[[], float]:
    import jax
    import jax.numpy as jnp
    from repro.kernels.ssm_scan import ssm_scan
    B, S, di, N, chunk = 2, 64, 128, 8, 16
    key = jax.random.PRNGKey(37)
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, di), jnp.float32))
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, di), jnp.float32)
    Bc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, N), jnp.float32)
    Cc = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N), jnp.float32)
    A_log = jnp.log(jnp.broadcast_to(
        jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)))

    def step() -> float:
        out = ssm_scan(dt, x, Bc, Cc, A_log, chunk=chunk, interpret=interpret)
        return float(jnp.sum(out))
    return step


_TRAIN_ARCH = "xlstm-350m"
_TRAIN_BATCH, _TRAIN_SEQ = 2, 32


def _build_lm_train(interpret: bool) -> Callable[[], float]:
    # interpret is irrelevant here: the smoke model's CPU path is pure jnp
    import jax
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.models import init_params
    from repro.models.steps import make_train_step
    from repro.optim.optimizer import MomentumSGD, MomentumSGDConfig
    cfg = get_config(_TRAIN_ARCH, smoke=True)
    params = init_params(jax.random.PRNGKey(41), cfg)
    opt = MomentumSGD(MomentumSGDConfig(lr=1e-3, momentum=0.9))
    opt_state = opt.init(params)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, _TRAIN_SEQ, _TRAIN_BATCH,
                                    seed=41))
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = {"params": params, "opt": opt_state, "i": 0}

    def step() -> float:
        batch = pipe.batch_at(state["i"])
        state["params"], state["opt"], metrics = step_fn(
            state["params"], state["opt"], batch)
        state["i"] += 1
        return float(metrics["loss"])
    return step


def _train_work() -> tuple[float, float, int]:
    """Analytic train-step work: ~6 FLOP per param per token, parameter +
    gradient + optimizer traffic for bytes (fp32)."""
    from repro.configs import get_config
    cfg = get_config(_TRAIN_ARCH, smoke=True)
    n_params = cfg.param_count()
    tokens = _TRAIN_BATCH * _TRAIN_SEQ
    flops = 6.0 * n_params * tokens
    bytes_ = 3.0 * n_params * 4
    mem = int(4 * n_params * 4)          # params + grads + momentum + slack
    return flops, bytes_, mem


def _attn_flops(B, Sq, Skv, H, d) -> float:
    return 4.0 * B * H * Sq * Skv * d


def build_catalog() -> dict[str, Workload]:
    """The canonical catalog, rebuilt fresh each call (entries are frozen)."""
    train_flops, train_bytes, train_mem = _train_work()
    entries = [
        Workload(
            name="flash-prefill", role=ROLE_ONLINE, seed=11, warmup=1, steps=3,
            flops_per_step=_attn_flops(1, 128, 128, 4, 64),
            bytes_per_step=float((128 * 4 * 64 + 2 * 128 * 2 * 64
                                  + 128 * 4 * 64) * 4),
            mem_bytes=(128 * 4 * 64 + 2 * 128 * 2 * 64) * 4,
            build=_build_flash_prefill, target_util=0.6),
        Workload(
            name="decode-serve", role=ROLE_ONLINE, seed=23, warmup=1, steps=3,
            flops_per_step=_attn_flops(4, 1, 256, 4, 64),
            bytes_per_step=float(4 * (2 * 256 * 2 * 64 + 2 * 4 * 64) * 4),
            mem_bytes=4 * 2 * 256 * 2 * 64 * 4,
            build=_build_decode_serve, target_util=0.45),
        Workload(
            name="ssm-scan", role=ROLE_OFFLINE, seed=37, warmup=1, steps=3,
            flops_per_step=float(2 * 64 * 128 * 8 * 6),
            bytes_per_step=float(2 * 64 * (2 * 128 + 2 * 8) * 4),
            mem_bytes=2 * 64 * (2 * 128 + 2 * 8) * 4,
            build=_build_ssm_scan),
        Workload(
            name="lm-train-step", role=ROLE_OFFLINE, seed=41, warmup=1, steps=2,
            flops_per_step=train_flops, bytes_per_step=train_bytes,
            mem_bytes=train_mem, build=_build_lm_train),
    ]
    return {w.name: w for w in entries}


def catalog_by_role(catalog: dict[str, Workload] | None = None,
                    ) -> tuple[list[Workload], list[Workload]]:
    """(online workloads, offline workloads) in catalog order."""
    catalog = catalog or build_catalog()
    ws = list(catalog.values())
    return ([w for w in ws if w.role == ROLE_ONLINE],
            [w for w in ws if w.role == ROLE_OFFLINE])


# ---------------------------------------------------------------------------
# Seed-era profiler API (the profiler's home since it left core/profiler.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ProfileStore:
    """The paper stores measured profiles in a database keyed by workload."""
    profiles: dict = dataclasses.field(default_factory=dict)

    def get(self, key: str) -> WorkloadProfile | None:
        return self.profiles.get(key)

    def put(self, key: str, profile: WorkloadProfile) -> None:
        self.profiles[key] = profile


def profile_step_fn(step_fn: Callable[[], None], *, name: str,
                    warmup: int = 2, iters: int = 5,
                    flops_per_step: float = 0.0,
                    bytes_per_step: float = 0.0,
                    peak_flops: float = 197e12,
                    peak_bw: float = 819e9,
                    mem_bytes: int = 0,
                    device_bytes: int = DEVICE_BYTES) -> WorkloadProfile:
    """Wall-clock profiling of an arbitrary step callable (the seed's dry-run
    path).  Prefer the catalog's deterministic :meth:`Workload.profile` for
    anything that feeds an artifact."""
    for _ in range(warmup):
        step_fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        step_fn()
    dt = (time.perf_counter() - t0) / iters
    compute_frac = min(1.0, (flops_per_step / peak_flops) / max(dt, 1e-9))
    bw_frac = min(1.0, (bytes_per_step / peak_bw) / max(dt, 1e-9))
    return WorkloadProfile(
        name=name, gpu_util=0.95, sm_activity=max(compute_frac, 0.05),
        sm_occupancy=0.5, mem_bw=max(bw_frac, 0.05), exec_time_ms=dt * 1e3,
        mem_bytes_frac=mem_bytes / device_bytes)


def profile_from_trace(model: str) -> WorkloadProfile:
    return OFFLINE_MODEL_PROFILES[model]
