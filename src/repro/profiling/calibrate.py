"""Calibration: turn a measured speed matrix into simulator ground truth.

Three artifacts come out of a :class:`~repro.profiling.matrix.SpeedMatrix`:

  * :class:`MeasuredInterferenceProvider` — a drop-in for
    :func:`repro.core.interference.shared_performance_arrays`: per-device
    profile arrays in, (online slowdown, offline throughput) out, but looked
    up from measured pair grids (nearest measured workload by profile
    distance, linear interpolation along the share axis) instead of the
    closed-form contention model.
  * a measured predictor training set (:func:`make_measured_dataset`) and
    per-GPU-type trained MLPs (:func:`build_measured_predictor`), so the §5
    speed predictor can train on measurements instead of on the very formula
    it is later evaluated against (the Fig. 12 circularity the seed had).
  * :class:`MeasuredMuxFlowPolicy` — MuxFlow scheduling (dynamic SM + KM
    matching) with measured shared-performance and a measured-trained
    predictor, registered as ``muxflow-measured`` and wired to the
    ``calibrated`` cluster scenario.

The default matrix is built lazily from the smoke suite (and memoized), so
``python -m repro.cluster.run --scenario calibrated`` is self-contained; set
``REPRO_SPEED_MATRIX=/path/to/matrix.json`` to calibrate from a saved
artifact (e.g. one produced on a testbed by ``python -m
repro.profiling.run --suite full``).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.interference import WorkloadProfile
from repro.profiling.matrix import SpeedMatrix

_MATCH_KEYS = ("gpu_util", "sm_activity", "mem_bw")

_DEFAULT_MATRICES: dict[tuple[str, int], SpeedMatrix] = {}


def default_matrix(suite: str = "smoke", seed: int = 0) -> SpeedMatrix:
    """The process-wide default matrix: ``$REPRO_SPEED_MATRIX`` if set,
    otherwise built from the named suite once and memoized."""
    path = os.environ.get("REPRO_SPEED_MATRIX")
    if path:
        return SpeedMatrix.load(path)
    key = (suite, seed)
    if key not in _DEFAULT_MATRICES:
        from repro.profiling.harness import build_speed_matrix
        _DEFAULT_MATRICES[key] = build_speed_matrix(suite, seed=seed)
    return _DEFAULT_MATRICES[key]


def workload_profile(matrix: SpeedMatrix, name: str) -> WorkloadProfile:
    """Reconstruct a measured workload's separate-execution profile."""
    p = matrix.workloads[name]["profile"]
    return WorkloadProfile(name=name, **p)


class MeasuredInterferenceProvider:
    """Vectorized measured shared-performance lookup.

    Call signature matches
    :func:`repro.core.interference.shared_performance_arrays` — ``on``/``off``
    are ``[key] -> (n,) array`` mappings, ``sm_off`` the per-device share —
    so any :class:`~repro.policies.base.SharingPolicy` can swap it in.  Each
    device is matched to its nearest measured online and offline workload by
    Euclidean distance over (gpu_util, sm_activity, mem_bw); the pair's
    measured slowdown/throughput grids are then linearly interpolated at the
    assigned share (clamped to the measured sweep at the ends).
    """

    def __init__(self, matrix: SpeedMatrix):
        self.matrix = matrix
        roles = {"online": [], "offline": []}
        for name, w in matrix.workloads.items():
            roles[w["role"]].append(name)
        self.online_names = sorted(roles["online"])
        self.offline_names = sorted(roles["offline"])
        if not self.online_names or not self.offline_names:
            raise ValueError("speed matrix must measure both roles")

        def feats(names):
            return np.array([[matrix.workloads[n]["profile"][k]
                              for k in _MATCH_KEYS] for n in names])

        self._on_feats = feats(self.online_names)
        self._off_feats = feats(self.offline_names)
        self._grids: dict[tuple[int, int], tuple] = {}
        for i, on in enumerate(self.online_names):
            for j, off in enumerate(self.offline_names):
                p = matrix.pair(on, off)
                self._grids[(i, j)] = (np.asarray(p["shares"], np.float64),
                                       np.asarray(p["online_slowdown"],
                                                  np.float64),
                                       np.asarray(p["offline_tput"],
                                                  np.float64))

    @staticmethod
    def _nearest(feats: np.ndarray, measured: np.ndarray) -> np.ndarray:
        # (n, 3) vs (m, 3) -> (n,) argmin over squared distance
        d2 = ((feats[:, None, :] - measured[None, :, :]) ** 2).sum(axis=2)
        return np.argmin(d2, axis=1)

    def __call__(self, on, off, sm_off) -> tuple[np.ndarray, np.ndarray]:
        sm_off = np.clip(np.asarray(sm_off, np.float64), 0.0, 1.0)
        on_f = np.stack([np.asarray(on[k], np.float64) for k in _MATCH_KEYS],
                        axis=1)
        off_f = np.stack([np.asarray(off[k], np.float64) for k in _MATCH_KEYS],
                         axis=1)
        oi = self._nearest(on_f, self._on_feats)
        oj = self._nearest(off_f, self._off_feats)
        slowdown = np.ones(sm_off.shape, np.float64)
        tput = np.zeros(sm_off.shape, np.float64)
        pair_code = oi * len(self.offline_names) + oj
        for (i, j), (grid, slow_g, tput_g) in self._grids.items():
            mask = pair_code == i * len(self.offline_names) + j
            if not mask.any():
                continue
            slowdown[mask] = np.interp(sm_off[mask], grid, slow_g)
            tput[mask] = np.interp(sm_off[mask], grid, tput_g)
        return np.maximum(slowdown, 1.0), np.clip(tput, 0.0, 1.0)

    # alias so the provider reads as a drop-in at call sites
    shared_performance_arrays = __call__


# ---------------------------------------------------------------------------
# Measured predictor training
# ---------------------------------------------------------------------------

def make_measured_dataset(matrix: SpeedMatrix, rng: np.random.Generator,
                          n: int = 2000, noise: float = 0.01,
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Predictor training pairs from the measured grids: random (pair,
    share) samples with the measured throughput (interpolated along the
    share sweep) as target.  Profile features are mildly jittered so the
    MLP sees a family around each measured workload, the way the synthetic
    ``make_dataset`` covers a family around each paper profile."""
    from repro.core.predictor import pair_features
    provider = MeasuredInterferenceProvider(matrix)
    feats, targets = [], []
    for _ in range(n):
        on_name = provider.online_names[
            rng.integers(len(provider.online_names))]
        off_name = provider.offline_names[
            rng.integers(len(provider.offline_names))]
        pair = matrix.pair(on_name, off_name)
        share = float(rng.uniform(0.05, 1.0))
        target = float(np.interp(share, pair["shares"],
                                 pair["offline_tput"]))
        on_p = workload_profile(matrix, on_name)
        off_p = workload_profile(matrix, off_name)

        def jitter(p):
            return dataclasses.replace(
                p,
                gpu_util=float(np.clip(p.gpu_util * rng.uniform(0.9, 1.1),
                                       0.0, 1.0)),
                sm_activity=float(np.clip(
                    p.sm_activity * rng.uniform(0.9, 1.1), 0.05, 1.0)),
                exec_time_ms=p.exec_time_ms * float(rng.uniform(0.9, 1.1)))

        feats.append(pair_features(jitter(on_p), jitter(off_p), share))
        targets.append(target + rng.normal(0.0, noise))
    return np.stack(feats), np.clip(np.array(targets, np.float32), 0.0, 1.0)


def build_measured_predictor(matrix: SpeedMatrix, gpu_types=("T4", "A10"),
                             n: int = 2000, epochs: int = 120, seed: int = 0):
    """Train one MLP per GPU type on the measured dataset (same
    architecture/optimizer as the synthetic path, different ground truth)."""
    import jax

    from repro.core.predictor import SpeedPredictor, train_predictor
    params_by_type = {}
    for i, t in enumerate(gpu_types):
        rng = np.random.default_rng(seed + i)
        feats, targets = make_measured_dataset(matrix, rng, n=n)
        params, _ = train_predictor(jax.random.PRNGKey(seed + i), feats,
                                    targets, epochs=epochs, seed=seed + i)
        params_by_type[t] = params
    return SpeedPredictor(params_by_type)


def predict_share_curve(predictor, gpu_type: str, online: WorkloadProfile,
                        offline: WorkloadProfile,
                        shares: np.ndarray) -> np.ndarray:
    """Predicted offline throughput across a share sweep, monotone
    non-decreasing by construction.

    More SM share can never make the offline workload slower (the measured
    grids are monotone up to sampling noise), so the calibrated prediction
    surface takes the isotonic envelope (running max) of the raw MLP outputs
    along the share axis — the property tests pin this contract."""
    from repro.core.predictor import pair_features
    shares = np.asarray(shares, np.float64)
    order = np.argsort(shares)
    feats = np.stack([pair_features(online, offline, float(s))
                      for s in shares[order]])
    raw = np.asarray(predictor.predict(gpu_type, feats), np.float64)
    iso = np.maximum.accumulate(raw)
    out = np.empty_like(iso)
    out[order] = iso
    return out


# ---------------------------------------------------------------------------
# The calibrated policy
# ---------------------------------------------------------------------------

class MeasuredMuxFlowPolicy:
    """MuxFlow scheduling with measured shared-performance.

    Same dynamic-SM + KM-matching scheduling as ``muxflow``, but the
    engine's per-tick ground truth comes from the profiled speed matrix via
    :class:`MeasuredInterferenceProvider`, and the speed predictor it
    schedules with trains on measured pairs.  With no matrix supplied the
    smoke-suite default is built lazily on first use (or loaded from
    ``$REPRO_SPEED_MATRIX``).

    (Declared as a :class:`~repro.policies.base.SharingPolicy` subclass at
    registration time — see the bottom of this module — to keep this
    module's import graph one-directional into ``repro.policies.base``.)
    """

    name = "muxflow-measured"
    description = ("MuxFlow with measured interference: speed matrix from "
                   "executed workload pairs replaces the analytic "
                   "contention model; predictor trains on measurements.")
    needs_predictor = True
    wants_scheduling = True

    def __init__(self, matrix: SpeedMatrix | None = None,
                 suite: str = "smoke"):
        self._matrix = matrix
        self._pinned = matrix is not None     # explicit matrix wins over env
        self._env_src: str | None = None
        self._suite = suite
        self._provider: MeasuredInterferenceProvider | None = None

    @property
    def matrix(self) -> SpeedMatrix:
        if self._pinned:
            return self._matrix
        # the registry holds one process-wide instance, so the memo must
        # track $REPRO_SPEED_MATRIX: setting/changing/unsetting it between
        # runs swaps the calibration source instead of being silently
        # ignored in favor of a stale matrix
        src = os.environ.get("REPRO_SPEED_MATRIX")
        if self._matrix is None or src != self._env_src:
            self._env_src = src
            self._matrix = default_matrix(self._suite)
            self._provider = None
        return self._matrix

    @property
    def provider(self) -> MeasuredInterferenceProvider:
        matrix = self.matrix            # may invalidate self._provider
        if self._provider is None:
            self._provider = MeasuredInterferenceProvider(matrix)
        return self._provider

    def scheduler_config(self, shard_size: int = 256):
        from repro.core.scheduler import SchedulerConfig
        return SchedulerConfig(use_dynamic_sm=True, use_matching=True,
                               shard_size=shard_size)

    def sm_shares(self, on, idx):
        from repro.core.dynamic_sm import dynamic_sm_array
        return dynamic_sm_array(on["sm_activity"][idx])

    def shared_performance(self, on, off, shares):
        return self.provider(on, off, shares)

    def build_predictor(self, gpu_types, *, samples: int = 2000,
                        epochs: int = 120, seed: int = 0):
        return build_measured_predictor(self.matrix, gpu_types, n=samples,
                                        epochs=epochs, seed=seed)


def register_measured_policy():
    """Idempotently register ``muxflow-measured`` (done on package import).

    The concrete registered class mixes :class:`MeasuredMuxFlowPolicy` over
    ``SharingPolicy`` here, lazily, so importing this module never imports
    the policy package back (one-directional import graph)."""
    global MeasuredMuxFlowPolicy
    from repro.policies.base import SharingPolicy, register, resolve
    if not issubclass(MeasuredMuxFlowPolicy, SharingPolicy):
        MeasuredMuxFlowPolicy = type("MeasuredMuxFlowPolicy",
                                     (MeasuredMuxFlowPolicy, SharingPolicy),
                                     {"__doc__": MeasuredMuxFlowPolicy.__doc__})
    try:
        return resolve("muxflow-measured")
    except ValueError:
        return register(MeasuredMuxFlowPolicy(),
                        aliases=("calibrated-muxflow",))
