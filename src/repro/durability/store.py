"""Write-ahead event stores: JSONL segments and sqlite, one API.

An :class:`EventStore` persists the control plane's full event stream with
enough fidelity that the bus's running SHA-256 digest can be rebuilt by
replaying the stored prefix — ``Event.key()`` round-trips exactly because
rows are serialized with shortest-repr floats (plain ``json``, *not* the
obs plane's rounding canonicalizer).

The JSONL backend appends to numbered segment files and seals a segment
every ``segment_events`` rows, recording its SHA-256 plus a chain hash
``chain_k = sha256(chain_{k-1} + sha256(segment_k))`` in ``index.json`` —
any retroactive edit to a sealed segment breaks every later chain link.
The sqlite backend stores the same rows in one table and maintains the
same logical chain over virtual segments, so either backend can verify
the other's guarantee.  ``truncate(n)`` discards a torn/stale suffix on
resume; re-running the remaining ticks re-emits that suffix
deterministically, so the final log is byte-identical to an
uninterrupted run's.
"""
from __future__ import annotations

import hashlib
import json
import os
import sqlite3

from repro.cluster.events import Event, EventKind

_GENESIS = "0" * 64


def _row_of(ev: Event) -> dict:
    return {"seq": ev.seq, "t": ev.t, "kind": ev.kind.value,
            "device": ev.device, "job": ev.job,
            "data": [[k, v] for k, v in ev.data]}


def _event_of(row: dict) -> Event:
    return Event(row["seq"], row["t"], EventKind(row["kind"]),
                 row["device"], row["job"],
                 tuple((k, tuple(v) if isinstance(v, list) else v)
                       for k, v in row["data"]))


def _dumps(row: dict) -> str:
    # shortest-repr floats (exact round-trip) — never the obs canonicalizer
    return json.dumps(row, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def _chain(prev_hex: str, seg_sha_hex: str) -> str:
    return hashlib.sha256((prev_hex + seg_sha_hex).encode()).hexdigest()


def _atomic_write(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class EventStore:
    """Append-only, truncatable, digest-reconstructable event log.

    Append/flush/fsync run under a **bounded deterministic retry ladder**
    (:meth:`_io`): a transient IO error is retried immediately — never a
    sleep, the artifact clock is sim time — up to ``max_io_retries`` times
    before it propagates.  The optional ``fault_injector`` seam (anything
    with ``store_fault(op)`` / ``note_io_recovered(op, attempts)``, see
    :class:`repro.chaos.FaultInjector`) fires *before* the real operation,
    so an injected fault never leaves a partial write behind and a retried
    append never duplicates a row.
    """

    #: chaos seam; None = the byte-identical no-chaos path
    fault_injector = None
    #: retries per IO operation before the error propagates
    max_io_retries = 3
    #: transient IO faults encountered (injected + real)
    io_faults = 0
    #: retry attempts that eventually succeeded
    io_retries = 0

    def _io(self, op: str, fn, exc=(OSError,)):
        """Run one IO operation under the bounded retry ladder."""
        inj = self.fault_injector
        attempts = 0
        while True:
            try:
                if inj is not None and inj.store_fault(op):
                    raise OSError(f"injected transient WAL {op} fault")
                out = fn()
            except exc:
                self.io_faults += 1
                if attempts >= self.max_io_retries:
                    raise
                attempts += 1
                self.io_retries += 1
                continue
            if attempts and inj is not None:
                note = getattr(inj, "note_io_recovered", None)
                if note is not None:
                    note(op, attempts)
            return out

    def append(self, ev: Event) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        raise NotImplementedError

    def abandon(self) -> None:
        """Drop the store handle WITHOUT durably closing — the chaos
        harness's in-process stand-in for SIGKILL.  JSONL: buffered bytes
        reach the file (the OS page cache would usually hold them) but
        nothing is fsynced or sealed; sqlite: the uncommitted suffix is
        rolled back and lost, the torn-write analog."""
        raise NotImplementedError

    def read(self, start: int = 0, stop: int | None = None):
        """Yield stored :class:`Event` objects for ``seq in [start, stop)``.
        Tolerates a torn final line (SIGKILL mid-write)."""
        raise NotImplementedError

    def count(self) -> int:
        raise NotImplementedError

    def truncate(self, n: int) -> None:
        """Drop every event with ``seq >= n`` (resume discards the
        post-snapshot suffix, then re-emits it by re-running ticks)."""
        raise NotImplementedError

    def chain(self) -> list[dict]:
        """Sealed-segment records: ``{file, start, n, sha256, chain}``."""
        raise NotImplementedError

    def verify(self) -> list[str]:
        """Re-hash sealed segments against the recorded chain; return
        human-readable problems (empty list == intact)."""
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    # ---------------------------------------------------------------- digest
    def replay_digest(self, n: int) -> "hashlib._Hash":
        """Rebuild the EventBus's running sha256 over events ``[0, n)`` —
        byte-exact because ``Event.key()`` round-trips through storage."""
        h = hashlib.sha256()
        for ev in self.read(0, n):
            h.update(repr(ev.key()).encode())
        return h


class JsonlEventStore(EventStore):
    """Append-only JSONL segments with a sha256 chain over sealed files."""

    INDEX = "index.json"

    def __init__(self, root: str, segment_events: int = 50_000):
        self.root = root
        self.segment_events = segment_events
        os.makedirs(root, exist_ok=True)
        self._sealed: list[dict] = []
        self._open_start = 0     # first seq of the open segment
        self._open_n = 0         # rows in the open segment
        self._n = 0              # total events
        idx_path = os.path.join(root, self.INDEX)
        if os.path.exists(idx_path):
            with open(idx_path) as f:
                idx = json.load(f)
            self._sealed = idx["segments"]
            self.segment_events = idx.get("segment_events",
                                          self.segment_events)
            self._open_start = (self._sealed[-1]["start"]
                                + self._sealed[-1]["n"]
                                if self._sealed else 0)
        # recover the open segment (which exists before any index does):
        # rewrite it from its parseable rows, dropping a torn tail from a
        # SIGKILL mid-write
        if os.path.exists(self._seg_path(self._open_start)):
            rows = list(self._read_segment(self._seg_path(self._open_start)))
            _atomic_write(self._seg_path(self._open_start),
                          "".join(_dumps(r) + "\n" for r in rows))
            self._open_n = len(rows)
        self._n = self._open_start + self._open_n
        self._f = open(self._seg_path(self._open_start), "a")

    # ------------------------------------------------------------- internals
    def _seg_path(self, start_seq: int) -> str:
        return os.path.join(self.root, f"segment-{start_seq:09d}.jsonl")

    @staticmethod
    def _read_segment(path: str):
        if not os.path.exists(path):
            return
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield json.loads(line)
                except json.JSONDecodeError:
                    return     # torn tail from a SIGKILL mid-write

    def _seal(self) -> None:
        """Close the full open segment, record its chain link, start anew."""
        self._f.close()
        path = self._seg_path(self._open_start)
        with open(path, "rb") as f:
            sha = hashlib.sha256(f.read()).hexdigest()
        prev = self._sealed[-1]["chain"] if self._sealed else _GENESIS
        self._sealed.append({
            "file": os.path.basename(path), "start": self._open_start,
            "n": self._open_n, "sha256": sha, "chain": _chain(prev, sha)})
        self._write_index()
        self._open_start = self._n
        self._open_n = 0
        self._f = open(self._seg_path(self._open_start), "a")

    def _write_index(self) -> None:
        _atomic_write(os.path.join(self.root, self.INDEX), _dumps(
            {"schema": "repro.durability.wal/v1",
             "backend": "jsonl",
             "segment_events": self.segment_events,
             "segments": self._sealed}) + "\n")

    # -------------------------------------------------------------- EventStore
    def append(self, ev: Event) -> None:
        if ev.seq != self._n:
            raise ValueError(f"WAL gap: expected seq {self._n}, got {ev.seq}")
        line = _dumps(_row_of(ev)) + "\n"
        self._io("append", lambda: self._f.write(line))
        self._n += 1
        self._open_n += 1
        if self._open_n >= self.segment_events:
            self._seal()

    def flush(self, fsync: bool = True) -> None:
        self._io("flush", self._f.flush)
        if fsync:
            self._io("fsync", lambda: os.fsync(self._f.fileno()))

    def read(self, start: int = 0, stop: int | None = None):
        self._f.flush()
        starts = [s["start"] for s in self._sealed] + [self._open_start]
        for s0 in starts:
            for row in self._read_segment(self._seg_path(s0)):
                if stop is not None and row["seq"] >= stop:
                    return
                if row["seq"] >= start:
                    yield _event_of(row)

    def count(self) -> int:
        return self._n

    def truncate(self, n: int) -> None:
        if n > self._n:
            raise ValueError(f"WAL truncate({n}) beyond {self._n} events")
        self._f.close()
        # keep fully-surviving sealed segments; everything later is folded
        # into one rewritten open segment holding rows [new_start, n)
        keep: list[dict] = []
        for seg in self._sealed:
            if seg["start"] + seg["n"] <= n:
                keep.append(seg)
            else:
                break
        new_start = keep[-1]["start"] + keep[-1]["n"] if keep else 0
        survivors: list[dict] = []
        for seg in self._sealed[len(keep):]:
            path = os.path.join(self.root, seg["file"])
            survivors.extend(r for r in self._read_segment(path)
                             if r["seq"] < n)
            os.unlink(path)
        old_open = self._seg_path(self._open_start)
        if os.path.exists(old_open):
            survivors.extend(r for r in self._read_segment(old_open)
                             if r["seq"] < n)
            os.unlink(old_open)
        survivors = sorted((r for r in survivors if r["seq"] >= new_start),
                           key=lambda r: r["seq"])
        _atomic_write(self._seg_path(new_start),
                      "".join(_dumps(r) + "\n" for r in survivors))
        self._sealed = keep
        self._open_start = new_start
        self._open_n = len(survivors)
        self._n = new_start + self._open_n
        if self._n != n:
            raise ValueError(f"WAL truncate({n}) left {self._n} events")
        self._write_index()
        self._f = open(self._seg_path(new_start), "a")

    def chain(self) -> list[dict]:
        return list(self._sealed)

    def verify(self) -> list[str]:
        problems: list[str] = []
        prev = _GENESIS
        for seg in self._sealed:
            path = os.path.join(self.root, seg["file"])
            if not os.path.exists(path):
                problems.append(f"missing sealed segment {seg['file']}")
                continue
            with open(path, "rb") as f:
                sha = hashlib.sha256(f.read()).hexdigest()
            if sha != seg["sha256"]:
                problems.append(f"segment {seg['file']} sha256 mismatch")
            if _chain(prev, seg["sha256"]) != seg["chain"]:
                problems.append(f"segment {seg['file']} chain link broken")
            prev = seg["chain"]
        return problems

    def close(self) -> None:
        self.flush()
        self._f.close()

    def abandon(self) -> None:
        self._f.flush()
        self._f.close()


class SqliteEventStore(EventStore):
    """Same API over one sqlite file; the chain covers virtual segments of
    ``segment_events`` rows so the tamper-evidence guarantee matches the
    JSONL backend's."""

    DB = "log.sqlite"

    def __init__(self, root: str, segment_events: int = 50_000):
        self.root = root
        self.segment_events = segment_events
        os.makedirs(root, exist_ok=True)
        self._db = sqlite3.connect(os.path.join(root, self.DB))
        self._db.execute(
            "CREATE TABLE IF NOT EXISTS events ("
            "seq INTEGER PRIMARY KEY, row TEXT NOT NULL)")
        self._db.execute(
            "CREATE TABLE IF NOT EXISTS chain ("
            "seg INTEGER PRIMARY KEY, start INTEGER, n INTEGER, "
            "sha256 TEXT, chain TEXT)")
        cur = self._db.execute("SELECT COALESCE(MAX(seq)+1, 0) FROM events")
        self._n = int(cur.fetchone()[0])
        cur = self._db.execute(
            "SELECT COALESCE(MAX(start+n), 0) FROM chain")
        self._sealed_upto = int(cur.fetchone()[0])

    def _seal_virtual(self) -> None:
        start = self._sealed_upto
        h = hashlib.sha256()
        for (row,) in self._db.execute(
                "SELECT row FROM events WHERE seq >= ? AND seq < ? "
                "ORDER BY seq", (start, start + self.segment_events)):
            h.update((row + "\n").encode())
        sha = h.hexdigest()
        cur = self._db.execute(
            "SELECT chain FROM chain ORDER BY seg DESC LIMIT 1")
        got = cur.fetchone()
        prev = got[0] if got else _GENESIS
        cur = self._db.execute("SELECT COALESCE(MAX(seg)+1, 0) FROM chain")
        seg = int(cur.fetchone()[0])
        self._db.execute(
            "INSERT INTO chain (seg, start, n, sha256, chain) "
            "VALUES (?, ?, ?, ?, ?)",
            (seg, start, self.segment_events, sha, _chain(prev, sha)))
        self._sealed_upto = start + self.segment_events

    _IO_ERRORS = (OSError, sqlite3.OperationalError)

    def append(self, ev: Event) -> None:
        if ev.seq != self._n:
            raise ValueError(f"WAL gap: expected seq {self._n}, got {ev.seq}")
        row = _dumps(_row_of(ev))
        self._io("append",
                 lambda: self._db.execute(
                     "INSERT INTO events (seq, row) VALUES (?, ?)",
                     (ev.seq, row)),
                 exc=self._IO_ERRORS)
        self._n += 1
        if self._n - self._sealed_upto >= self.segment_events:
            self._seal_virtual()

    def flush(self, fsync: bool = True) -> None:
        self._io("fsync", self._db.commit, exc=self._IO_ERRORS)

    def read(self, start: int = 0, stop: int | None = None):
        q = "SELECT row FROM events WHERE seq >= ?"
        params: list = [start]
        if stop is not None:
            q += " AND seq < ?"
            params.append(stop)
        for (row,) in self._db.execute(q + " ORDER BY seq", params):
            yield _event_of(json.loads(row))

    def count(self) -> int:
        return self._n

    def truncate(self, n: int) -> None:
        self._db.execute("DELETE FROM events WHERE seq >= ?", (n,))
        self._db.execute("DELETE FROM chain WHERE start + n > ?", (n,))
        self._db.commit()
        cur = self._db.execute("SELECT COALESCE(MAX(seq)+1, 0) FROM events")
        self._n = int(cur.fetchone()[0])
        cur = self._db.execute("SELECT COALESCE(MAX(start+n), 0) FROM chain")
        self._sealed_upto = int(cur.fetchone()[0])

    def chain(self) -> list[dict]:
        return [{"file": self.DB, "start": int(s), "n": int(nn),
                 "sha256": sha, "chain": ch}
                for s, nn, sha, ch in self._db.execute(
                    "SELECT start, n, sha256, chain FROM chain "
                    "ORDER BY seg")]

    def verify(self) -> list[str]:
        problems: list[str] = []
        prev = _GENESIS
        for seg in self.chain():
            h = hashlib.sha256()
            for (row,) in self._db.execute(
                    "SELECT row FROM events WHERE seq >= ? AND seq < ? "
                    "ORDER BY seq", (seg["start"], seg["start"] + seg["n"])):
                h.update((row + "\n").encode())
            if h.hexdigest() != seg["sha256"]:
                problems.append(
                    f"virtual segment @{seg['start']} sha256 mismatch")
            if _chain(prev, seg["sha256"]) != seg["chain"]:
                problems.append(
                    f"virtual segment @{seg['start']} chain link broken")
            prev = seg["chain"]
        return problems

    def close(self) -> None:
        self._db.commit()
        self._db.close()

    def abandon(self) -> None:
        self._db.rollback()
        self._db.close()


BACKENDS = ("jsonl", "sqlite")


def open_store(root: str, backend: str = "jsonl",
               segment_events: int = 50_000) -> EventStore:
    if backend == "jsonl":
        return JsonlEventStore(root, segment_events=segment_events)
    if backend == "sqlite":
        return SqliteEventStore(root, segment_events=segment_events)
    raise ValueError(f"unknown event-store backend {backend!r} "
                     f"(expected one of {BACKENDS})")
