"""The durable run loop: WAL sink + periodic snapshots + ``--resume``.

A durable run lives in one directory (see the package docstring for the
layout).  Creation writes ``run.json`` (human-readable provenance: scenario
name, seed, engine, artifact paths, cadence) and ``scenario.pkl`` (the
fully-resolved Scenario — resume's one construction input), signs a first
manifest, then drives ``ControlPlane.run`` with a tick callback that every
``snapshot_every_s`` of sim time flushes+fsyncs the WAL, pickles a
state snapshot atomically, prunes old snapshots, and re-signs the manifest.

Resume verifies the manifest signature and the sha256 of everything it is
about to unpickle, picks the newest verifiable snapshot, rebuilds a fresh
ControlPlane from the recorded Scenario (static structure is deterministic
re-init), overwrites its mutable state from the snapshot, truncates the WAL
to the snapshot's event count, and re-runs the remaining ticks — the engine
re-emits the discarded suffix deterministically, so the final report, event
log, and obs artifacts are byte-identical to an uninterrupted run's.  A
crash before the first snapshot resumes from tick 0 the same way.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import pickle

from repro.durability.manifest import (build_manifest, file_sha256,
                                       verify_manifest, write_manifest)
from repro.durability.snapshot import capture_control, restore_control
from repro.durability.store import open_store

RUN_SCHEMA = "repro.durability.run/v1"
DEFAULT_SNAPSHOT_EVERY_S = 1800.0


def _atomic_json(path: str, doc: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _spill_obs(obs, rundir: str):
    """A prom-only (or alerts-only) ObsConfig runs its metrics recorder on
    a digest-only (fileless) writer — which cannot be re-opened mid-stream
    on resume.  Durable runs therefore spill the metrics JSONL into the run
    directory; the stream digest (and hence the report) is unchanged."""
    if (obs is not None and (obs.prom_out or obs.alerts_out)
            and not obs.metrics_out):
        return dataclasses.replace(
            obs, metrics_out=os.path.join(rundir, "obs-metrics-spill.jsonl"))
    return obs


def _obs_dict(obs) -> dict | None:
    return None if obs is None else dataclasses.asdict(obs)


def _obs_from_dict(d: dict | None):
    if d is None:
        return None
    from repro.obs import ObsConfig
    if d.get("alert_rules") is not None:
        d = dict(d, alert_rules=tuple(d["alert_rules"]))
    return ObsConfig(**d)


def _run_meta(sc, run_json_path: str) -> dict:
    sha, _ = file_sha256(run_json_path)
    return {"scenario": sc.name, "seed": sc.seed,
            "n_devices": sc.n_devices, "engine": sc.engine,
            "horizon_s": sc.horizon_seconds(), "tick_s": sc.tick_s,
            "config_sha256": sha}


class DurableRun:
    """One durable run (fresh or resumed) bound to its directory."""

    def __init__(self, rundir: str, scenario, obs, meta: dict, store):
        self.rundir = os.path.abspath(rundir)
        self.scenario = scenario
        self.obs = obs
        self.meta = meta
        self.store = store
        self.cp = None
        self.report: dict | None = None
        self.keep_snapshots = int(meta["keep_snapshots"])
        self.snapshot_every_s = float(meta["snapshot_every_s"])
        self.out = meta.get("out")
        self.snapshots_taken = 0
        self.resumed_from_tick: int | None = None
        # resume ladder: (rel_path, reason) for every snapshot that was
        # skipped as corrupt/unreadable on the way to the next good one
        self.snapshot_skips: list[tuple[str, str]] = []

    # ------------------------------------------------------------ creation
    @classmethod
    def create(cls, scenario, rundir: str, *, obs=None, out: str | None = None,
               snapshot_every_s: float = DEFAULT_SNAPSHOT_EVERY_S,
               backend: str = "jsonl", keep_snapshots: int = 3,
               segment_events: int = 50_000) -> "DurableRun":
        rundir = os.path.abspath(rundir)
        os.makedirs(rundir, exist_ok=True)
        os.makedirs(os.path.join(rundir, "snapshots"), exist_ok=True)
        obs = _spill_obs(obs, rundir)
        meta = {"schema": RUN_SCHEMA, "scenario": scenario.name,
                "seed": scenario.seed, "n_devices": scenario.n_devices,
                "engine": scenario.engine, "tick_s": scenario.tick_s,
                "horizon_s": scenario.horizon_seconds(),
                "snapshot_every_s": float(snapshot_every_s),
                "backend": backend, "keep_snapshots": int(keep_snapshots),
                "segment_events": int(segment_events),
                "out": out, "obs": _obs_dict(obs)}
        _atomic_json(os.path.join(rundir, "run.json"), meta)
        with open(os.path.join(rundir, "scenario.pkl"), "wb") as f:
            pickle.dump(scenario, f)
        store = open_store(os.path.join(rundir, "events"), backend,
                           segment_events=segment_events)
        run = cls(rundir, scenario, obs, meta, store)
        run._write_manifest(final=False)     # present before any snapshot
        return run

    # -------------------------------------------------------------- resume
    @classmethod
    def open(cls, rundir: str) -> "DurableRun":
        """Open an existing run directory for resume.  Verifies the
        manifest signature and the hash of every pickle before loading."""
        rundir = os.path.abspath(rundir)
        run_json = os.path.join(rundir, "run.json")
        if not os.path.exists(run_json):
            raise FileNotFoundError(f"no run.json in {rundir} — not a "
                                    "durable run directory")
        with open(run_json) as f:
            meta = json.load(f)
        if meta.get("schema") != RUN_SCHEMA:
            raise ValueError(f"unexpected run.json schema "
                             f"{meta.get('schema')!r}")
        manifest_path = os.path.join(rundir, "manifest.json")
        problems = verify_manifest(manifest_path, check_files=False)
        if problems:
            raise ValueError("manifest verification failed: "
                             + "; ".join(problems))
        with open(manifest_path) as f:
            manifest = json.load(f)
        cls._check_listed(manifest, rundir, "scenario.pkl")
        with open(os.path.join(rundir, "scenario.pkl"), "rb") as f:
            scenario = pickle.load(f)
        obs = _obs_from_dict(meta.get("obs"))
        store = open_store(os.path.join(rundir, "events"),
                           meta.get("backend", "jsonl"),
                           segment_events=meta.get("segment_events", 50_000))
        run = cls(rundir, scenario, obs, meta, store)
        run._manifest = manifest
        return run

    @staticmethod
    def _check_listed(manifest: dict, rundir: str, rel: str) -> None:
        entry = manifest.get("artifacts", {}).get(rel)
        if entry is None:
            raise ValueError(f"{rel} not listed in the manifest")
        sha, size = file_sha256(os.path.join(rundir, rel))
        if sha != entry["sha256"] or size != entry["bytes"]:
            raise ValueError(f"{rel} does not match its manifest hash")

    def _pick_snapshot(self) -> tuple[str, dict] | None:
        """Newest snapshot that exists, matches its manifest hash, and
        actually unpickles — **skip-to-next-good**: a snapshot written
        after the last manifest refresh (crash inside the snapshot step),
        hash-mismatched, or corrupt-but-hash-consistent (bad bytes made it
        to disk before signing) is recorded in ``snapshot_skips`` and the
        search continues with the previous one, which is still a valid
        resume point (resume just re-runs more ticks)."""
        listed = getattr(self, "_manifest", {}).get("artifacts", {})
        paths = sorted(glob.glob(
            os.path.join(self.rundir, "snapshots", "snap-*.pkl")),
            reverse=True)
        for path in paths:
            rel = os.path.relpath(path, self.rundir)
            entry = listed.get(rel)
            if entry is None:
                continue      # newer than the manifest — normal, not logged
            sha, size = file_sha256(path)
            if sha != entry["sha256"] or size != entry["bytes"]:
                self.snapshot_skips.append((rel, "manifest hash mismatch"))
                continue
            try:
                with open(path, "rb") as f:
                    snap = pickle.load(f)
            except Exception as exc:    # any unpickling failure mode
                self.snapshot_skips.append((rel, f"unreadable: {exc}"))
                continue
            if not isinstance(snap, dict) or "tick_i" not in snap:
                self.snapshot_skips.append((rel, "not a snapshot payload"))
                continue
            return path, snap
        return None

    # ----------------------------------------------------------- run loops
    def _n_ticks(self) -> int:
        sc = self.scenario
        return int(sc.horizon_seconds() / sc.tick_s)

    def _every_ticks(self) -> int:
        return max(1, int(round(self.snapshot_every_s / self.scenario.tick_s)))

    def _tick_callback(self):
        every, n_ticks = self._every_ticks(), self._n_ticks()

        def cb(ticks_done: int, t: float) -> None:
            if ticks_done % every == 0 and ticks_done < n_ticks:
                self._snapshot(ticks_done, t)
        return cb

    def execute(self, predictor=None, *, at_tick: int | None = None) -> dict:
        """Run to completion — fresh if no usable snapshot exists, resumed
        otherwise (``at_tick`` pins a specific snapshot, for benchmarks).
        Returns the deterministic campaign report."""
        from repro.cluster.control import ControlPlane
        picked = None
        if at_tick is not None:
            path = os.path.join(self.rundir, "snapshots",
                                f"snap-{at_tick:07d}.pkl")
            self._check_listed(getattr(self, "_manifest", {"artifacts": {}}),
                               self.rundir,
                               os.path.relpath(path, self.rundir))
            with open(path, "rb") as f:
                picked = (path, pickle.load(f))
        elif hasattr(self, "_manifest"):
            picked = self._pick_snapshot()
        if picked is None:
            # fresh start (or crash before the first snapshot): discard any
            # WAL prefix and run from tick 0
            self.store.truncate(0)
            self.cp = ControlPlane(self.scenario, predictor=predictor,
                                   obs=self.obs)
            self.store.fault_injector = getattr(self.cp, "chaos", None)
            self.cp.bus.attach_sink(self.store.append)
            self.cp.run(tick_callback=self._tick_callback())
        else:
            _path, snap = picked
            self.resumed_from_tick = snap["tick_i"]
            prefixes = self._read_obs_prefixes(snap)
            self.cp = ControlPlane(self.scenario, predictor=predictor,
                                   obs=self.obs)
            self.store.fault_injector = getattr(self.cp, "chaos", None)
            restore_control(self.cp, snap, store=self.store,
                            obs_prefixes=prefixes)
            self.store.truncate(snap["bus"]["n_events"])
            self.cp.bus.attach_sink(self.store.append)
            self.cp.run(start_tick=snap["tick_i"], start_t=snap["t"],
                        tick_callback=self._tick_callback())
        self.store.flush()
        self.report = self.cp.report()
        return self.report

    def _read_obs_prefixes(self, snap: dict) -> dict:
        """Surviving obs file prefixes, read BEFORE ControlPlane
        construction truncates the output files."""
        prefixes: dict[str, bytes] = {}
        obs_snap = snap.get("obs")
        if not obs_snap or self.obs is None:
            return prefixes
        for key, path in (("metrics", self.obs.metrics_out),
                          ("trace", self.obs.trace_out),
                          ("alerts", self.obs.alerts_out)):
            part = obs_snap.get(key)
            if part is None:
                continue
            offset = part["writer"]["offset"]
            if offset is None or path is None:
                raise ValueError(
                    f"snapshot has a fileless obs {key} writer — durable "
                    "runs require file-backed obs outputs")
            with open(path, "rb") as f:
                data = f.read(offset)
            if len(data) != offset:
                raise ValueError(
                    f"obs {key} file {path} shorter ({len(data)}B) than "
                    f"its snapshot offset ({offset}B)")
            prefixes[key] = data
        return prefixes

    # ------------------------------------------------------------ snapshot
    def _snapshot(self, tick_i: int, t: float) -> None:
        self.store.flush(fsync=True)
        snap = capture_control(self.cp, t, tick_i)
        path = os.path.join(self.rundir, "snapshots",
                            f"snap-{tick_i:07d}.pkl")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(snap, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self.snapshots_taken += 1
        self._prune_snapshots()
        self._write_manifest(final=False)

    def _prune_snapshots(self) -> None:
        paths = sorted(glob.glob(
            os.path.join(self.rundir, "snapshots", "snap-*.pkl")))
        for path in paths[:-self.keep_snapshots]:
            os.unlink(path)

    # ------------------------------------------------------------ manifest
    def _artifacts(self, final: bool) -> list[str]:
        arts = [os.path.join(self.rundir, "run.json"),
                os.path.join(self.rundir, "scenario.pkl")]
        arts += sorted(glob.glob(
            os.path.join(self.rundir, "snapshots", "snap-*.pkl")))
        if final:
            arts += sorted(glob.glob(
                os.path.join(self.rundir, "events", "*")))
            if self.out:
                arts.append(self.out)
            if self.obs is not None:
                arts += [p for p in (self.obs.metrics_out,
                                     self.obs.trace_out, self.obs.prom_out,
                                     self.obs.alerts_out)
                         if p]
        return arts

    def _write_manifest(self, final: bool) -> None:
        meta = _run_meta(self.scenario, os.path.join(self.rundir, "run.json"))
        meta["final"] = bool(final)
        manifest = build_manifest(self.rundir, self._artifacts(final), meta)
        write_manifest(os.path.join(self.rundir, "manifest.json"), manifest)
        self._manifest = manifest

    def finalize_manifest(self) -> None:
        """Seal the run: close the WAL and sign the complete artifact set
        (event segments, report, obs outputs).  Call after the report file
        has been written."""
        self.store.close()
        self._write_manifest(final=True)


def run_durable(scenario, rundir: str, *, obs=None, out: str | None = None,
                snapshot_every_s: float = DEFAULT_SNAPSHOT_EVERY_S,
                backend: str = "jsonl", keep_snapshots: int = 3,
                predictor=None) -> DurableRun:
    """Fresh durable run; returns the :class:`DurableRun` with its
    ``report`` populated (call ``finalize_manifest()`` once the report
    file is written)."""
    run = DurableRun.create(scenario, rundir, obs=obs, out=out,
                            snapshot_every_s=snapshot_every_s,
                            backend=backend, keep_snapshots=keep_snapshots)
    run.execute(predictor=predictor)
    return run


def resume_run(rundir: str, *, at_tick: int | None = None,
               predictor=None) -> DurableRun:
    """Resume (or restart, if no snapshot survived) a durable run."""
    run = DurableRun.open(rundir)
    run.execute(predictor=predictor, at_tick=at_tick)
    return run


def verify_rundir(manifest_path: str) -> list[str]:
    """The ``--verify-manifest`` CLI: manifest signature + artifact hashes,
    plus the WAL's per-segment chain when the directory holds one."""
    problems = verify_manifest(manifest_path)
    rundir = os.path.dirname(os.path.abspath(manifest_path))
    events = os.path.join(rundir, "events")
    if os.path.isdir(events):
        try:
            with open(os.path.join(rundir, "run.json")) as f:
                backend = json.load(f).get("backend", "jsonl")
        except OSError:
            backend = "jsonl"
        store = open_store(events, backend)
        try:
            problems += store.verify()
        finally:
            store.close()
    return problems
