"""Signed run manifests: artifact sha256s + config provenance, HMAC-sealed.

A durable run refreshes ``manifest.json`` at every snapshot and again at
finalize, so the manifest is always present for ``--resume`` to verify
*before* unpickling any snapshot — pickles are only loaded after their
recorded sha256 matches the file bytes and the manifest's HMAC-SHA256
signature verifies.  The signing key comes from ``REPRO_MANIFEST_KEY``;
without it a documented development key is used (tamper-*evidence* for CI
and local runs, not secrecy — anyone holding the key can re-sign).

The manifest body contains no wall-clock timestamps, so for a fixed
scenario/seed the finalized manifest is byte-identical across runs — the
same discipline every other deterministic artifact in this repo follows.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os

MANIFEST_SCHEMA = "repro.durability.manifest/v1"
DEV_KEY = "repro-dev-manifest-key"      # documented fallback, not a secret
KEY_ENV = "REPRO_MANIFEST_KEY"


def _key() -> bytes:
    return os.environ.get(KEY_ENV, DEV_KEY).encode()


def _canonical(body: dict) -> bytes:
    return json.dumps(body, sort_keys=True, separators=(",", ":"),
                      allow_nan=False).encode()


def file_sha256(path: str) -> tuple[str, int]:
    h = hashlib.sha256()
    n = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
            n += len(chunk)
    return h.hexdigest(), n


def sign_manifest(body: dict) -> str:
    body = {k: v for k, v in body.items() if k != "signature"}
    return hmac.new(_key(), _canonical(body), hashlib.sha256).hexdigest()


def build_manifest(rundir: str, artifacts: list[str], run_meta: dict) -> dict:
    """List every artifact (paths inside ``rundir`` become relative) with
    its sha256 + byte length, attach provenance, and sign."""
    rundir = os.path.abspath(rundir)
    entries: dict[str, dict] = {}
    for path in sorted(set(artifacts)):
        apath = os.path.abspath(path)
        if not os.path.exists(apath):
            continue
        rel = (os.path.relpath(apath, rundir)
               if apath.startswith(rundir + os.sep) else apath)
        sha, size = file_sha256(apath)
        entries[rel] = {"sha256": sha, "bytes": size}
    body = {"schema": MANIFEST_SCHEMA, "run": run_meta,
            "artifacts": entries}
    return {**body, "signature": sign_manifest(body)}


def write_manifest(path: str, manifest: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def verify_manifest(path: str, check_files: bool = True) -> list[str]:
    """Return human-readable problems (empty list == signature and every
    recorded artifact hash verify)."""
    problems: list[str] = []
    try:
        with open(path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable manifest {path}: {e}"]
    sig = manifest.pop("signature", None)
    if sig is None:
        return [f"manifest {path} has no signature"]
    want = sign_manifest(manifest)
    if not hmac.compare_digest(sig, want):
        problems.append("HMAC signature mismatch (wrong key or tampered "
                        "manifest)")
    if not check_files:
        return problems
    rundir = os.path.dirname(os.path.abspath(path))
    for rel, entry in manifest.get("artifacts", {}).items():
        apath = rel if os.path.isabs(rel) else os.path.join(rundir, rel)
        if not os.path.exists(apath):
            problems.append(f"artifact missing: {rel}")
            continue
        sha, size = file_sha256(apath)
        if sha != entry["sha256"]:
            problems.append(f"artifact sha256 mismatch: {rel}")
        elif size != entry["bytes"]:
            problems.append(f"artifact length mismatch: {rel}")
    return problems
