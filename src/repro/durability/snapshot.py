"""Capture/restore of the control plane's full mutable state.

A snapshot is taken at a tick boundary and records *only mutable state*:
everything static — fleet layout, QPS curves, feasibility masks, trained
predictor weights, trace schedules — is a deterministic function of the
Scenario and is rebuilt by constructing a fresh :class:`ControlPlane` on
resume.  That keeps snapshots small and sidesteps everything unpicklable
(jax predictor params, ``sim.tick_qps`` closures inside serving lanes,
open file handles).

Three things cannot be pickled at all and are *reconstructed* instead:

* the EventBus's running SHA-256 — replayed from the WAL prefix
  ``[0, n_events)`` (``Event.key()`` round-trips storage exactly);
* each obs ``JsonlWriter``'s running SHA-256 — the snapshot records the
  flushed byte offset, resume truncates the surviving partial file to it
  and re-hashes those bytes;
* numpy ``Generator`` streams — captured as ``bit_generator.state`` dicts.

Wall-clock-only state (``sim.schedule_latencies``, the phase profiler) is
deliberately dropped: it is quarantined from every deterministic artifact,
so resetting it cannot move report bytes.
"""
from __future__ import annotations

import collections
import copy

import numpy as np

SNAPSHOT_SCHEMA = "repro.durability.snapshot/v1"


def _copy_arrays(d: dict) -> dict:
    return {k: (np.copy(v) if isinstance(v, np.ndarray) else v)
            for k, v in d.items()}


# --------------------------------------------------------------------- sim
def capture_sim(sim) -> dict:
    """Mutable state of a :class:`~repro.core.simulator.ClusterSim`."""
    s = sim.state
    snap = {
        "rng": sim.rng.bit_generator.state,
        "state": {k: np.copy(getattr(s, k)) for k in vars(s)},
        "monitor": {k: np.copy(getattr(sim.monitor, k))
                    for k in ("state", "_init_at", "_readmit_at",
                              "_ol_times", "_ol_ptr")},
        "job_spec": list(sim.job_spec),
        "pending": list(sim.pending),
        "finished": list(sim.finished),
        "evictions": sim.evictions,
        "executions": sim.executions,
        "errors_injected": sim.errors_injected,
        "online_incidents": sim.online_incidents,
        "_n_injected": sim._n_injected,
        "_lat_sum": sim._lat_sum,
        "_lat_wsum": sim._lat_wsum,
        "_base_lat_sum": sim._base_lat_sum,
        "_lat_hist": np.copy(sim._lat_hist),
        "_util_acc": np.copy(sim._util_acc),
        "_util_ticks": sim._util_ticks,
        "_tput_sum": sim._tput_sum,
        "_tput_ticks": sim._tput_ticks,
        "_timeline": {k: list(v) for k, v in sim._timeline.items()},
        "_job_i": sim._job_i,
        "_next_sched": sim._next_sched,
        "_ext_mask": (np.copy(sim._ext_mask)
                      if sim._ext_mask is not None else None),
        "err_handled": list(sim.err_handler.handled),
    }
    pred = sim.predictor
    if hasattr(pred, "_cache"):     # CachedSpeedPredictor wrapper
        snap["predictor"] = {
            "cache": collections.OrderedDict(pred._cache),
            "hits": pred.hits, "misses": pred.misses,
            "evictions": pred.evictions}
    if sim._matcher is not None:
        m = sim._matcher
        snap["matcher"] = {
            "cache": dict(m._cache), "n_shards": m._n_shards,
            "rounds": m.rounds, "shards_solved": m.shards_solved,
            "shards_reused": m.shards_reused,
            "full_solves": m.full_solves}
    return snap


def restore_sim(sim, snap: dict) -> None:
    """Overwrite a freshly-constructed sim's mutable state in place.  Pure
    caches (``_qps_memo``, the offline gather cache, the lazily-built xla
    engine) are reset, not restored — they rebuild deterministically."""
    sim.rng.bit_generator.state = snap["rng"]
    for k, v in snap["state"].items():
        setattr(sim.state, k, np.copy(v))
    for k, v in snap["monitor"].items():
        setattr(sim.monitor, k, np.copy(v))
    sim.job_spec = list(snap["job_spec"])
    sim.pending = list(snap["pending"])
    sim.finished = list(snap["finished"])
    sim.evictions = snap["evictions"]
    sim.executions = snap["executions"]
    sim.errors_injected = snap["errors_injected"]
    sim.online_incidents = snap["online_incidents"]
    sim._n_injected = snap["_n_injected"]
    sim._lat_sum = snap["_lat_sum"]
    sim._lat_wsum = snap["_lat_wsum"]
    sim._base_lat_sum = snap["_base_lat_sum"]
    sim._lat_hist = np.copy(snap["_lat_hist"])
    sim._util_acc = np.copy(snap["_util_acc"])
    sim._util_ticks = snap["_util_ticks"]
    sim._tput_sum = snap["_tput_sum"]
    sim._tput_ticks = snap["_tput_ticks"]
    sim._timeline = {k: list(v) for k, v in snap["_timeline"].items()}
    sim._job_i = snap["_job_i"]
    sim._next_sched = snap["_next_sched"]
    sim._ext_mask = (np.copy(snap["_ext_mask"])
                     if snap["_ext_mask"] is not None else None)
    sim.err_handler.handled = list(snap["err_handled"])
    sim.schedule_latencies = []          # wall-clock-only; quarantined
    sim._qps_memo = None
    sim._off_cache = {}
    sim._off_cache_ver = -1
    sim._xla = None
    if "predictor" in snap:
        p = snap["predictor"]
        sim.predictor._cache = collections.OrderedDict(p["cache"])
        sim.predictor.hits = p["hits"]
        sim.predictor.misses = p["misses"]
        sim.predictor.evictions = p["evictions"]
    if "matcher" in snap and sim._matcher is not None:
        m = snap["matcher"]
        sim._matcher._cache = dict(m["cache"])
        sim._matcher._n_shards = m["n_shards"]
        sim._matcher.rounds = m["rounds"]
        sim._matcher.shards_solved = m["shards_solved"]
        sim._matcher.shards_reused = m["shards_reused"]
        sim._matcher.full_solves = m["full_solves"]


# ----------------------------------------------------------------- serving
def _capture_serving(plane) -> list[dict]:
    lanes = []
    for lane in plane.lanes:
        lanes.append({
            "service": lane.service,
            "queue": [list(c) for c in lane.queue],
            "hist": np.copy(lane.hist),
            "arrived": lane.arrived, "served": lane.served,
            "shed": lane.shed, "within_slo": lane.within_slo,
            "win_hist": np.copy(lane.win_hist),
            "win_arrived": lane.win_arrived, "win_served": lane.win_served,
            "win_shed": lane.win_shed, "win_within": lane.win_within,
            "lat_sum_ms": lane.lat_sum_ms, "max_ms": lane.max_ms,
            "peak_queue": lane.peak_queue, "cap_sum": lane.cap_sum,
            "ticks": lane.ticks, "batch_seq": lane._batch_seq,
            "brownout_shed": lane.brownout_shed,
            "size_rng": lane.size_rng.bit_generator.state,
            "stream": (lane.process._stream.bit_generator.state
                       if lane.process._stream is not None else None),
        })
    return lanes


def _restore_serving(plane, lanes: list[dict]) -> None:
    from collections import deque
    by_svc = {row["service"]: row for row in lanes}
    if set(by_svc) != {ln.service for ln in plane.lanes}:
        raise ValueError("snapshot serving lanes do not match scenario")
    for lane in plane.lanes:
        row = by_svc[lane.service]
        lane.queue = deque([list(c) for c in row["queue"]])
        lane.hist = np.copy(row["hist"])
        lane.arrived = row["arrived"]
        lane.served = row["served"]
        lane.shed = row["shed"]
        lane.within_slo = row["within_slo"]
        lane.win_hist = np.copy(row["win_hist"])
        lane.win_arrived = row["win_arrived"]
        lane.win_served = row["win_served"]
        lane.win_shed = row["win_shed"]
        lane.win_within = row["win_within"]
        lane.lat_sum_ms = row["lat_sum_ms"]
        lane.max_ms = row["max_ms"]
        lane.peak_queue = row["peak_queue"]
        lane.cap_sum = row["cap_sum"]
        lane.ticks = row["ticks"]
        lane._batch_seq = row["batch_seq"]
        lane.brownout_shed = row.get("brownout_shed", 0)
        lane.size_rng.bit_generator.state = row["size_rng"]
        if row["stream"] is not None:
            lane.process._stream.bit_generator.state = row["stream"]


# --------------------------------------------------------------------- obs
def _capture_registry(registry) -> dict:
    fams = {}
    for name, fam in registry._families.items():
        children = {}
        for key, child in fam._children.items():
            if fam.kind == "histogram":
                children[key] = ("h", list(child.bucket_counts),
                                 child.sum, child.count)
            else:
                children[key] = (fam.kind[0], child.value)
        fams[name] = children
    return fams


def _restore_registry(registry, fams: dict) -> None:
    from repro.obs.metrics import _Counter, _Gauge, _Histogram
    for name, children in fams.items():
        fam = registry._families[name]
        fam._children.clear()
        for key, payload in children.items():
            if payload[0] == "h":
                child = _Histogram(fam.buckets)
                child.bucket_counts = list(payload[1])
                child.sum = payload[2]
                child.count = payload[3]
            elif payload[0] == "c":
                child = _Counter()
                child.value = payload[1]
            else:
                child = _Gauge()
                child.value = payload[1]
            fam._children[key] = child


def _capture_writer(writer) -> dict:
    """Flush, then record the file's durable byte offset + row count; the
    running sha256 is rebuilt from those bytes on resume."""
    import os
    writer._flush()
    offset = None
    if writer._f is not None:
        writer._f.flush()
        offset = os.fstat(writer._f.fileno()).st_size
    return {"rows": writer.rows, "offset": offset}


def restore_writer(writer, rows: int, prefix: bytes) -> None:
    """Reset a freshly-constructed writer to a mid-stream position: the
    surviving file prefix becomes the file content, the running sha256 is
    re-derived from it, and the fresh constructor's buffered header (the
    same bytes, already inside ``prefix``) is discarded."""
    import hashlib
    writer._buf.clear()
    writer.rows = rows
    writer._hash = hashlib.sha256(prefix)
    if writer._f is not None:
        writer._f.seek(0)
        writer._f.truncate()
        writer._f.write(prefix.decode("utf-8"))
        writer._f.flush()


def _capture_obs(obs) -> dict:
    snap: dict = {"metrics": None, "trace": None, "alerts": None}
    if obs.metrics is not None:
        rec = obs.metrics
        snap["metrics"] = {
            "writer": _capture_writer(rec.writer),
            "dev_acc": np.copy(rec._dev_acc),
            "prev_healthy": np.copy(rec._prev_healthy),
            "tick_i": rec._tick_i, "win_ticks": rec._win_ticks,
            "windows": rec.windows,
            "prev_totals": dict(rec._prev_totals),
            "registry": _capture_registry(rec.registry)}
    if getattr(obs, "alerts", None) is not None:
        eng = obs.alerts
        snap["alerts"] = {
            "writer": _capture_writer(eng.writer),
            "windows": eng.windows,
            "breach_windows": eng.breach_windows,
            "transitions": eng.transitions,
            "next_id": eng._next_id,
            "incidents": [dict(vars(i)) for i in eng.incidents],
            "states": {key: {"state": st.state, "breaches": st.breaches,
                             "clears": st.clears, "peak": st.peak,
                             "ring": list(st.ring),
                             "incident": (st.incident.id
                                          if st.incident is not None
                                          else None)}
                       for key, st in eng._states.items()}}
    if obs.trace is not None:
        bt = obs._bus_tracer
        snap["trace"] = {
            "writer": _capture_writer(obs.trace.writer),
            "kinds": dict(obs.trace.kinds),
            "submit": dict(bt._submit),
            "open": {j: dict(v) for j, v in bt._open.items()},
            "segments": dict(bt._segments)}
    return snap


def _restore_obs(obs, snap: dict, prefixes: dict) -> None:
    """``prefixes`` maps ``"metrics"``/``"trace"`` to the surviving file
    prefix bytes (read *before* fresh construction truncated the files)."""
    if snap["metrics"] is not None:
        rec = obs.metrics
        m = snap["metrics"]
        restore_writer(rec.writer, m["writer"]["rows"],
                       prefixes.get("metrics", b""))
        rec._dev_acc = np.copy(m["dev_acc"])
        rec._prev_healthy = np.copy(m["prev_healthy"])
        rec._tick_i = m["tick_i"]
        rec._win_ticks = m["win_ticks"]
        rec.windows = m["windows"]
        rec._prev_totals = dict(m["prev_totals"])
        _restore_registry(rec.registry, m["registry"])
    if snap.get("alerts") is not None and obs.alerts is not None:
        from repro.obs.alerts import Incident, _RuleState
        al = snap["alerts"]
        eng = obs.alerts
        restore_writer(eng.writer, al["writer"]["rows"],
                       prefixes.get("alerts", b""))
        eng.windows = al["windows"]
        eng.breach_windows = al["breach_windows"]
        eng.transitions = al["transitions"]
        eng._next_id = al["next_id"]
        eng.incidents = [Incident(**row) for row in al["incidents"]]
        by_id = {i.id: i for i in eng.incidents}
        eng._states = {}
        for key, row in al["states"].items():
            st = _RuleState()
            st.state = row["state"]
            st.breaches = row["breaches"]
            st.clears = row["clears"]
            st.peak = row["peak"]
            st.ring = list(row["ring"])
            st.incident = (by_id[row["incident"]]
                           if row["incident"] is not None else None)
            eng._states[key] = st
    if snap["trace"] is not None:
        tr = snap["trace"]
        restore_writer(obs.trace.writer, tr["writer"]["rows"],
                       prefixes.get("trace", b""))
        obs.trace.kinds = dict(tr["kinds"])
        bt = obs._bus_tracer
        bt._submit = dict(tr["submit"])
        bt._open = {j: dict(v) for j, v in tr["open"].items()}
        bt._segments = dict(tr["segments"])


# ----------------------------------------------------------- control plane
def capture_control(cp, t: float, tick_i: int) -> dict:
    """Snapshot a mid-run :class:`~repro.cluster.control.ControlPlane` at a
    tick boundary (after tick ``tick_i`` completed, sim clock at ``t``)."""
    bus = cp.bus
    snap = {
        "schema": SNAPSHOT_SCHEMA,
        "t": t,
        "tick_i": tick_i,
        "bus": {"n_events": bus.n_events,
                "counts": dict(bus.counts),
                "sink_events": bus.sink_events},
        "sim": capture_sim(cp.sim),
        "trace_i": cp._trace_i,
        "last_telemetry": _copy_arrays(cp.last_telemetry),
        "autoscale_decisions": [dict(d) for d in cp.autoscale_decisions],
        "scalers": {svc: {"replicas": s.replicas,
                          "last_scale_at": s._last_scale_at,
                          "below_since": s._below_since}
                    for svc, s in cp.scalers.items()},
        "campaign": None, "agents": None, "jobs": None,
        "serving": None, "obs": None, "chaos": None,
    }
    if cp.campaign is not None:
        c = cp.campaign
        snap["campaign"] = {"rng": c.rng.bit_generator.state,
                            "injected": dict(c.injected_by_kind),
                            "propagated": dict(c.propagated_by_kind)}
    if cp.agents is not None:
        a = cp.agents
        snap["agents"] = {
            "rng": a.rng.bit_generator.state,
            "last_report": np.copy(a.last_report),
            "stale": np.copy(a.stale),
            "stale_episodes": a.stale_episodes,
            "stale_device_ticks": a.stale_device_ticks,
            "reports_sent": a.reports_sent,
            "reports_dropped": a.reports_dropped,
            "next_beat": a._next_beat,
            "seen": _copy_arrays(a.seen),
            "seen_state": np.copy(a.seen_state)}
    if cp.job_manager is not None:
        jm = cp.job_manager
        # JobRecords are mutable — copy so post-snapshot ticks can't bleed in
        snap["jobs"] = {"jobs": {j: copy.copy(r)
                                 for j, r in jm.jobs.items()},
                        "violations": list(jm.violations)}
    if cp.serving is not None:
        snap["serving"] = _capture_serving(cp.serving)
    if getattr(cp, "chaos", None) is not None:
        snap["chaos"] = cp.chaos.capture()
    if cp.obs is not None:
        snap["obs"] = _capture_obs(cp.obs)
    return snap


def restore_control(cp, snap: dict, *, store=None,
                    obs_prefixes: dict | None = None) -> None:
    """Overwrite a freshly-constructed ControlPlane's mutable state from a
    snapshot.  ``store`` (the WAL) replays the event prefix to rebuild the
    bus's running sha256; ``obs_prefixes`` carries the surviving obs file
    prefixes (read before construction truncated them)."""
    bus = cp.bus
    n = snap["bus"]["n_events"]
    bus._seq = n
    bus.counts = dict(snap["bus"]["counts"])
    bus.sink_events = snap["bus"]["sink_events"]
    if store is not None:
        bus._hash = store.replay_digest(n)
        if bus.keep_log:
            # reproduce emit()'s retention semantics over the prefix
            bus.log = []
            bus.dropped = 0
            for ev in store.read(0, n):
                if len(bus.log) < bus.log_cap:
                    bus.log.append(ev)
                else:
                    bus.dropped += 1
    restore_sim(cp.sim, snap["sim"])
    cp._trace_i = snap["trace_i"]
    cp.last_telemetry = _copy_arrays(snap["last_telemetry"])
    cp.autoscale_decisions = [dict(d) for d in snap["autoscale_decisions"]]
    for svc, row in snap["scalers"].items():
        s = cp.scalers[svc]
        s.replicas = row["replicas"]
        s._last_scale_at = row["last_scale_at"]
        s._below_since = row["below_since"]
    if snap["campaign"] is not None:
        c = cp.campaign
        c.rng.bit_generator.state = snap["campaign"]["rng"]
        c.injected_by_kind = dict(snap["campaign"]["injected"])
        c.propagated_by_kind = dict(snap["campaign"]["propagated"])
    if snap["agents"] is not None:
        a = cp.agents
        row = snap["agents"]
        a.rng.bit_generator.state = row["rng"]
        a.last_report = np.copy(row["last_report"])
        a.stale = np.copy(row["stale"])
        a.stale_episodes = row["stale_episodes"]
        a.stale_device_ticks = row["stale_device_ticks"]
        a.reports_sent = row["reports_sent"]
        a.reports_dropped = row["reports_dropped"]
        a._next_beat = row["next_beat"]
        a.seen = _copy_arrays(row["seen"])
        a.seen_state = np.copy(row["seen_state"])
    if snap["jobs"] is not None and cp.job_manager is not None:
        cp.job_manager.jobs = {j: copy.copy(r)
                               for j, r in snap["jobs"]["jobs"].items()}
        cp.job_manager.violations = list(snap["jobs"]["violations"])
    if snap["serving"] is not None and cp.serving is not None:
        _restore_serving(cp.serving, snap["serving"])
    if (snap.get("chaos") is not None
            and getattr(cp, "chaos", None) is not None):
        cp.chaos.restore(snap["chaos"])
    if snap["obs"] is not None and cp.obs is not None:
        _restore_obs(cp.obs, snap["obs"], obs_prefixes or {})
