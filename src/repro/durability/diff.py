"""WAL diffing: pinpoint the first divergent event between two runs.

``diff_runs(A, B)`` is the regression tool the ROADMAP asked for: given two
durable run directories (e.g. the same scenario before and after a code
change, or two seeds suspected identical), it locates the exact first event
where the WALs part ways — without reading both streams end to end.

The per-segment sha256 chain (``chain_k = sha256(chain_{k-1} +
sha256(seg_k))``) makes chain equality at index ``k`` equivalent to "every
sealed segment through ``k`` is byte-identical", a monotone predicate — so
a binary search over the common sealed prefix finds the first mismatched
segment in O(log segments) hash comparisons.  Only that one segment (or
the unsealed tail, when every common sealed segment matches) is then read
event-by-event, comparing :meth:`Event.key` — the exact tuple the bus's
running digest hashes.

The report carries the divergent seq/tick, both events, a context window
of surrounding events from each run, and — when the runs recorded alerts —
each run's incident timeline open at the divergence tick, so a behavioral
regression lands next to the operator-facing harm it caused.
"""
from __future__ import annotations

import itertools
import json
import os

from repro.durability.store import _row_of, open_store
from repro.obs.alerts import incidents_open_at, read_incidents

DIFF_SCHEMA = "repro.durability.diff/v1"


def _open_rundir(rundir: str):
    run_json = os.path.join(rundir, "run.json")
    if not os.path.exists(run_json):
        raise FileNotFoundError(f"no run.json in {rundir} — not a durable "
                                "run directory")
    with open(run_json) as f:
        meta = json.load(f)
    store = open_store(os.path.join(rundir, "events"),
                       meta.get("backend", "jsonl"),
                       segment_events=meta.get("segment_events", 50_000))
    return meta, store


def _first_mismatched_segment(chain_a: list, chain_b: list) -> int:
    """Binary-search the sealed chains: the first common index whose chain
    hash differs, or ``min(len_a, len_b)`` when every common sealed
    segment matches (chain equality at k ⟺ the whole prefix through k is
    identical, so the predicate is monotone)."""
    lo, hi = 0, min(len(chain_a), len(chain_b))
    while lo < hi:
        mid = (lo + hi) // 2
        if chain_a[mid]["chain"] == chain_b[mid]["chain"]:
            lo = mid + 1
        else:
            hi = mid
    return lo


def _incident_timeline(rundir: str, meta: dict):
    path = (meta.get("obs") or {}).get("alerts_out")
    if path and os.path.exists(path):
        return read_incidents(path)
    return None


def diff_runs(rundir_a: str, rundir_b: str, *, context: int = 3) -> dict:
    """Compare two durable runs' WALs; see module docstring.  The returned
    document has ``identical=True`` and ``first_divergence=None`` when the
    event streams match in full."""
    meta_a, store_a = _open_rundir(rundir_a)
    meta_b, store_b = _open_rundir(rundir_b)
    try:
        chain_a, chain_b = store_a.chain(), store_b.chain()
        n_a, n_b = store_a.count(), store_b.count()
        # chain bisection assumes identical segmentation; with different
        # segment sizes the hashes are incomparable — fall back to a scan
        comparable = (meta_a.get("segment_events")
                      == meta_b.get("segment_events"))
        k = _first_mismatched_segment(chain_a, chain_b) if comparable else 0
        sealed_mismatch = (comparable
                           and k < min(len(chain_a), len(chain_b)))
        if sealed_mismatch:
            # divergence is inside sealed segment k (its prefix matched)
            start = chain_a[k]["start"]
            stop = start + max(chain_a[k]["n"], chain_b[k]["n"])
        else:
            # all common sealed segments match: scan the remainder (the
            # unsealed tail, or the longer run's extra segments)
            start = (chain_a[k - 1]["start"] + chain_a[k - 1]["n"]
                     if k else 0)
            stop = None
        div_seq = None
        ev_a = ev_b = None
        for a, b in itertools.zip_longest(store_a.read(start, stop),
                                          store_b.read(start, stop)):
            if a is None or b is None or a.key() != b.key():
                div_seq = (a if a is not None else b).seq
                ev_a, ev_b = a, b
                break
        doc = {
            "schema": DIFF_SCHEMA,
            "a": _run_cell(meta_a, n_a, len(chain_a)),
            "b": _run_cell(meta_b, n_b, len(chain_b)),
            "identical": div_seq is None,
            "sealed_segments_compared": (min(len(chain_a), len(chain_b))
                                         if comparable else 0),
            "first_mismatched_segment": k if sealed_mismatch else None,
            "first_divergence": None,
            "incidents_at_divergence": None,
        }
        if div_seq is None:
            return doc
        t_div = (ev_a if ev_a is not None else ev_b).t
        ctx_start = max(start, div_seq - context)
        ctx_stop = div_seq + context + 1
        tick_s = meta_a.get("tick_s") or 1.0
        doc["first_divergence"] = {
            "seq": div_seq,
            "t": t_div,
            "tick": int(t_div / tick_s),
            "event_a": _row_of(ev_a) if ev_a is not None else None,
            "event_b": _row_of(ev_b) if ev_b is not None else None,
            "context_a": [_row_of(e)
                          for e in store_a.read(ctx_start, ctx_stop)],
            "context_b": [_row_of(e)
                          for e in store_b.read(ctx_start, ctx_stop)],
        }
        inc = {}
        for side, rundir, meta in (("a", rundir_a, meta_a),
                                   ("b", rundir_b, meta_b)):
            timeline = _incident_timeline(rundir, meta)
            inc[side] = None if timeline is None else {
                "total": len(timeline),
                "open_at_t": [i.row() for i in
                              incidents_open_at(timeline, t_div)],
            }
        if inc["a"] is not None or inc["b"] is not None:
            doc["incidents_at_divergence"] = inc
        return doc
    finally:
        store_a.close()
        store_b.close()


def _run_cell(meta: dict, n_events: int, n_sealed: int) -> dict:
    return {"scenario": meta.get("scenario"), "seed": meta.get("seed"),
            "engine": meta.get("engine"),
            "n_devices": meta.get("n_devices"),
            "n_events": n_events, "sealed_segments": n_sealed}


def format_diff(doc: dict) -> str:
    """A short human-readable digest (stderr; the JSON document is the
    machine-readable artifact)."""
    a, b = doc["a"], doc["b"]
    head = (f"A: {a['scenario']} seed={a['seed']} engine={a['engine']} "
            f"({a['n_events']} events)\n"
            f"B: {b['scenario']} seed={b['seed']} engine={b['engine']} "
            f"({b['n_events']} events)")
    if doc["identical"]:
        return head + "\nno divergence: event streams are identical"
    fd = doc["first_divergence"]
    lines = [head,
             f"first divergence at seq {fd['seq']} "
             f"(t={fd['t']:.1f}s, tick {fd['tick']})"]
    for side in ("a", "b"):
        ev = fd[f"event_{side}"]
        lines.append(f"  {side}: " + ("<stream ended>" if ev is None else
                                      f"{ev['kind']} device={ev['device']} "
                                      f"job={ev['job']} data={ev['data']}"))
    inc = doc.get("incidents_at_divergence")
    if inc:
        for side in ("a", "b"):
            cell = inc[side]
            if cell is not None:
                lines.append(f"  incidents open in {side} at divergence: "
                             f"{len(cell['open_at_t'])} "
                             f"(of {cell['total']} total)")
    return "\n".join(lines)
