"""Time-travel inspection: the live state of a durable run at any tick.

``inspect_run`` answers "what did the cluster look like at tick T?" for a
finished (or crashed) durable run: pick the newest manifest-verified
snapshot at or before T, restore a fresh ControlPlane from it, replay the
remaining ticks to *exactly* T via :meth:`ControlPlane.run`'s pause seam
(``stop_tick``), and summarize the paused state — device/mstate histograms,
the job queue and placement table, serving lane depths, and the incident
timeline open at T (read back from the run's persisted ``incidents.jsonl``).

Determinism contract: the summary document is byte-identical whether the
replay started from a snapshot or from tick 0 (``from_start=True``), and
across the numpy/xla engines — CI cmp-gates this.  The inspection plane is
read-only: it never attaches a WAL sink, never truncates the store, and
runs with ``obs=None`` so the run's own metrics/trace/alert artifacts are
untouched.
"""
from __future__ import annotations

import glob
import os
import pickle

import numpy as np

from repro.durability.manifest import file_sha256
from repro.durability.snapshot import restore_control
from repro.obs.alerts import incidents_open_at, read_incidents
from repro.obs.export import canonical_json

INSPECT_SCHEMA = "repro.durability.inspect/v1"

_MSTATE_NAMES = ("init", "healthy", "unhealthy", "overlimit", "disabled")


def _pick_snapshot_before(run, tick: int):
    """Newest manifest-verified snapshot with ``tick_i <= tick`` (snapshot
    filenames carry the tick, so mismatching ones are skipped without
    unpickling)."""
    listed = getattr(run, "_manifest", {}).get("artifacts", {})
    paths = sorted(glob.glob(
        os.path.join(run.rundir, "snapshots", "snap-*.pkl")), reverse=True)
    for path in paths:
        base = os.path.basename(path)
        try:
            snap_tick = int(base[len("snap-"):-len(".pkl")])
        except ValueError:
            continue
        if snap_tick > tick:
            continue
        rel = os.path.relpath(path, run.rundir)
        entry = listed.get(rel)
        if entry is None:
            continue
        sha, size = file_sha256(path)
        if sha != entry["sha256"] or size != entry["bytes"]:
            continue
        with open(path, "rb") as f:
            return pickle.load(f)
    return None


def build_paused(run, tick: int, *, from_start: bool = False,
                 predictor=None):
    """A fresh ControlPlane for ``run``'s scenario, advanced to exactly
    ``tick`` completed ticks and paused (not finalized).  Returns
    ``(cp, replayed_from_tick)``."""
    from repro.cluster.control import ControlPlane
    sc = run.scenario
    n_ticks = int(sc.horizon_seconds() / sc.tick_s)
    if not 0 <= tick <= n_ticks:
        raise ValueError(f"tick {tick} outside the run's horizon "
                         f"[0, {n_ticks}]")
    cp = ControlPlane(sc, predictor=predictor, obs=None)
    start_tick, start_t = 0, 0.0
    snap = None if from_start else _pick_snapshot_before(run, tick)
    if snap is not None:
        restore_control(cp, snap, store=run.store)
        start_tick, start_t = snap["tick_i"], snap["t"]
    cp.run(start_tick=start_tick, start_t=start_t, stop_tick=tick)
    return cp, start_tick


def summarize_state(cp, tick: int) -> dict:
    """The deterministic state document for a paused ControlPlane.  Every
    field derives from engine-identical state — never paths, snapshot
    provenance, or wall clock — so snapshot-replay and from-start paths
    produce identical bytes."""
    sim = cp.sim
    sc = cp.scenario
    t = tick * sc.tick_s
    s = sim.state
    n = int(sim.cfg.n_devices)
    failed = s.failed_until > t
    outage = s.outage_until > t
    mstate_hist = np.bincount(sim.monitor.state,
                              minlength=len(_MSTATE_NAMES))
    by_model: dict[str, int] = {}
    by_pool: dict[str, int] = {}
    for i in np.flatnonzero(s.has_job):
        spec = sim.job_spec[int(i)]
        if spec is not None:
            by_model[spec.model] = by_model.get(spec.model, 0) + 1
        pool = sim.pool_names[int(sim.pool_of[int(i)])]
        by_pool[pool] = by_pool.get(pool, 0) + 1
    serving = None
    if cp.serving is not None:
        serving = {
            lane.service: {
                "queued": int(sum(c[1] for c in lane.queue)),
                "arrived": int(lane.arrived),
                "served": int(lane.served),
                "shed": int(lane.shed),
                "peak_queue": int(lane.peak_queue),
            } for lane in cp.serving.lanes}
    return {
        "schema": INSPECT_SCHEMA,
        "scenario": sc.name,
        "seed": sc.seed,
        "policy": sc.policy,
        "tick": tick,
        "t": t,
        "devices": {
            "total": n,
            "failed": int(failed.sum()),
            "outage": int(outage.sum()),
            "busy": int(s.has_job.sum()),
            "schedulable": int(sim.monitor.schedulable.sum()),
        },
        "mstate": {name: int(mstate_hist[i])
                   for i, name in enumerate(_MSTATE_NAMES)},
        "pools": sim.pool_view(t),
        "jobs": {
            "pending": len(sim.pending),
            "running": int(s.has_job.sum()),
            "finished": len(sim.finished),
            "executions": int(sim.executions),
            "evictions": int(sim.evictions),
            "errors_injected": int(sim.errors_injected),
            "online_incidents": int(sim.online_incidents),
            "trace_submitted": int(cp._trace_i),
            "next_pending": [spec.job_id for spec in sim.pending[:10]],
        },
        "placements": {
            "by_model": dict(sorted(by_model.items())),
            "by_pool": dict(sorted(by_pool.items())),
        },
        "serving": serving,
        "events": {
            "n_events": int(cp.bus.n_events),
            "counts": {k: int(v)
                       for k, v in sorted(cp.bus.counts.items())},
        },
    }


def _run_incidents(run):
    """The run's persisted incident timeline, if it recorded one."""
    path = run.obs.alerts_out if run.obs is not None else None
    if path and os.path.exists(path):
        return read_incidents(path)
    return None


def inspect_run(rundir: str, tick: int | None = None, *,
                around_incident: int | None = None,
                from_start: bool = False, predictor=None) -> dict:
    """Time-travel a durable run to a tick and summarize its state (see
    module docstring).  ``around_incident=K`` targets the tick incident K
    opened at instead of an explicit ``tick``."""
    from repro.cluster.control import jsonify
    from repro.durability.runner import DurableRun
    run = DurableRun.open(rundir)
    try:
        incidents = _run_incidents(run)
        if around_incident is not None:
            if incidents is None:
                raise ValueError(
                    f"--around-incident needs an incidents.jsonl, but "
                    f"{rundir} recorded none (run with --alerts-out)")
            inc = next((i for i in incidents if i.id == around_incident),
                       None)
            if inc is None:
                raise ValueError(
                    f"no incident id {around_incident} in {rundir} "
                    f"({len(incidents)} incidents recorded)")
            tick = int(round(inc.opened_t / run.scenario.tick_s))
        if tick is None:
            raise ValueError("need a tick or an incident id to inspect at")
        cp, _ = build_paused(run, tick, from_start=from_start,
                             predictor=predictor)
        doc = summarize_state(cp, tick)
        if incidents is not None:
            t = tick * run.scenario.tick_s
            doc["incidents"] = {
                "total": len(incidents),
                "open_at_t": [inc.row()
                              for inc in incidents_open_at(incidents, t)],
            }
        else:
            doc["incidents"] = None
        return jsonify(doc)
    finally:
        run.store.close()


def dump_inspection(doc: dict, path: str | None = None) -> str:
    """Serialize an inspection document with the canonical exporter (sorted
    keys, rounded floats) — the byte-stable form CI ``cmp``s.  Writes to
    ``path`` when given; returns the serialized text either way."""
    text = canonical_json(doc) + "\n"
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text


def _fmt_table(doc: dict) -> str:
    """A short human-readable digest (stderr; never cmp-gated)."""
    dev = doc["devices"]
    jobs = doc["jobs"]
    lines = [
        f"tick {doc['tick']} (t={doc['t']:.0f}s) scenario="
        f"{doc['scenario']} seed={doc['seed']}",
        f"devices: {dev['total']} total, {dev['busy']} busy, "
        f"{dev['schedulable']} schedulable, {dev['failed']} failed, "
        f"{dev['outage']} in outage",
        f"jobs: {jobs['running']} running, {jobs['pending']} pending, "
        f"{jobs['finished']} finished ({jobs['evictions']} evictions, "
        f"{jobs['errors_injected']} errors, "
        f"{jobs['online_incidents']} online incidents)",
    ]
    inc = doc.get("incidents")
    if inc is not None:
        open_rows = inc["open_at_t"]
        lines.append(f"incidents: {inc['total']} total, "
                     f"{len(open_rows)} open at t"
                     + ("".join(f"\n  #{r['id']} {r['rule']} [{r['target']}]"
                                f" {r['severity']} opened t={r['opened_t']}"
                                for r in open_rows[:10])))
    return "\n".join(lines)
