"""Durable, event-sourced control plane: WAL + snapshots + signed manifests.

The determinism substrate (gapless event sequences, SHA-256 stream digests,
byte-identical numpy/xla reports) makes crash recovery *provable*: persist
the event stream and periodic state snapshots, and a run killed at an
arbitrary tick can be resumed to a final report that is byte-identical to
an uninterrupted same-seed run.

Layout of a durable run directory::

    rundir/
      run.json              # provenance: scenario, seed, engine, artifact paths
      scenario.pkl          # the fully-resolved Scenario (resume input)
      events/
        segment-000000.jsonl  # append-only WAL segments (or log.sqlite)
        index.json            # sealed-segment sha256 chain
      snapshots/
        snap-0000360.pkl      # tick-boundary state snapshots
      manifest.json         # artifact sha256s + HMAC signature

Modules:

* :mod:`~repro.durability.store` — ``EventStore`` API with JSONL-segment and
  sqlite backends; per-segment SHA-256 chain hashes.
* :mod:`~repro.durability.snapshot` — capture/restore of the mutable state of
  ClusterSim, ControlPlane, ServingPlane, and the obs plane's mid-stream
  writers.
* :mod:`~repro.durability.manifest` — HMAC-SHA256 signed run manifests.
* :mod:`~repro.durability.runner` — the durable run loop and ``--resume``.
* :mod:`~repro.durability.inspect` — time-travel: replay to an arbitrary
  tick and summarize the live state (``python -m repro inspect``).
* :mod:`~repro.durability.diff` — pinpoint the first divergent WAL event
  between two runs via chain bisection (``python -m repro diff``).
"""
from repro.durability.diff import DIFF_SCHEMA, diff_runs, format_diff
from repro.durability.inspect import (INSPECT_SCHEMA, build_paused,
                                      dump_inspection, inspect_run)
from repro.durability.manifest import (sign_manifest, verify_manifest,
                                       write_manifest)
from repro.durability.runner import (DurableRun, resume_run, run_durable,
                                     verify_rundir)
from repro.durability.snapshot import (capture_sim, restore_sim,
                                       capture_control, restore_control)
from repro.durability.store import (JsonlEventStore, SqliteEventStore,
                                    open_store)

__all__ = [
    "JsonlEventStore", "SqliteEventStore", "open_store",
    "capture_sim", "restore_sim", "capture_control", "restore_control",
    "sign_manifest", "verify_manifest", "write_manifest",
    "DurableRun", "run_durable", "resume_run", "verify_rundir",
    "INSPECT_SCHEMA", "inspect_run", "build_paused", "dump_inspection",
    "DIFF_SCHEMA", "diff_runs", "format_diff",
]
