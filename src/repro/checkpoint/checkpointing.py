"""Sharded checkpointing: atomic, async-capable, eviction-safe.

Layout: <dir>/step_<N>/ with one .npz per pytree leaf-group and a JSON
manifest (tree structure, shapes, dtypes, step).  Writes go to a temp dir +
atomic rename so a SIGTERM mid-write never corrupts the latest checkpoint —
this is the persistence behind MuxFlow's graceful-exit and evict/restart
paths ("we record checkpoints of offline workloads and restart ... after
transmitting the models and checkpoints").

Restore reshards automatically: arrays are loaded as numpy and placed with
`jax.device_put(x, sharding)` against whatever mesh the restarted job has —
the elastic-rescale path.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        leaves, treedef = _flatten(tree)
        manifest = {"step": step, "treedef": str(treedef),
                    "n_leaves": len(leaves),
                    "shapes": [list(np.shape(l)) for l in leaves],
                    "dtypes": [str(np.asarray(l).dtype if not isinstance(l, jax.Array)
                                   else l.dtype) for l in leaves]}
        arrays = {}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == np.dtype("bfloat16"):
                arrays[f"leaf_{i}"] = arr.view(np.uint16)
                manifest["dtypes"][i] = "bfloat16"
            else:
                arrays[f"leaf_{i}"] = arr
        np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                   # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and
             os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of `like_tree`; optionally reshard."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == manifest["n_leaves"], "tree structure changed"
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    import ml_dtypes
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[f"leaf_{i}"]
        if manifest["dtypes"][i] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), step


class AsyncCheckpointer:
    """Background-thread checkpointing: the train loop hands off host copies
    and keeps stepping (the paper hides scheduling/checkpoint overhead inside
    the interval the same way)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._do_save, args=(step, host_tree), daemon=True)
        self._thread.start()

    def _do_save(self, step, host_tree):
        save(self.ckpt_dir, step, host_tree, keep=self.keep)
        self.last_saved = step

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
