"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(q, k, v, *, causal=True, window=None,
                        kv_len=None) -> jax.Array:
    """q: (B,Sq,H,d); k,v: (B,Skv,Hk,d).  fp32 softmax, GQA by repeat."""
    B, Sq, H, d = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask &= k_pos < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_reference(q, k_cache, v_cache, kv_len) -> jax.Array:
    """q: (B,1,H,d) against (B,Skv,Hk,d) caches with kv_len valid entries."""
    B, _, H, d = q.shape
    Skv = k_cache.shape[1]
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
    outs = attention_reference(
        q, k_cache, v_cache, causal=False,
        kv_len=None)  # full; mask below per batch
    # redo with per-batch masks (reference simplicity over speed)
    Hk = k_cache.shape[2]
    G = H // Hk
    k = jnp.repeat(k_cache, G, axis=2) if G > 1 else k_cache
    v = jnp.repeat(v_cache, G, axis=2) if G > 1 else v_cache
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    mask = jnp.arange(Skv)[None, :] < kv_len[:, None]      # (B, Skv)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssm_scan_reference(dt, x, B_ssm, C_ssm, A_log) -> jax.Array:
    """Sequential selective scan.  Shapes as ssm_scan; returns fp32 y."""
    Bsz, S, di = x.shape
    N = B_ssm.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    bx = (dtf * x.astype(jnp.float32))

    def step(h, inp):
        dt_t, bx_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None] * A)               # (B, di, N)
        h = dA * h + bx_t[..., None] * B_t[:, None, :]
        return h, (h * C_t[:, None, :]).sum(-1)

    h0 = jnp.zeros((Bsz, di, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (dtf.swapaxes(0, 1), bx.swapaxes(0, 1),
                                    B_ssm.astype(jnp.float32).swapaxes(0, 1),
                                    C_ssm.astype(jnp.float32).swapaxes(0, 1)))
    return ys.swapaxes(0, 1)
