"""Pallas TPU flash-attention kernel (train/prefill hot spot).

Tiling: grid (batch*kv_heads*q_groups, Sq/block_q); each program streams KV
blocks of `block_k` through VMEM with the online-softmax recurrence, keeping
(block_q, d) accumulators in VMEM scratch.  Causal and sliding-window masks
are applied from absolute positions; GQA is handled by mapping each query
head-group onto its KV head via the BlockSpec index maps (no KV repeat in
HBM).

Block shapes default to (block_q, block_k) = (128, 128): MXU-aligned
(multiples of 128 on the contracting/lane dims) and a VMEM working set of
block_q*d + 2*block_k*d + block_q*block_k fp32 ≈ 0.3 MB at d=128 — far under
the ~16 MB VMEM budget, leaving room for double buffering.

Validated against ref.attention_reference in interpret mode (tests sweep
shapes/dtypes); on CPU the model's distribution path uses the jnp chunked
form (models/layers.py) with identical math.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_k,
                  causal, window, sm_scale):
    qi = pl.program_id(1)
    # NB: length-1 slices (not raw int indices) throughout — int indices in
    # ref loads/stores break jax 0.4.x interpret-mode discharge on CPU
    q = q_ref[...][0].astype(jnp.float32) * sm_scale     # (block_q, d)
    d = q.shape[-1]
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, d), jnp.float32)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    num_k = seq_k // block_k

    def body(ki, carry):
        m, l, acc = carry
        k_blk = pl.load(k_ref, (slice(0, 1), pl.dslice(ki * block_k, block_k),
                                slice(None)))[0].astype(jnp.float32)
        v_blk = pl.load(v_ref, (slice(0, 1), pl.dslice(ki * block_k, block_k),
                                slice(None)))[0].astype(jnp.float32)
        s = q @ k_blk.T                                  # (block_q, block_k)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + p @ v_blk
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_k, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)[None]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, d); k, v: (B, Skv, Hk, d), H = G*Hk.  Returns (B,Sq,H,d).

    Each grid program owns one (batch, q-head, q-block); the BlockSpec index
    map sends query head h to KV head h // G.
    """
    B, Sq, H, d = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    sm_scale = 1.0 / math.sqrt(d)
    # layout: heads-major so one program sees a contiguous (seq, d) tile
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, d)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, d)

    grid = (B * H, Sq // block_q)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=Skv,
        causal=causal, window=window, sm_scale=sm_scale)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, Skv, d), lambda bh, qi, G=G: (bh // G, 0, 0)),
            pl.BlockSpec((1, Skv, d), lambda bh, qi, G=G: (bh // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
