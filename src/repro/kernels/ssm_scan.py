"""Pallas TPU chunked selective-scan kernel (Mamba hot spot in jamba).

Grid (batch, n_chunks) with the chunk axis *sequential*: the SSM state
h (d_inner_block, N) lives in VMEM scratch and is carried across chunk
iterations (dimension_semantics=("parallel", "arbitrary")).  Within a chunk
the first-order recurrence h_t = dA_t·h_{t-1} + dBx_t is evaluated by a
short fori_loop over the chunk (N=16 lanes per channel; the per-step work is
a (d_blk, N) FMA — VPU-bound, which is the true character of the Mamba scan;
the matmuls around it stay in XLA).

VMEM working set per program: chunk·d_blk (dt, x) + chunk·N (B, C) + d_blk·N
(state) fp32 ≈ 0.6 MB at chunk=64, d_blk=512, N=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _ssm_kernel(dt_ref, bx_ref, c_ref, alog_ref, o_ref, h_ref, *, chunk, n_state):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dt = dt_ref[...][0].astype(jnp.float32)          # (chunk, d_blk)
    bx = bx_ref[...][0].astype(jnp.float32)          # (chunk, d_blk)  = dt*x (pre-multiplied)
    Bc = c_ref[...][0, :, 0, :]                      # (chunk, N)  B_t
    Cc = c_ref[...][0, :, 1, :]                      # (chunk, N)  C_t
    A = -jnp.exp(alog_ref[...].astype(jnp.float32))   # (d_blk, N)

    def step(t, carry):
        h, out = carry
        dA = jnp.exp(dt[t][:, None] * A)                       # (d_blk, N)
        h = dA * h + bx[t][:, None] * Bc[t][None, :]
        y_t = (h * Cc[t][None, :]).sum(axis=1)                 # (d_blk,)
        out = jax.lax.dynamic_update_index_in_dim(out, y_t, t, 0)
        return h, out

    h0 = h_ref[...]
    out0 = jnp.zeros((chunk, dt.shape[1]), jnp.float32)
    h, out = jax.lax.fori_loop(0, chunk, step, (h0, out0))
    h_ref[...] = h
    o_ref[...] = out.astype(o_ref.dtype)[None]


def ssm_scan(dt: jax.Array, x: jax.Array, B_ssm: jax.Array, C_ssm: jax.Array,
             A_log: jax.Array, *, chunk: int = 64,
             interpret: bool = False) -> jax.Array:
    """Selective scan: y[b,t,d] = Σ C[b,t]·h[b,t,d,:], h recurrent.

    dt, x: (B, S, di); B_ssm, C_ssm: (B, S, N); A_log: (di, N).
    Returns y (B, S, di) fp32 (without the D·x skip, applied by the caller).
    """
    Bsz, S, di = x.shape
    N = B_ssm.shape[-1]
    assert S % chunk == 0
    nck = S // chunk
    bx = (dt * x).astype(jnp.float32)
    bc = jnp.stack([B_ssm, C_ssm], axis=2)      # (B, S, 2, N)

    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_state=N)
    out = pl.pallas_call(
        kernel,
        grid=(Bsz, nck),
        in_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 2, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((di, N), lambda b, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((di, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(dt.astype(jnp.float32), bx, bc.astype(jnp.float32), A_log)
    return out
