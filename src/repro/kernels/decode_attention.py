"""Pallas TPU flash-decode kernel (the online-serving hot spot MuxFlow
protects).

One new query token per sequence against a long KV cache: grid
(batch*kv_heads, Skv/block_k) with the KV-length axis *sequential* ("split-K"
over the cache).  Each program reduces its KV block into VMEM scratch
(running max / sum / accumulator, flash-decoding style) and the final block
normalizes — giving O(block) VMEM for arbitrarily long caches.

The G query heads of a KV group are carried together: the q tile is (G, d),
MXU work per block is (G, d) × (d, block_k).  block_k defaults to 512 lanes:
the kernel is bandwidth-bound, so wide blocks amortize control overhead while
(G·block_k + block_k·d) stays ≪ VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, block_k, grid_k, sm_scale):
    ki = pl.program_id(1)
    G, d = q_ref.shape[1], q_ref.shape[2]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...][0].astype(jnp.float32) * sm_scale          # (G, d)
    k_blk = k_ref[...][0].astype(jnp.float32)                 # (block_k, d)
    v_blk = v_ref[...][0].astype(jnp.float32)
    s = q @ k_blk.T                                      # (G, block_k)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(k_pos < len_ref[...][0], s, NEG_INF)
    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_new = acc_prev * corr[:, None] + p @ v_blk
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ki == grid_k - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)[None]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len, *, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, 1, H, d); caches: (B, Skv, Hk, d); kv_len: valid entries
    (scalar or (B,)).  Returns (B, 1, H, d)."""
    B, _, H, d = q.shape
    Skv, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    assert Skv % block_k == 0, (Skv, block_k)
    sm_scale = 1.0 / math.sqrt(d)
    qt = q.reshape(B, Hk, G, d).reshape(B * Hk, G, d)
    kt = k_cache.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, d)
    vt = v_cache.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, d)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1, 1),
                            (B, Hk)).reshape(B * Hk, 1)
    grid_k = Skv // block_k
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               grid_k=grid_k, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hk, grid_k),
        in_specs=[
            pl.BlockSpec((1, G, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hk, G, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),      # running max
            pltpu.VMEM((G,), jnp.float32),      # running sum
            pltpu.VMEM((G, d), jnp.float32),    # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, lens)
    return out.reshape(B, 1, H, d)
