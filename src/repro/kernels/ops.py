"""Jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True off-TPU so the same call sites work everywhere;
on TPU backends the real Mosaic kernels run.
"""
from __future__ import annotations

import functools

import jax

from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .ssm_scan import ssm_scan as _ssm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *, block_k=512,
                     interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _decode(q, k_cache, v_cache, kv_len, block_k=block_k,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(dt, x, B_ssm, C_ssm, A_log, *, chunk=64, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _ssm(dt, x, B_ssm, C_ssm, A_log, chunk=chunk, interpret=interpret)
