"""Optimizers: AdamW (training) and momentum SGD (the paper's predictor
optimizer).  Pure-pytree, no external deps; optimizer moments are fp32 and
inherit the parameter sharding (FSDP params => ZeRO-sharded moments for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), g


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    master_weights: bool = False   # keep an fp32 master copy of bf16 params


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


class AdamW:
    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg

    def init(self, params) -> dict:
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.cfg.master_weights:
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return state

    def update(self, params, grads, state):
        cfg = self.cfg
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        step = state["step"] + 1
        lr = cosine_lr(cfg, step)
        b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
        ref = state.get("master", params)

        def upd(p_ref, g, m, v):
            gf = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * gf
            v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
            mhat = m / b1c
            vhat = v / b2c
            pf = p_ref.astype(jnp.float32)
            pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
            return pf, m, v

        flat_ref, treedef = jax.tree.flatten(ref)
        outs = [upd(p, g, m, v) for p, g, m, v in zip(
            flat_ref, jax.tree.leaves(grads), jax.tree.leaves(state["m"]),
            jax.tree.leaves(state["v"]))]
        new_master = treedef.unflatten([o[0] for o in outs])
        new_m = treedef.unflatten([o[1] for o in outs])
        new_v = treedef.unflatten([o[2] for o in outs])
        new_params = jax.tree.map(lambda mw, p: mw.astype(p.dtype), new_master, params)
        new_state = {"m": new_m, "v": new_v, "step": step}
        if cfg.master_weights:
            new_state["master"] = new_master
        return new_params, new_state, gnorm


@dataclasses.dataclass(frozen=True)
class MomentumSGDConfig:
    """The paper trains the speed-predictor MLPs 'with momentum SGD optimizer
    in PyTorch' — this is that optimizer, in JAX."""
    lr: float = 1e-2
    momentum: float = 0.9
    weight_decay: float = 0.0
    nesterov: bool = False


class MomentumSGD:
    def __init__(self, cfg: MomentumSGDConfig):
        self.cfg = cfg

    def init(self, params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        cfg = self.cfg

        def upd(p, g, mu):
            gf = g.astype(jnp.float32) + cfg.weight_decay * p.astype(jnp.float32)
            mu = cfg.momentum * mu + gf
            d = gf + cfg.momentum * mu if cfg.nesterov else mu
            return (p.astype(jnp.float32) - cfg.lr * d).astype(p.dtype), mu

        flat_p, treedef = jax.tree.flatten(params)
        outs = [upd(p, g, mu) for p, g, mu in zip(
            flat_p, jax.tree.leaves(grads), jax.tree.leaves(state["mu"]))]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_mu = treedef.unflatten([o[1] for o in outs])
        return new_p, {"mu": new_mu, "step": state["step"] + 1}, global_norm(grads)
