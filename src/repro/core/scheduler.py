"""Matching-based scheduling (§5, Algorithm 1) and the global manager.

Every scheduling interval: build the bipartite graph between online workloads
(one per shareable GPU) and pending/running offline workloads; edge weight =
speed-predictor normalized throughput at the dynamic-SM share; solve with KM;
apply the matching (with move = checkpoint + restart semantics handled by the
caller/simulator).  Devices whose SysMonitor is not Healthy contribute no
node — this is also how elasticity works: the graph is simply rebuilt from
the live device set, so node joins/leaves are absorbed at the next interval.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dynamic_sm import dynamic_sm, fixed_sm
from repro.core.interference import WorkloadProfile
from repro.core.matching import km_match
from repro.core.predictor import SpeedPredictor, pair_features


@dataclasses.dataclass
class OnlineSlot:
    """A shareable GPU running one online workload."""
    device_id: int
    gpu_type: str
    profile: WorkloadProfile


@dataclasses.dataclass
class OfflineJob:
    job_id: int
    profile: WorkloadProfile
    remaining_iters: float


@dataclasses.dataclass
class Assignment:
    device_id: int
    job_id: int
    sm_share: float
    predicted_tput: float


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    use_dynamic_sm: bool = True     # False => MuxFlow-S ablation (fixed 40 %)
    use_matching: bool = True       # False => MuxFlow-M ablation (greedy FIFO)
    fixed_sm_share: float = 0.4
    min_weight: float = 0.02        # prune edges below this predicted tput


def _sm_share(cfg: SchedulerConfig, online: WorkloadProfile) -> float:
    if cfg.use_dynamic_sm:
        return dynamic_sm(online.sm_activity)
    return fixed_sm(cfg.fixed_sm_share)


def schedule(slots: list[OnlineSlot], jobs: list[OfflineJob],
             predictor: SpeedPredictor,
             cfg: SchedulerConfig = SchedulerConfig()) -> list[Assignment]:
    """Algorithm 1.  Returns the chosen assignments."""
    if not slots or not jobs:
        return []
    n, m = len(slots), len(jobs)
    # batched prediction: one feature matrix per gpu type
    weights = np.zeros((n, m), dtype=np.float64)
    shares = np.zeros((n,), dtype=np.float64)
    by_type: dict[str, list[int]] = {}
    for i, s in enumerate(slots):
        shares[i] = _sm_share(cfg, s.profile)
        by_type.setdefault(s.gpu_type, []).append(i)
    for gpu_type, idxs in by_type.items():
        feats = np.stack([
            pair_features(slots[i].profile, j.profile, shares[i])
            for i in idxs for j in jobs])
        pred = predictor.predict(gpu_type, feats).reshape(len(idxs), m)
        for row, i in enumerate(idxs):
            weights[i] = pred[row]
    weights[weights < cfg.min_weight] = 0.0

    if cfg.use_matching:
        pairs = km_match(weights)
    else:
        # MuxFlow-M ablation: FIFO jobs onto arbitrary (first) free devices
        pairs = [(i, j) for i, j in zip(range(n), range(min(n, m)))]
        pairs = [(i, j) for i, j in pairs if weights[i, j] > 0]
    return [Assignment(device_id=slots[i].device_id, job_id=jobs[j].job_id,
                       sm_share=float(shares[i]),
                       predicted_tput=float(weights[i, j]))
            for i, j in pairs]
