"""Matching-based scheduling (§5, Algorithm 1) and the global manager.

Every scheduling interval: build the bipartite graph between online workloads
(one per shareable GPU) and pending/running offline workloads; edge weight =
speed-predictor normalized throughput at the dynamic-SM share; solve with KM;
apply the matching (with move = checkpoint + restart semantics handled by the
caller/simulator).  Devices whose SysMonitor is not Healthy contribute no
node — this is also how elasticity works: the graph is simply rebuilt from
the live device set, so node joins/leaves are absorbed at the next interval.

Paper-scale path: offline jobs carry one of a handful of distinct profiles,
so the weight matrix has only ``n_slots × n_unique_profiles`` distinct
entries.  Prediction is batched over that grid (one predictor call per GPU
type instead of one per pair), and when the bipartite problem exceeds
``shard_size`` the matcher switches from dense KM to
:func:`repro.core.matching.sharded_match_compact`, which partitions
devices/jobs into bounded shards (the paper schedules per cluster partition
anyway) and prunes near-zero edges — O(shards · s³) instead of O(n³).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dynamic_sm import dynamic_sm, fixed_sm
from repro.core.interference import WorkloadProfile
from repro.core.matching import km_match, sharded_match_compact
from repro.core.predictor import N_FEATURES, SpeedPredictor


@dataclasses.dataclass
class OnlineSlot:
    """A shareable GPU running one online workload."""
    device_id: int
    gpu_type: str
    profile: WorkloadProfile


@dataclasses.dataclass
class OfflineJob:
    job_id: int
    profile: WorkloadProfile
    remaining_iters: float


@dataclasses.dataclass
class Assignment:
    device_id: int
    job_id: int
    sm_share: float
    predicted_tput: float


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    use_dynamic_sm: bool = True     # False => MuxFlow-S ablation (fixed 40 %)
    use_matching: bool = True       # False => MuxFlow-M ablation (greedy FIFO)
    fixed_sm_share: float = 0.4
    min_weight: float = 0.02        # prune edges below this predicted tput
    shard_size: int = 256           # partition bound for paper-scale matching
    row_slack: int = 16             # extra devices kept per shard model group


def _sm_share(cfg: SchedulerConfig, online: WorkloadProfile) -> float:
    if cfg.use_dynamic_sm:
        return dynamic_sm(online.sm_activity)
    return fixed_sm(cfg.fixed_sm_share)


def build_online_slots(free_idx, gpu_type: list[str], service_idx,
                       on: dict, services: tuple[str, ...],
                       ) -> list[OnlineSlot]:
    """Materialize :class:`OnlineSlot` objects for the free devices of a
    fleet from vectorized online-profile arrays (see
    :func:`repro.core.interference.online_profile_arrays`).  Shared by the
    simulator engine and the cluster control plane."""
    return [
        OnlineSlot(int(i), gpu_type[i], WorkloadProfile(
            name=services[service_idx[i]],
            gpu_util=float(on["gpu_util"][i]),
            sm_activity=float(on["sm_activity"][i]),
            sm_occupancy=float(on["sm_occupancy"][i]),
            mem_bw=float(on["mem_bw"][i]),
            exec_time_ms=float(on["exec_time_ms"][i]),
            mem_bytes_frac=float(on["mem_bytes_frac"][i])))
        for i in free_idx]


def job_groups(jobs: list[OfflineJob]) -> tuple[np.ndarray,
                                                list[WorkloadProfile]]:
    """Group jobs by (identical) offline profile: (col_group (m,), uniq)."""
    group_of: dict[WorkloadProfile, int] = {}
    col_group = np.empty(len(jobs), np.int64)
    uniq: list[WorkloadProfile] = []
    for j, jb in enumerate(jobs):
        g = group_of.get(jb.profile)
        if g is None:
            g = group_of[jb.profile] = len(uniq)
            uniq.append(jb.profile)
        col_group[j] = g
    return col_group, uniq


def build_weight_grid_arrays(gpu_types: list[str], on_feats: np.ndarray,
                             shares: np.ndarray, jobs: list[OfflineJob],
                             predictor: SpeedPredictor, cfg: SchedulerConfig,
                             ) -> tuple[np.ndarray, np.ndarray]:
    """Array-native batched prediction over the (slot × unique offline
    profile) grid — the engines' hot path (no per-slot Python objects).

    ``gpu_types`` is the per-slot GPU type, ``on_feats`` the (n, 4) float32
    online feature block (util, activity, occupancy, exec seconds), and
    ``shares`` the per-slot offline SM share.  Returns ``(values (n, u),
    col_group (m,))``.  One predictor call per GPU type; cost is O(n · u)
    instead of O(n · m) — with the paper's four offline models u = 4
    regardless of queue depth.
    """
    n, m = len(gpu_types), len(jobs)
    col_group, uniq = job_groups(jobs)
    u = len(uniq)
    off_feats = np.array([[p.gpu_util, p.sm_activity, p.sm_occupancy,
                           p.exec_time_ms / 1000.0] for p in uniq],
                         np.float32)
    values = np.zeros((n, u), np.float64)
    shares32 = shares.astype(np.float32)
    gpu_types_arr = np.asarray(gpu_types)
    # distinct types in first-occurrence order, without a Python iteration
    # over every slot
    uniq_types, first = np.unique(gpu_types_arr, return_index=True)
    for gpu_type in uniq_types[np.argsort(first)]:
        idxs = np.flatnonzero(gpu_types_arr == gpu_type)
        k = len(idxs)
        feats = np.empty((k, u, N_FEATURES), np.float32)
        feats[:, :, 0:4] = on_feats[idxs][:, None, :]
        feats[:, :, 4:8] = off_feats[None, :, :]
        feats[:, :, 8] = shares32[idxs][:, None]
        pred = predictor.predict(gpu_type, feats.reshape(k * u, N_FEATURES))
        values[idxs] = pred.reshape(k, u)
    values[values < cfg.min_weight] = 0.0
    return values, col_group


def static_weight_grid(shares: np.ndarray, jobs: list[OfflineJob],
                       cfg: SchedulerConfig) -> tuple[np.ndarray, np.ndarray]:
    """Predictor-free fallback grid — the degradation-ladder rung for a
    speed-predictor outage.

    Uses the §4.3 static share table alone: an offline partner granted SM
    share ``s`` is assumed to run at roughly ``1 − 0.6·s`` of solo speed
    (the calibrated average contention slope), identically for every
    offline profile.  Placement quality drops to "any job on the least
    contended device", but scheduling rounds keep running — no predictor
    call is made.  Same ``(values (n, u), col_group (m,))`` contract as
    :func:`build_weight_grid_arrays`.
    """
    col_group, uniq = job_groups(jobs)
    u = max(1, len(uniq))
    col = np.maximum(cfg.min_weight, 1.0 - 0.6 * shares.astype(np.float64))
    return np.tile(col[:, None], (1, u)), col_group


def build_weight_grid(slots: list[OnlineSlot], jobs: list[OfflineJob],
                      predictor: SpeedPredictor, cfg: SchedulerConfig,
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Slot-object wrapper over :func:`build_weight_grid_arrays` (kept for
    the reference engine and external callers; the numerics live in the
    array-native core, so both paths produce identical grids)."""
    shares = np.array([_sm_share(cfg, s.profile) for s in slots], np.float64)
    on_feats = np.array([[s.profile.gpu_util, s.profile.sm_activity,
                          s.profile.sm_occupancy,
                          s.profile.exec_time_ms / 1000.0]
                         for s in slots], np.float32)
    values, col_group = build_weight_grid_arrays(
        [s.gpu_type for s in slots], on_feats, shares, jobs, predictor, cfg)
    return values, col_group, shares


def solve_matching(values: np.ndarray, col_group: np.ndarray,
                   cfg: SchedulerConfig, *, row_ids: np.ndarray | None = None,
                   matcher=None) -> list[tuple[int, int]]:
    """The matching step of Algorithm 1 on a compact weight grid.

    Small problems solve dense exact KM; larger ones go through the
    partitioned matcher — warm-started via ``matcher`` (an
    :class:`repro.core.matching.IncrementalMatcher`, exact by construction)
    when one is supplied, cold otherwise.
    """
    n, m = values.shape[0], col_group.shape[0]
    if not cfg.use_matching:
        # MuxFlow-M ablation: FIFO jobs onto arbitrary (first) free devices
        return [(i, i) for i in range(min(n, m))
                if values[i, col_group[i]] > 0]
    if max(n, m) <= cfg.shard_size:
        return km_match(values[:, col_group])           # dense exact KM
    if matcher is not None:
        if row_ids is None:
            row_ids = np.arange(n)
        return matcher.match(values, col_group, row_ids,
                             shard_size=cfg.shard_size,
                             row_slack=cfg.row_slack)
    return sharded_match_compact(values, col_group,
                                 shard_size=cfg.shard_size,
                                 row_slack=cfg.row_slack)


def schedule(slots: list[OnlineSlot], jobs: list[OfflineJob],
             predictor: SpeedPredictor,
             cfg: SchedulerConfig = SchedulerConfig(),
             matcher=None) -> list[Assignment]:
    """Algorithm 1.  Returns the chosen assignments."""
    if not slots or not jobs:
        return []
    values, col_group, shares = build_weight_grid(slots, jobs, predictor, cfg)
    row_ids = np.array([s.device_id for s in slots], np.int64)
    pairs = solve_matching(values, col_group, cfg, row_ids=row_ids,
                           matcher=matcher)
    return [Assignment(device_id=slots[i].device_id, job_id=jobs[j].job_id,
                       sm_share=float(shares[i]),
                       predicted_tput=float(values[i, col_group[j]]))
            for i, j in pairs]
