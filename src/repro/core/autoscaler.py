"""Horizontal autoscaling for online services — the service-manager piece
the paper references ("deploys containers, discovers service, and autoscales
horizontal pods").

Reactive target-tracking: keep per-replica load (QPS / capacity) near a
target band with hysteresis and cooldown.  Interacts with MuxFlow: scaling
*down* frees whole devices to become Healthy share targets at the next
matching round; scaling *up* evicts the offline partner first (the same
SysMonitor-eviction path), so online capacity always wins.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class AutoscalerConfig:
    target_load: float = 0.6          # desired per-replica QPS/capacity
    upper: float = 0.8                # scale up above this
    lower: float = 0.35               # scale down below this
    min_replicas: int = 1
    max_replicas: int = 64
    cooldown_s: float = 300.0
    scale_down_stability_s: float = 600.0


@dataclasses.dataclass
class ScaleDecision:
    replicas: int
    delta: int
    reason: str


class Autoscaler:
    def __init__(self, cfg: AutoscalerConfig, replicas: int,
                 qps_capacity_per_replica: float):
        self.cfg = cfg
        self.replicas = replicas
        self.capacity = qps_capacity_per_replica
        self._last_scale_at = -math.inf
        self._below_since: float | None = None

    def observe(self, total_qps: float, now: float) -> ScaleDecision | None:
        cfg = self.cfg
        load = total_qps / max(self.replicas * self.capacity, 1e-9)
        if now - self._last_scale_at < cfg.cooldown_s:
            return None
        if load > cfg.upper:
            want = min(cfg.max_replicas,
                       max(self.replicas + 1,
                           math.ceil(total_qps / (self.capacity * cfg.target_load))))
            if want > self.replicas:
                delta = want - self.replicas
                self.replicas = want
                self._last_scale_at = now
                self._below_since = None
                return ScaleDecision(want, delta, f"load {load:.2f} > {cfg.upper}")
            return None
        if load < cfg.lower:
            if self._below_since is None:
                self._below_since = now
                return None
            if now - self._below_since < cfg.scale_down_stability_s:
                return None
            want = max(cfg.min_replicas,
                       math.ceil(total_qps / (self.capacity * cfg.target_load)))
            if want < self.replicas:
                delta = want - self.replicas
                self.replicas = want
                self._last_scale_at = now
                self._below_since = None
                return ScaleDecision(want, delta,
                                     f"load {load:.2f} < {cfg.lower} (stable)")
            return None
        self._below_since = None
        return None
