"""Dynamic SM allocation (§4.3, Fig. 8): the offline workload's SM share is
set complementary to the online workload's measured SM activity instead of a
fixed split — workload A at 20 % SM leaves 80 % for its offline partner,
workload B at 80 % leaves 20 %.
"""
from __future__ import annotations

import numpy as np


def _check_band(floor: float, cap: float, step: float) -> None:
    if not floor <= cap:
        raise ValueError(f"floor {floor} > cap {cap}")
    if not np.isfinite(step):
        raise ValueError(f"step must be finite, got {step}")


def dynamic_sm(online_sm_activity: float, *, headroom: float = 0.05,
               floor: float = 0.1, cap: float = 0.9,
               step: float = 0.1) -> float:
    """Complementary share: 1 − a_on − headroom, clipped to [floor, cap] and
    quantized to MPS-style `step` increments
    (CUDA_MPS_ACTIVE_THREAD_PERCENTAGE granularity).

    The result always lies in [floor, cap]; when quantization pushes the
    share past a band edge the edge wins, so with a band edge off the step
    grid the returned share can sit on the edge rather than the grid.
    """
    _check_band(floor, cap, step)
    share = 1.0 - float(online_sm_activity) - headroom
    share = max(floor, min(cap, share))
    if step > 0:
        share = round(share / step) * step
    return max(floor, min(cap, share))


def dynamic_sm_array(online_sm_activity, *, headroom: float = 0.05,
                     floor: float = 0.1, cap: float = 0.9,
                     step: float = 0.1) -> np.ndarray:
    """Vectorized :func:`dynamic_sm` over a fleet's activity array.  Mirrors
    the scalar operation order (same clip → half-even round → clip), so each
    element is bitwise-identical to the scalar call — pinned by a property
    test."""
    _check_band(floor, cap, step)
    share = 1.0 - np.asarray(online_sm_activity, np.float64) - headroom
    share = np.clip(share, floor, cap)
    if step > 0:
        share = np.round(share / step) * step
    return np.clip(share, floor, cap)


def fixed_sm(share: float = 0.4) -> float:
    """The MuxFlow-S ablation baseline: a fixed offline SM share."""
    return share
