"""Dynamic SM allocation (§4.3, Fig. 8): the offline workload's SM share is
set complementary to the online workload's measured SM activity instead of a
fixed split — workload A at 20 % SM leaves 80 % for its offline partner,
workload B at 80 % leaves 20 %.
"""
from __future__ import annotations


def dynamic_sm(online_sm_activity: float, *, headroom: float = 0.05,
               floor: float = 0.1, cap: float = 0.9,
               step: float = 0.1) -> float:
    """Complementary share: 1 − a_on − headroom, clipped to [floor, cap] and
    quantized to MPS-style `step` increments
    (CUDA_MPS_ACTIVE_THREAD_PERCENTAGE granularity)."""
    share = 1.0 - float(online_sm_activity) - headroom
    share = max(floor, min(cap, share))
    if step > 0:
        share = round(share / step) * step
    return max(floor, min(cap, share))


def fixed_sm(share: float = 0.4) -> float:
    """The MuxFlow-S ablation baseline: a fixed offline SM share."""
    return share
