"""Mixed error handling (§4.2) — safety protection for online workloads.

The paper's production error census (Fig. 7): ~99 % of propagated errors are
SIGINT/SIGTERM container stops; the rest are MPS server crashes, XID31 memory
page faults, and other MPS hangs.  MuxFlow therefore:

  * intercepts SIGINT/SIGTERM in the offline container, freezes kernel
    launches, and releases the CUDA context actively (graceful exit);
  * for the 1 % tail, matches error patterns with an automated detector and
    resets the context + MPS server.

`GracefulExit` is a real signal-handling harness (used by the multiplexer and
the serve example); `MixedErrorHandler` encodes the policy; the simulator
injects this taxonomy to measure propagation with/without the mechanism.
"""
from __future__ import annotations

import contextlib
import dataclasses
import enum
import signal


class ErrorKind(enum.Enum):
    SIGINT = "sigint"
    SIGTERM = "sigterm"
    MPS_SERVER_CRASH = "mps_server_crash"
    XID31_PAGE_FAULT = "xid31_page_fault"
    MPS_HANG = "mps_hang"


# Production proportions (Fig. 7): SIGINT+SIGTERM = 99 %.
ERROR_MIX: dict[ErrorKind, float] = {
    ErrorKind.SIGINT: 0.62,
    ErrorKind.SIGTERM: 0.37,
    ErrorKind.MPS_SERVER_CRASH: 0.004,
    ErrorKind.XID31_PAGE_FAULT: 0.003,
    ErrorKind.MPS_HANG: 0.003,
}


class Action(enum.Enum):
    GRACEFUL_EXIT = "graceful_exit"        # freeze launches + release context
    RESET_CONTEXT = "reset_context"        # reset CUDA context + MPS server


@dataclasses.dataclass
class HandledError:
    kind: ErrorKind
    action: Action
    propagated: bool          # did the shared online workload feel it?


class MixedErrorHandler:
    """Policy: signals → graceful exit (never propagates); pattern-matched
    tail errors → detector alert → context/MPS reset (brief online impact,
    matching the deployment's residual 0.9 % vs 0.7 % device error rate)."""

    SIGNAL_KINDS = (ErrorKind.SIGINT, ErrorKind.SIGTERM)

    def __init__(self, graceful_enabled: bool = True,
                 detector_enabled: bool = True):
        self.graceful_enabled = graceful_enabled
        self.detector_enabled = detector_enabled
        self.handled: list[HandledError] = []

    def handle(self, kind: ErrorKind) -> HandledError:
        if kind in self.SIGNAL_KINDS:
            if self.graceful_enabled:
                h = HandledError(kind, Action.GRACEFUL_EXIT, propagated=False)
            else:  # the un-protected baseline: MPS context hangs, online dies
                h = HandledError(kind, Action.RESET_CONTEXT, propagated=True)
        else:
            # tail errors: detector alerts, context reset; propagation only
            # if the detector is off (no automated pattern matching)
            h = HandledError(kind, Action.RESET_CONTEXT,
                             propagated=not self.detector_enabled)
        self.handled.append(h)
        return h

    def propagation_rate(self) -> float:
        if not self.handled:
            return 0.0
        return sum(1 for h in self.handled if h.propagated) / len(self.handled)


def error_from_uniform(u: float) -> ErrorKind:
    """Map a uniform [0,1) draw to an error kind per the production mix.
    Split out from :func:`sample_error` so the simulator engines can consume
    pre-drawn per-tick uniform vectors (keeps both engines on one RNG
    stream)."""
    kinds = list(ERROR_MIX)
    probs = [ERROR_MIX[k] for k in kinds]
    total = sum(probs)
    r = u * total
    acc = 0.0
    for k, p in zip(kinds, probs):
        acc += p
        if r <= acc:
            return k
    return kinds[-1]


def sample_error(rng) -> ErrorKind:
    return error_from_uniform(rng.random())


class GracefulExit:
    """Real SIGINT/SIGTERM interception for the offline process: on signal,
    freeze kernel launches (via the throttle), run the checkpoint callback,
    release resources, then exit cleanly.  Usable as a context manager.
    """

    def __init__(self, throttle=None, on_checkpoint=None, on_release=None):
        self.throttle = throttle
        self.on_checkpoint = on_checkpoint
        self.on_release = on_release
        self.triggered: ErrorKind | None = None
        self._prev: dict[int, object] = {}

    def _handler(self, signum, frame):
        self.triggered = (ErrorKind.SIGINT if signum == signal.SIGINT
                          else ErrorKind.SIGTERM)
        if self.throttle is not None:
            self.throttle.freeze()            # freeze all kernel launches
        if self.on_checkpoint is not None:
            self.on_checkpoint()              # persist offline progress
        if self.on_release is not None:
            self.on_release()                 # release the CUDA context

    def __enter__(self):
        for sig in (signal.SIGINT, signal.SIGTERM):
            self._prev[sig] = signal.signal(sig, self._handler)
        return self

    def __exit__(self, *exc):
        for sig, prev in self._prev.items():
            with contextlib.suppress(Exception):
                signal.signal(sig, prev)
        return False
