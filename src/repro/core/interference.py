"""Space-sharing interference model — the simulator's ground truth.

On real hardware this is what DCGM measures; here it is an analytic model of
SM and memory-bandwidth contention calibrated against the paper's Figure 4:

  * Fig 4(a): with a tuned SM split, one T4 yields up to +62 % extra offline
    compute while slowing the online workload < 20 %.
  * Fig 4(b): sweeping the offline SM share 10 %→100 % moves both workloads'
    normalized performance by > 5×.

The workload profile mirrors the paper's predictor features: GPU utilization,
SM activity, SM occupancy, and separate execution time.

This model is the *synthetic* ground truth.  Its measured counterpart —
:class:`repro.profiling.calibrate.MeasuredInterferenceProvider`, built from
executed workload pairs — is call-compatible with
:func:`shared_performance_arrays` and backs the ``muxflow-measured`` policy
and the ``calibrated`` cluster scenario.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Separate-execution profile (what the workload profiler measures)."""
    name: str
    gpu_util: float        # time-occupancy in [0,1]
    sm_activity: float     # space-occupancy in [0,1] (peak SM demand)
    sm_occupancy: float    # per-SM warp occupancy in [0,1]
    mem_bw: float          # HBM bandwidth fraction in [0,1]
    exec_time_ms: float    # iteration (or request) latency running alone
    mem_bytes_frac: float = 0.3   # GPU memory footprint fraction


# Model constants (calibrated; see benchmarks/fig4_sharing.py)
_SM_CONTENTION = 0.25      # online slowdown per unit instantaneous SM overlap
_BW_CONTENTION = 0.35      # slowdown per unit memory-bandwidth oversubscription
_MPS_OVERHEAD = 0.02       # fixed MPS time-slicing overhead when shared
_BASE_CONTENTION = 0.10    # cache/scheduler interference ~ offline SM use
_OFF_BW_SENS = 0.45        # offline sensitivity to bandwidth contention
_OFF_OVERLAP_SENS = 0.35   # offline tput loss per unit instantaneous overlap


def shared_performance(online: WorkloadProfile, offline: WorkloadProfile,
                       sm_off: float) -> tuple[float, float]:
    """Returns (online_slowdown >= 1, offline_norm_tput in [0,1]) when the
    pair shares one GPU with `sm_off` SM fraction assigned to the offline
    workload (CUDA_MPS_ACTIVE_THREAD_PERCENTAGE analogue)."""
    sm_off = float(np.clip(sm_off, 0.0, 1.0))
    a_on = online.sm_activity                     # time-avg SM demand
    used_off = min(sm_off, offline.sm_activity)   # offline uses what it needs
    # while an online kernel is executing, its instantaneous SM demand is
    # duty-cycle corrected (avg activity / time occupancy)
    inst_on = min(1.0, a_on / max(online.gpu_util, 0.05))
    overlap_inst = max(0.0, inst_on + used_off - 1.0)
    overlap_avg = overlap_inst * online.gpu_util
    # memory bandwidth contention
    bw_off = offline.mem_bw * (used_off / max(offline.sm_activity, 1e-6))
    bw_over = max(0.0, online.mem_bw * online.gpu_util + bw_off - 1.0)
    # used_off^1.5 spelled as x*sqrt(x): sqrt is IEEE-correctly-rounded on
    # every backend (numpy, XLA CPU), unlike libm pow — this keeps the
    # compiled tick engine bitwise-aligned with the numpy engines
    online_slowdown = (1.0 + _MPS_OVERHEAD
                       + _BASE_CONTENTION * used_off * np.sqrt(used_off)
                       + _SM_CONTENTION * overlap_inst / max(inst_on, 0.05)
                       + _BW_CONTENTION * bw_over / max(online.mem_bw, 0.05))
    # offline throughput: what it gets of its demand, minus contention losses
    eff = used_off - 0.5 * overlap_avg
    tput = eff / max(offline.sm_activity, 1e-6)
    tput *= 1.0 / (1.0 + _OFF_OVERLAP_SENS * overlap_inst
                   + _OFF_BW_SENS * bw_over / max(offline.mem_bw, 0.05))
    tput *= (1.0 - _MPS_OVERHEAD)
    return float(online_slowdown), float(np.clip(tput, 0.0, 1.0))


def memory_feasible(online: WorkloadProfile, offline: WorkloadProfile,
                    quota: float = 0.4) -> bool:
    """xCUDA memory-quota check: offline must fit its quota AND the sum must
    fit the device (the paper fixes the offline quota to 40 %)."""
    return (offline.mem_bytes_frac <= quota
            and online.mem_bytes_frac + offline.mem_bytes_frac <= 0.98)


def qps_to_activity(qps: float, qps_capacity: float, peak_sm: float) -> float:
    """Map request rate to online SM activity (saturating)."""
    x = qps / max(qps_capacity, 1e-6)
    return peak_sm * (1.0 - math.exp(-1.6 * x))


# Profiles for the paper's four offline DL models (T4-class numbers) plus a
# few online-service archetypes.  Values follow the published relative speeds
# (VGG16 bandwidth-heavy, Inception compute-light, etc.).
OFFLINE_MODEL_PROFILES = {
    "ResNet50": WorkloadProfile("ResNet50", 0.95, 0.72, 0.55, 0.55, 180.0, 0.18),
    "VGG16": WorkloadProfile("VGG16", 0.97, 0.80, 0.60, 0.75, 300.0, 0.22),
    "DenseNet201": WorkloadProfile("DenseNet201", 0.93, 0.66, 0.45, 0.60, 260.0, 0.20),
    "Inception-V3": WorkloadProfile("Inception-V3", 0.90, 0.58, 0.42, 0.45, 210.0, 0.16),
}

# Calibrated so the online-only fleet averages match the paper's Fig. 15
# baselines: GPU util ~26 %, SM activity ~16 %, memory ~42 %.
ONLINE_SERVICE_PROFILES = {
    "recommend": dict(peak_sm=0.30, mem_bw=0.35, qps_capacity=150.0,
                      base_latency_ms=38.0, mem_bytes_frac=0.42),
    "translate": dict(peak_sm=0.38, mem_bw=0.42, qps_capacity=90.0,
                      base_latency_ms=55.0, mem_bytes_frac=0.45),
    "vision": dict(peak_sm=0.46, mem_bw=0.48, qps_capacity=60.0,
                   base_latency_ms=70.0, mem_bytes_frac=0.40),
}


def online_profile_consts(service_idx: np.ndarray,
                          services: tuple[str, ...]) -> dict[str, np.ndarray]:
    """Per-device service-constant gathers for :func:`online_profile_arrays`.

    ``service_idx`` is fixed for a fleet's lifetime, so engines compute this
    once instead of re-gathering five constant arrays every tick."""
    def const(key):
        return np.array([ONLINE_SERVICE_PROFILES[s][key] for s in services],
                        np.float64)[service_idx]

    consts = {k: const(k) for k in ("qps_capacity", "peak_sm", "mem_bw",
                                    "base_latency_ms", "mem_bytes_frac")}
    for arr in consts.values():
        # these arrays are cached for a fleet's lifetime and two of them
        # are handed out verbatim every tick (exec_time_ms,
        # mem_bytes_frac); freeze them so a misbehaving policy mutating
        # its inputs fails loudly instead of corrupting every later tick
        arr.flags.writeable = False
    return consts


def online_profile_arrays(service_idx: np.ndarray, qps: np.ndarray,
                          services: tuple[str, ...],
                          consts: dict[str, np.ndarray] | None = None,
                          ) -> dict[str, np.ndarray]:
    """Vectorized :func:`online_profile` over a fleet.

    ``service_idx[i]`` indexes into ``services``; returns a dict of per-device
    arrays with the same fields as :class:`WorkloadProfile`.  The arithmetic
    mirrors the scalar function operation-for-operation so values agree
    bitwise with per-device calls.  Pass a precomputed ``consts`` (from
    :func:`online_profile_consts`) to skip the per-call constant gathers on
    hot paths — the values are identical either way.
    """
    if consts is None:
        consts = online_profile_consts(service_idx, services)
    cap = consts["qps_capacity"]
    peak = consts["peak_sm"]
    x = qps / cap
    act = peak * (1.0 - np.exp(-1.6 * (qps / np.maximum(cap, 1e-6))))
    util = np.clip(0.08 + 0.40 * x, 0.0, 1.0)
    return {
        "gpu_util": util,
        "sm_activity": act,
        "sm_occupancy": 0.35 + 0.3 * act,
        "mem_bw": consts["mem_bw"] * util,
        "exec_time_ms": consts["base_latency_ms"],
        "mem_bytes_frac": consts["mem_bytes_frac"],
    }


def instantaneous_sm_demand(sm_activity: np.ndarray,
                            gpu_util: np.ndarray) -> np.ndarray:
    """Duty-cycle-corrected instantaneous SM demand: while a kernel is
    executing, its SM demand is the time-averaged activity divided by the
    time occupancy (floored at 0.05), capped at 1.  The single home for this
    correction — the interference model and the sharing policies that reason
    about spatial slack (tally-priority, static-partition) all use it."""
    return np.minimum(1.0, sm_activity / np.maximum(gpu_util, 0.05))


def shared_performance_arrays(on: dict[str, np.ndarray],
                              off: dict[str, np.ndarray],
                              sm_off: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized :func:`shared_performance`: elementwise over per-device
    online/offline profile arrays.  Mirrors the scalar operation order."""
    sm_off = np.clip(sm_off, 0.0, 1.0)
    a_on = on["sm_activity"]
    used_off = np.minimum(sm_off, off["sm_activity"])
    inst_on = instantaneous_sm_demand(a_on, on["gpu_util"])
    overlap_inst = np.maximum(0.0, inst_on + used_off - 1.0)
    overlap_avg = overlap_inst * on["gpu_util"]
    bw_off = off["mem_bw"] * (used_off / np.maximum(off["sm_activity"], 1e-6))
    bw_over = np.maximum(0.0, on["mem_bw"] * on["gpu_util"] + bw_off - 1.0)
    online_slowdown = (1.0 + _MPS_OVERHEAD
                       + _BASE_CONTENTION * used_off * np.sqrt(used_off)
                       + _SM_CONTENTION * overlap_inst / np.maximum(inst_on, 0.05)
                       + _BW_CONTENTION * bw_over / np.maximum(on["mem_bw"], 0.05))
    eff = used_off - 0.5 * overlap_avg
    tput = eff / np.maximum(off["sm_activity"], 1e-6)
    tput = tput * (1.0 / (1.0 + _OFF_OVERLAP_SENS * overlap_inst
                          + _OFF_BW_SENS * bw_over / np.maximum(off["mem_bw"], 0.05)))
    tput = tput * (1.0 - _MPS_OVERHEAD)
    return online_slowdown, np.clip(tput, 0.0, 1.0)


def offline_profile_arrays(model_idx: np.ndarray,
                           models: tuple[str, ...]) -> dict[str, np.ndarray]:
    """Per-device offline profile arrays from a model-index array (devices
    without a job may carry any index; mask downstream)."""
    def const(attr):
        return np.array([getattr(OFFLINE_MODEL_PROFILES[m], attr)
                         for m in models], np.float64)[model_idx]

    return {k: const(k) for k in ("gpu_util", "sm_activity", "sm_occupancy",
                                  "mem_bw", "exec_time_ms", "mem_bytes_frac")}


def online_profile(service: str, qps: float) -> WorkloadProfile:
    s = ONLINE_SERVICE_PROFILES[service]
    x = qps / s["qps_capacity"]
    act = qps_to_activity(qps, s["qps_capacity"], s["peak_sm"])
    util = float(np.clip(0.08 + 0.40 * x, 0.0, 1.0))
    return WorkloadProfile(
        name=service, gpu_util=util, sm_activity=act,
        sm_occupancy=0.35 + 0.3 * act, mem_bw=s["mem_bw"] * util,
        exec_time_ms=s["base_latency_ms"], mem_bytes_frac=s["mem_bytes_frac"])
