"""Maximum-weight bipartite matching via the Kuhn–Munkres (Hungarian)
algorithm — the paper's scheduler core (§5), O(|V|³).

`km_match(weights)` maximizes total weight over a (possibly rectangular)
weight matrix; unmatched rows/cols are allowed (padding with zero weight —
an offline workload may stay pending, a GPU may stay unshared, exactly the
paper's semantics where every edge weight = predicted normalized throughput
≥ 0).

Implementation: Jonker–Volgenant shortest-augmenting-path with potentials
(numpy-vectorized inner loop), the standard exact O(n³) form of KM.
"""
from __future__ import annotations

import itertools

import numpy as np


def _jv_min_assign(cost: np.ndarray) -> np.ndarray:
    """Minimum-cost perfect assignment on a square matrix.
    Returns col_of_row (n,).  O(n^3)."""
    n = cost.shape[0]
    INF = np.inf
    u = np.zeros(n + 1)
    v = np.zeros(n + 1)
    p = np.zeros(n + 1, dtype=np.int64)          # p[j] = row matched to col j
    way = np.zeros(n + 1, dtype=np.int64)
    # 1-indexed internally; column 0 is virtual
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(n + 1, INF)
        used = np.zeros(n + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            # vectorized relaxation over unused columns 1..n
            free = ~used[1:]
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            better = free & (cur < minv[1:])
            minv[1:] = np.where(better, cur, minv[1:])
            way[1:][better] = j0
            # find delta over free columns
            masked = np.where(free, minv[1:], INF)
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            # update potentials
            u[p[used]] += delta
            v[used] -= delta
            minv[1:][free] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        # augment along the path
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    col_of_row = np.zeros(n, dtype=np.int64)
    for j in range(1, n + 1):
        if p[j] > 0:
            col_of_row[p[j] - 1] = j - 1
    return col_of_row


def km_match(weights: np.ndarray) -> list[tuple[int, int]]:
    """Maximum-weight matching.  weights: (n_online, n_offline), >= 0.
    Returns [(row, col), ...] for matched pairs with weight > 0."""
    w = np.asarray(weights, dtype=np.float64)
    if w.size == 0:
        return []
    n_r, n_c = w.shape
    n = max(n_r, n_c)
    pad = np.zeros((n, n))
    pad[:n_r, :n_c] = w
    cost = w.max() - pad if w.size else pad      # maximize -> minimize
    col_of_row = _jv_min_assign(cost)
    out = []
    for r in range(n_r):
        c = int(col_of_row[r])
        if c < n_c and pad[r, c] > 0:
            out.append((r, c))
    return out


def matching_weight(weights: np.ndarray, pairs: list[tuple[int, int]]) -> float:
    return float(sum(weights[r, c] for r, c in pairs))


def brute_force_match(weights: np.ndarray) -> float:
    """Exponential oracle for tests (n <= ~8): best total weight over all
    injective partial assignments."""
    w = np.asarray(weights, dtype=np.float64)
    n_r, n_c = w.shape
    best = 0.0
    cols = list(range(n_c))
    k = min(n_r, n_c)
    for rows in itertools.combinations(range(n_r), k):
        for perm in itertools.permutations(cols, k):
            s = sum(max(w[r, c], 0.0) for r, c in zip(rows, perm))
            best = max(best, s)
    return best
