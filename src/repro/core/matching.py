"""Maximum-weight bipartite matching via the Kuhn–Munkres (Hungarian)
algorithm — the paper's scheduler core (§5), O(|V|³).

`km_match(weights)` maximizes total weight over a (possibly rectangular)
weight matrix; unmatched rows/cols are allowed (padding with zero weight —
an offline workload may stay pending, a GPU may stay unshared, exactly the
paper's semantics where every edge weight = predicted normalized throughput
≥ 0).

Implementation: Jonker–Volgenant shortest-augmenting-path with potentials
(numpy-vectorized inner loop), the standard exact O(n³) form of KM.
"""
from __future__ import annotations

import hashlib
import itertools

import numpy as np

try:  # optional C-implemented backend (declared in the dev extra)
    from scipy.optimize import linear_sum_assignment as _scipy_lsa
except Exception:  # pragma: no cover - exercised only without scipy
    _scipy_lsa = None


def _jv_min_assign(cost: np.ndarray) -> np.ndarray:
    """Minimum-cost assignment of every row to a distinct column on a
    rectangular matrix with n_rows <= n_cols.  Returns col_of_row (n_rows,).
    O(n_rows² · n_cols) — the square case is the classic O(n³) form."""
    n_r, n_c = cost.shape
    assert n_r <= n_c
    INF = np.inf
    u = np.zeros(n_r + 1)
    v = np.zeros(n_c + 1)
    p = np.zeros(n_c + 1, dtype=np.int64)        # p[j] = row matched to col j
    way = np.zeros(n_c + 1, dtype=np.int64)
    # 1-indexed internally; column 0 is virtual
    for i in range(1, n_r + 1):
        p[0] = i
        j0 = 0
        minv = np.full(n_c + 1, INF)
        used = np.zeros(n_c + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            # vectorized relaxation over unused columns 1..n_c
            free = ~used[1:]
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            better = free & (cur < minv[1:])
            minv[1:] = np.where(better, cur, minv[1:])
            way[1:][better] = j0
            # find delta over free columns
            masked = np.where(free, minv[1:], INF)
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            # update potentials
            u[p[used]] += delta
            v[used] -= delta
            minv[1:][free] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        # augment along the path
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    col_of_row = np.zeros(n_r, dtype=np.int64)
    for j in range(1, n_c + 1):
        if p[j] > 0:
            col_of_row[p[j] - 1] = j - 1
    return col_of_row


def km_match(weights: np.ndarray) -> list[tuple[int, int]]:
    """Maximum-weight matching.  weights: (n_online, n_offline), >= 0.
    Returns [(row, col), ...] for matched pairs with weight > 0.

    The rectangular problem is solved natively on its short side (the long
    side is never padded to square — padding buries the solver in identical
    zero-weight dummy columns and turns e.g. a 2000×100 instance into a
    2000³ one).  When scipy is importable its C implementation of the same
    algorithm is used — the pure-numpy JV below is the reference fallback,
    and it degrades badly on the scheduler's tie-heavy shards (only a
    handful of distinct weight columns at paper scale)."""
    w = np.asarray(weights, dtype=np.float64)
    if w.size == 0:
        return []
    if _scipy_lsa is not None:
        ri, ci = _scipy_lsa(w, maximize=True)
        return [(int(r), int(c)) for r, c in zip(ri, ci) if w[r, c] > 0]
    n_r, n_c = w.shape
    transposed = n_r > n_c
    a = w.T if transposed else w
    cost = a.max() - a                           # maximize -> minimize
    col_of_row = _jv_min_assign(cost)
    out = []
    for r in range(a.shape[0]):
        c = int(col_of_row[r])
        if a[r, c] > 0:
            out.append((c, r) if transposed else (r, c))
    return sorted(out) if transposed else out


def matching_weight(weights: np.ndarray, pairs: list[tuple[int, int]]) -> float:
    return float(sum(weights[r, c] for r, c in pairs))


# ---------------------------------------------------------------------------
# Partitioned (sharded) matching for paper-scale clusters
# ---------------------------------------------------------------------------
#
# At n = 20 000 devices a dense KM round is O(n³) and unusable.  The paper
# schedules per cluster partition anyway (§5), so we split the bipartite
# problem into bounded-size shards and solve each exactly.  Two structural
# reductions keep this near-optimal:
#
#   * offline jobs of the same model produce *identical* weight columns, so
#     column counts can be capped at the number of matchable pairs and each
#     shard can be dealt a proportional mix of every column group;
#   * an optimal matching touches at most min(n, m) devices, and (by a simple
#     exchange argument) there is always an optimum inside the union of each
#     column-group's top-min(n, m) devices — everything else is pruned.


def _group_duplicate_columns(weights: np.ndarray,
                             decimals: int = 12) -> tuple[np.ndarray, np.ndarray]:
    """Returns (values (n, u), col_group (m,)) where u is the number of
    distinct columns (rounded to `decimals`)."""
    w = np.round(weights, decimals)
    groups: dict[bytes, int] = {}
    col_group = np.empty(w.shape[1], np.int64)
    firsts: list[int] = []
    for j in range(w.shape[1]):
        key = w[:, j].tobytes()
        g = groups.get(key)
        if g is None:
            g = groups[key] = len(firsts)
            firsts.append(j)
        col_group[j] = g
    return weights[:, firsts].astype(np.float64, copy=True), col_group


def _prune_row_heavy(vals: np.ndarray, rows_s: np.ndarray,
                     grp_s: np.ndarray, row_slack: int) -> np.ndarray:
    """Row-heavy shard pruning shared by the compact and incremental
    matchers: keep per group only the strongest (group count + slack) rows
    — KM pads rectangular problems to the max dimension, so near-square
    shards are critical."""
    keep_mask = np.zeros(rows_s.size, bool)
    for g in np.unique(grp_s):
        kk = min(rows_s.size, int((grp_s == g).sum()) + row_slack)
        col_vals = vals[rows_s, g]
        keep_mask[np.argpartition(-col_vals, kk - 1)[:kk]] = True
    return rows_s[keep_mask]


def _greedy_repair(vals: np.ndarray, col_group: np.ndarray,
                   keep_cols: list[np.ndarray], cand: np.ndarray,
                   out: list[tuple[int, int]], row_used: np.ndarray,
                   col_used: np.ndarray) -> None:
    """Patch rows/columns the shard partition stranded (shared by the
    compact and incremental matchers); appends to ``out`` in place."""
    n = row_used.shape[0]
    free_rows = np.flatnonzero(~row_used & np.isin(np.arange(n), cand))
    if not free_rows.size:
        return
    for cols_g in keep_cols:
        for c in cols_g:
            if col_used[c]:
                continue
            g = col_group[c]
            best = int(np.argmax(vals[free_rows, g]))
            if vals[free_rows[best], g] > 0.0:
                r = int(free_rows[best])
                out.append((r, int(c)))
                row_used[r] = True
                col_used[c] = True
                free_rows = np.delete(free_rows, best)
                if free_rows.size == 0:
                    return
        if free_rows.size == 0:
            return


def sharded_match_compact(values: np.ndarray, col_group: np.ndarray, *,
                          shard_size: int = 256, min_weight: float = 0.0,
                          row_slack: int = 16,
                          greedy_repair: bool = True) -> list[tuple[int, int]]:
    """Sharded maximum-weight matching on the compact form.

    ``values``: (n_rows, u) — weight of pairing row i with any column of
    group g (columns inside a group are identical/interchangeable).
    ``col_group``: (m,) — group id per real column.  Returns real
    (row, col) pairs.  Never materializes the dense (n × m) matrix, so it
    stays cheap at 20k devices × thousands of jobs.
    """
    values = np.asarray(values, np.float64)
    col_group = np.asarray(col_group, np.int64)
    n, u = values.shape
    m = col_group.shape[0]
    if n == 0 or m == 0:
        return []
    vals = values.copy()
    if min_weight > 0.0:
        vals[vals < min_weight] = 0.0
    cap = min(n, m)
    # FIFO column cap per group: at most `cap` columns of a group can match
    keep_cols = [np.flatnonzero(col_group == g)[:cap] for g in range(u)]
    kept = int(sum(len(c) for c in keep_cols))
    # candidate rows: union of per-group top-k (k = matchable pairs)
    k = min(n, kept)
    if n > k:
        cand_mask = np.zeros(n, bool)
        for g in range(u):
            cand_mask[np.argpartition(-vals[:, g], k - 1)[:k]] = True
        cand = np.flatnonzero(cand_mask)
    else:
        cand = np.arange(n)
    size = max(len(cand), kept)
    if size <= shard_size:                       # small enough: one exact KM
        cols = np.sort(np.concatenate(keep_cols))
        pairs = km_match(vals[np.ix_(cand, np.arange(u))][:, col_group[cols]])
        return sorted((int(cand[r]), int(cols[c])) for r, c in pairs)
    n_shards = -(-size // shard_size)
    # deal rows and each group's columns round-robin so every shard sees a
    # proportional device/model mix; rows are stratified by preferred group
    # (then strength) so no shard is starved of devices that favor a model
    pref = np.argmax(vals[cand], axis=1)
    row_order = cand[np.lexsort((-vals[cand].max(axis=1), pref))]
    row_shards = [row_order[s::n_shards] for s in range(n_shards)]
    col_shards: list[list[int]] = [[] for _ in range(n_shards)]
    for g in range(u):
        for j, c in enumerate(keep_cols[g]):
            col_shards[(j + g) % n_shards].append(int(c))
    out: list[tuple[int, int]] = []
    row_used = np.zeros(n, bool)
    col_used = np.zeros(m, bool)
    for s in range(n_shards):
        rows_s, cols_s = row_shards[s], np.asarray(col_shards[s], np.int64)
        if rows_s.size == 0 or cols_s.size == 0:
            continue
        grp_s = col_group[cols_s]
        rows_k = (_prune_row_heavy(vals, rows_s, grp_s, row_slack)
                  if rows_s.size > 2 * cols_s.size else rows_s)
        pairs = km_match(vals[rows_k[:, None], grp_s[None, :]])
        for r, c in pairs:
            out.append((int(rows_k[r]), int(cols_s[c])))
            row_used[rows_k[r]] = True
            col_used[cols_s[c]] = True
    if greedy_repair:
        # shards can strand a few rows/columns; greedily patch the remainder
        _greedy_repair(vals, col_group, keep_cols, cand, out, row_used,
                       col_used)
    return sorted(out)


def sharded_match(weights: np.ndarray, *, shard_size: int = 256,
                  min_weight: float = 0.0, row_slack: int = 16,
                  greedy_repair: bool = True) -> list[tuple[int, int]]:
    """Sharded maximum-weight matching on an explicit weight matrix.

    Equivalent to :func:`km_match` (exact) whenever the problem fits in one
    shard; at larger sizes it partitions into bounded sub-problems and stays
    within ~1 % of the dense optimum on scheduler-shaped instances (few
    distinct column groups).  Weights below ``min_weight`` are pruned to 0.
    """
    w = np.asarray(weights, np.float64)
    if w.size == 0:
        return []
    if min_weight > 0.0:
        w = w.copy()
        w[w < min_weight] = 0.0
    if max(w.shape) <= shard_size:
        return sorted(km_match(w))
    values, col_group = _group_duplicate_columns(w)
    return sharded_match_compact(values, col_group, shard_size=shard_size,
                                 row_slack=row_slack,
                                 greedy_repair=greedy_repair)


# ---------------------------------------------------------------------------
# Incremental (warm-started) sharded matching
# ---------------------------------------------------------------------------


def _stable_row_hash(ids: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (splitmix64 finalizer) of row/device ids —
    the shard deal must depend only on the id, never on round-varying
    values, so that a device keeps its shard across scheduling rounds."""
    x = np.asarray(ids, np.uint64).copy()
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


class IncrementalMatcher:
    """Warm-started sharded maximum-weight matching, exact by construction.

    The scheduler re-solves the (free devices × pending jobs) matching every
    round even though, in steady state, most of the bipartite problem is
    unchanged: the same devices are free with the same (quantized) weight
    rows, and the backlog's per-model column counts are stable.  This
    matcher persists per-shard solutions across rounds:

    * rows (devices) are dealt to shards by a **stable hash of their id** —
      not by round-varying value orderings — so a device's shard never
      changes while the shard count is stable;
    * each group's columns are dealt round-robin exactly like
      :func:`sharded_match_compact`, and within a shard only the *count*
      per group matters (columns of a group are interchangeable);
    * a shard's sub-problem is keyed by its exact content (row ids, their
      weight rows, the dealt group layout).  A key hit replays the stored
      local solution; a miss solves the shard with exact KM.  Either way
      the result is **identical to a cold solve of the current inputs** —
      the cache can only skip recomputation of an identical sub-problem,
      never change an answer — which is what lets both simulator engines
      (and the warm-vs-cold tests) rely on bitwise-equal assignments.

    When the dirty fraction (key misses / non-empty shards) exceeds
    ``full_solve_dirty_frac`` the round is treated as a full re-solve and
    the cache is rebuilt from scratch; the cache always holds exactly the
    previous round's shards, so memory is bounded by one round.
    """

    def __init__(self, *, shard_size: int = 256, row_slack: int = 16,
                 greedy_repair: bool = True,
                 full_solve_dirty_frac: float = 0.5):
        self.shard_size = shard_size
        self.row_slack = row_slack
        self.greedy_repair = greedy_repair
        self.full_solve_dirty_frac = full_solve_dirty_frac
        self._cache: dict[bytes, list[tuple[int, int]]] = {}
        self._n_shards: int | None = None
        # counters for benchmarks/telemetry
        self.rounds = 0
        self.shards_solved = 0
        self.shards_reused = 0
        self.full_solves = 0

    # ------------------------------------------------------------------ api
    def match(self, values: np.ndarray, col_group: np.ndarray,
              row_ids: np.ndarray, *, shard_size: int | None = None,
              row_slack: int | None = None) -> list[tuple[int, int]]:
        """Maximum-weight matching on the compact form (see
        :func:`sharded_match_compact`); returns real (row, col) pairs.
        ``row_ids`` are stable per-row identities (device ids).  Callers
        with a per-round :class:`SchedulerConfig` pass its
        ``shard_size``/``row_slack`` so policy settings are honored (stale
        cache entries keyed under other settings simply miss)."""
        if shard_size is not None:
            self.shard_size = shard_size
        if row_slack is not None:
            self.row_slack = row_slack
        vals = np.asarray(values, np.float64)
        col_group = np.asarray(col_group, np.int64)
        row_ids = np.asarray(row_ids, np.int64)
        n, u = vals.shape
        m = col_group.shape[0]
        if n == 0 or m == 0:
            return []
        self.rounds += 1
        cap = min(n, m)
        keep_cols = [np.flatnonzero(col_group == g)[:cap] for g in range(u)]
        kept = int(sum(len(c) for c in keep_cols))
        # candidate rows: union of per-group top-k (argpartition is a pure
        # function of the value array, so identical rounds key identically)
        k = min(n, kept)
        if n > k:
            cand_mask = np.zeros(n, bool)
            for g in range(u):
                cand_mask[np.argpartition(-vals[:, g], k - 1)[:k]] = True
            cand = np.flatnonzero(cand_mask)
        else:
            cand = np.arange(n)
        size = max(len(cand), kept)
        if size <= self.shard_size:                 # small: one exact KM
            cols = np.sort(np.concatenate(keep_cols))
            pairs = km_match(vals[np.ix_(cand, np.arange(u))]
                             [:, col_group[cols]])
            return sorted((int(cand[r]), int(cols[c])) for r, c in pairs)
        n_shards = -(-size // self.shard_size)
        if n_shards != self._n_shards:
            self._cache.clear()
            self._n_shards = n_shards
        shard_of = _stable_row_hash(row_ids[cand]) % np.uint64(n_shards)
        col_shards: list[list[int]] = [[] for _ in range(n_shards)]
        for g in range(u):
            for j, c in enumerate(keep_cols[g]):
                col_shards[(j + g) % n_shards].append(int(c))
        # plan every shard first so the dirty fraction is known up front
        plans = []
        n_dirty = 0
        for s in range(n_shards):
            rows_s = cand[shard_of == np.uint64(s)]
            cols_s = np.asarray(col_shards[s], np.int64)
            if rows_s.size == 0 or cols_s.size == 0:
                continue
            grp_s = col_group[cols_s]
            rows_k = (_prune_row_heavy(vals, rows_s, grp_s, self.row_slack)
                      if rows_s.size > 2 * cols_s.size else rows_s)
            key = hashlib.blake2b(
                row_ids[rows_k].tobytes() + b"|" + vals[rows_k].tobytes()
                + b"|" + grp_s.tobytes(), digest_size=16).digest()
            cached = self._cache.get(key)
            if cached is None:
                n_dirty += 1
            plans.append((key, rows_k, cols_s, grp_s, cached))
        if plans and n_dirty / len(plans) > self.full_solve_dirty_frac:
            # mostly-changed round: rebuild from scratch
            self._cache.clear()
            self.full_solves += 1
            plans = [(key, rows_k, cols_s, grp_s, None)
                     for key, rows_k, cols_s, grp_s, _ in plans]
        out: list[tuple[int, int]] = []
        row_used = np.zeros(n, bool)
        col_used = np.zeros(m, bool)
        new_cache: dict[bytes, list[tuple[int, int]]] = {}
        for key, rows_k, cols_s, grp_s, cached in plans:
            if cached is None:
                # local pairs are stored positionally: (row slot, col slot)
                # — the key pins the rows and the group layout, and columns
                # of a group are interchangeable, so replaying positions on
                # this round's column ids reproduces a cold solve exactly
                cached = km_match(vals[rows_k[:, None], grp_s[None, :]])
                self.shards_solved += 1
            else:
                self.shards_reused += 1
            new_cache[key] = cached
            for r, c in cached:
                out.append((int(rows_k[r]), int(cols_s[c])))
                row_used[rows_k[r]] = True
                col_used[cols_s[c]] = True
        self._cache = new_cache
        if self.greedy_repair:
            # shards can strand a few rows/columns; greedily patch the rest
            _greedy_repair(vals, col_group, keep_cols, cand, out, row_used,
                           col_used)
        return sorted(out)

    def stats(self) -> dict:
        return {"rounds": self.rounds, "shards_solved": self.shards_solved,
                "shards_reused": self.shards_reused,
                "full_solves": self.full_solves,
                "cached_shards": len(self._cache)}


def brute_force_match(weights: np.ndarray) -> float:
    """Exponential oracle for tests (n <= ~8): best total weight over all
    injective partial assignments."""
    w = np.asarray(weights, dtype=np.float64)
    n_r, n_c = w.shape
    best = 0.0
    cols = list(range(n_c))
    k = min(n_r, n_c)
    for rows in itertools.combinations(range(n_r), k):
        for perm in itertools.permutations(cols, k):
            s = sum(max(w[r, c], 0.0) for r, c in zip(rows, perm))
            best = max(best, s)
    return best
