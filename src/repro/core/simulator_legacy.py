"""The original per-device cluster simulator, kept as the reference engine.

This is the seed implementation of the trace-driven simulator: one Python
object per device, one Python loop iteration per device per tick.  It is
O(n_devices) interpreted work per tick and unusable at paper scale, but its
per-device control flow is easy to audit — so it stays as the ground truth
that the vectorized engine in ``core/simulator.py`` is pinned against by a
fixed-seed parity test.

Two deliberate deviations from the seed version keep the two engines
bit-reproducible against each other:

  * per-tick randomness is drawn as one ``(3, n_devices)`` uniform block
    (hardware-failure, error, error-kind rows) instead of ad-hoc scalar
    draws, and the error kind is derived via
    :func:`repro.core.errors.error_from_uniform`;
  * QPS curves and online profiles are read from the shared vectorized
    providers (:class:`repro.core.traces.QPSBank`,
    :func:`repro.core.interference.online_profile_arrays`) so both engines
    see bitwise-identical trace inputs (numpy and libm transcendentals can
    differ in the last ULP).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.errors import MixedErrorHandler, error_from_uniform
from repro.core.interference import (OFFLINE_MODEL_PROFILES, WorkloadProfile,
                                     memory_feasible, online_profile,
                                     online_profile_arrays, shared_performance)
from repro.core.predictor import SpeedPredictor
from repro.core.protection import DeviceTelemetry
from repro.core.scheduler import (OfflineJob, OnlineSlot, SchedulerConfig,
                                  schedule)
from repro.core.simulator import _BASE_LATENCY_MS, SimConfig, SimResults
from repro.core.sysmonitor import SysMonitor
from repro.core.traces import SERVICES, OfflineJobSpec, OnlineQPS, QPSBank, make_trace
from repro.policies import resolve as resolve_policy

# the seven policies this reference engine implements per-device; newer
# registry policies are vectorized-engine-only (nothing pins them here)
_REFERENCE_POLICIES = ("muxflow", "muxflow-s", "muxflow-m", "muxflow-s-m",
                       "online-only", "time-sharing", "pb-time-sharing")


@dataclasses.dataclass
class _Device:
    idx: int
    gpu_type: str
    service: str
    service_idx: int
    monitor: SysMonitor
    job: "_RunningJob | None" = None
    failed_until: float = -1.0
    online_outage_until: float = -1.0
    base_latency_ms: float = 50.0
    speed: float = 1.0                         # A10 runs offline 1.35x faster


@dataclasses.dataclass
class _RunningJob:
    spec: OfflineJobSpec
    progress_s: float                          # in separate-execution seconds
    checkpoint_s: float                        # last checkpointed progress
    sm_share: float
    started_at: float
    shared_wall_s: float = 0.0                 # wall seconds on a device


class LegacyClusterSim:
    def __init__(self, cfg: SimConfig, predictor: SpeedPredictor | None = None):
        pol = resolve_policy(cfg.policy)
        if pol.name not in _REFERENCE_POLICIES:
            raise ValueError(
                f"reference engine implements only {_REFERENCE_POLICIES}, "
                f"got {pol.name!r}")
        self._pol_name = pol.name
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        if pol.needs_predictor and predictor is None:
            raise ValueError(f"policy {pol.name!r} needs a speed predictor")
        if predictor is not None and cfg.predictor_cache_quantum > 0:
            # mirror the vectorized engine's memoization so both engines
            # schedule on identical (quantized) predictions
            from repro.core.predictor import CachedSpeedPredictor
            predictor = CachedSpeedPredictor(
                predictor, quantum=cfg.predictor_cache_quantum)
        self.predictor = predictor
        self.qps_bank = QPSBank([OnlineQPS(self.rng)
                                 for _ in range(cfg.n_devices)])
        self.devices = [
            _Device(
                idx=i,
                gpu_type=cfg.gpu_types[i % len(cfg.gpu_types)],
                service=SERVICES[i % len(SERVICES)],
                service_idx=i % len(SERVICES),
                monitor=SysMonitor(now=0.0),
                base_latency_ms=_BASE_LATENCY_MS[SERVICES[i % len(SERVICES)]],
                speed=1.35 if cfg.gpu_types[i % len(cfg.gpu_types)] == "A10" else 1.0,
            )
            for i in range(cfg.n_devices)
        ]
        self.models = tuple(OFFLINE_MODEL_PROFILES)
        self.feasible = {
            (svc, m): memory_feasible(online_profile(svc, 50.0),
                                      OFFLINE_MODEL_PROFILES[m],
                                      cfg.memory_quota)
            for svc in SERVICES for m in self.models}
        self.jobs = make_trace(cfg.trace, cfg.n_devices, cfg.horizon_s, cfg.seed)
        self.pending: list[OfflineJobSpec] = []
        self.err_handler = MixedErrorHandler(graceful_enabled=cfg.graceful_exit)
        self.finished: list[tuple] = []            # (spec, jct, wall, progress)
        self.evictions = 0
        self.executions = 0
        self.errors_injected = 0
        self.online_incidents = 0
        # accumulators
        self._lat_sum = self._lat_wsum = 0.0
        self._lat_samples: list[float] = []
        self._base_lat_sum = 0.0
        self._util_acc = np.zeros(3)          # gpu_util, sm_act, mem
        self._util_ticks = 0
        self._tput_sum = self._tput_ticks = 0.0
        self._timeline: dict[str, list] = {"t": [], "gpu_util": [], "sm_act": [],
                                           "mem": [], "slowdown": [], "tput": []}

    def _profile_at(self, d: _Device, on_arrs: dict) -> WorkloadProfile:
        i = d.idx
        return WorkloadProfile(
            name=d.service,
            gpu_util=float(on_arrs["gpu_util"][i]),
            sm_activity=float(on_arrs["sm_activity"][i]),
            sm_occupancy=float(on_arrs["sm_occupancy"][i]),
            mem_bw=float(on_arrs["mem_bw"][i]),
            exec_time_ms=float(on_arrs["exec_time_ms"][i]),
            mem_bytes_frac=float(on_arrs["mem_bytes_frac"][i]))

    def _service_idx_array(self) -> np.ndarray:
        return np.array([d.service_idx for d in self.devices], np.int64)

    # ------------------------------------------------------------------ run
    def run(self) -> SimResults:
        cfg = self.cfg
        t = 0.0
        job_i = 0
        next_sched = 0.0
        n_ticks = int(cfg.horizon_s / cfg.tick_s)
        self._sidx = self._service_idx_array()
        for _ in range(n_ticks):
            # job arrivals
            while job_i < len(self.jobs) and self.jobs[job_i].submit_s <= t:
                self.pending.append(self.jobs[job_i])
                job_i += 1
            # scheduling interval
            if self._pol_name != "online-only" and t >= next_sched:
                self._schedule(t)
                next_sched = t + cfg.schedule_interval_s
            self._tick(t)
            t += cfg.tick_s
        return self._results(t)

    # ------------------------------------------------------------- schedule
    def _schedule(self, t: float) -> None:
        cfg = self.cfg
        if self._pol_name in ("time-sharing", "pb-time-sharing"):
            # greedy FIFO packing: any alive device without a job
            for d in self.devices:
                if not self.pending:
                    break
                if d.job is None and d.failed_until <= t:
                    spec = self.pending.pop(0)
                    self._start_job(d, spec, 0.5, t)
            return
        if not self.pending:
            return
        sched_cfg = SchedulerConfig(
            use_dynamic_sm=self._pol_name in ("muxflow", "muxflow-m"),
            use_matching=self._pol_name in ("muxflow", "muxflow-s"),
            shard_size=cfg.shard_size)
        # free healthy devices (the paper only schedules onto Healthy GPUs)
        qps = self.qps_bank.qps(t)
        on_arrs = online_profile_arrays(self._sidx, qps, SERVICES)
        slots, free_devs = [], []
        for d in self.devices:
            if d.job is None and d.failed_until <= t and d.monitor.schedulable:
                slots.append(OnlineSlot(d.idx, d.gpu_type,
                                        self._profile_at(d, on_arrs)))
                free_devs.append(d)
        jobs = [OfflineJob(s.job_id, OFFLINE_MODEL_PROFILES[s.model],
                           s.duration_s) for s in self.pending]
        assignments = schedule(slots, jobs, self.predictor, sched_cfg)
        by_job = {s.job_id: s for s in self.pending}
        dev_by_id = {d.idx: d for d in self.devices}
        for a in assignments:
            spec = by_job.get(a.job_id)
            if spec is None:
                continue
            dev = dev_by_id[a.device_id]
            if not self.feasible[(dev.service, spec.model)]:
                continue  # xCUDA memory quota rejects the pairing
            by_job.pop(a.job_id)
            self.pending.remove(spec)
            self._start_job(dev, spec, a.sm_share, t)

    def _start_job(self, d: _Device, spec: OfflineJobSpec, share: float,
                   t: float) -> None:
        d.job = _RunningJob(spec=spec, progress_s=0.0, checkpoint_s=0.0,
                            sm_share=share, started_at=t)
        self.executions += 1

    # ----------------------------------------------------------------- tick
    def _tick(self, t: float) -> None:
        cfg = self.cfg
        dt = cfg.tick_s
        # shared RNG contract with the vectorized engine: one (3, n) block
        fail_u, err_u, kind_u = self.rng.random((3, len(self.devices)))
        qps_arr = self.qps_bank.qps(t)
        on_arrs = online_profile_arrays(self._sidx, qps_arr, SERVICES)
        lat_num = lat_den = 0.0
        base_num = 0.0
        util = np.zeros(3)
        tput_sum, tput_n = 0.0, 0
        slow_sum, slow_n = 0.0, 0
        for d in self.devices:
            # hardware failure / recovery
            if d.failed_until > t:
                continue
            if fail_u[d.idx] < dt / (cfg.device_mtbf_h * 3600.0):
                d.failed_until = t + cfg.device_repair_s
                self._evict(d, t, requeue=True, count=False)
                continue
            qps = float(qps_arr[d.idx])
            on = self._profile_at(d, on_arrs)
            slowdown, tput = 1.0, 0.0
            if d.job is not None:
                off = OFFLINE_MODEL_PROFILES[d.job.spec.model]
                slowdown, tput = self._policy_perf(d, on, off)
                tput *= d.speed
                # offline progress + periodic checkpoint
                d.job.progress_s += tput * dt
                d.job.shared_wall_s += dt
                if (d.job.progress_s - d.job.checkpoint_s
                        >= cfg.checkpoint_interval_s):
                    d.job.checkpoint_s = d.job.progress_s
                tput_sum += tput
                tput_n += 1
                # error injection (offline container errors)
                p_err = cfg.error_rate_per_job_hour * dt / 3600.0
                if err_u[d.idx] < p_err:
                    self._inject_error(d, t, float(kind_u[d.idx]))
                if d.job is not None and d.job.progress_s >= d.job.spec.duration_s:
                    self.finished.append((d.job.spec, t - d.job.spec.submit_s,
                                          d.job.shared_wall_s, d.job.progress_s))
                    d.job = None
            # telemetry + SysMonitor
            used_off = (min(d.job.sm_share,
                            OFFLINE_MODEL_PROFILES[d.job.spec.model].sm_activity)
                        if d.job else 0.0)
            tele = DeviceTelemetry(
                ts=t,
                gpu_util=min(1.0, on.gpu_util + (0.62 * used_off if d.job else 0.0)),
                sm_activity=min(1.0, on.sm_activity + used_off * 0.45),
                sm_clock=1590.0 - 420.0 * max(0.0, on.sm_activity + used_off - 0.8),
                mem_used_frac=min(1.0, on.mem_bytes_frac
                                  + (OFFLINE_MODEL_PROFILES[d.job.spec.model].mem_bytes_frac
                                     if d.job else 0.0)),
            )
            state, events = d.monitor.update(tele, t)
            if "evict" in events and d.job is not None:
                self._evict(d, t, requeue=True)
            # online latency accounting (weighted by qps)
            outage = d.online_outage_until > t
            lat = d.base_latency_ms * slowdown * (10.0 if outage else 1.0)
            lat_num += lat * qps
            base_num += d.base_latency_ms * qps
            lat_den += qps
            self._lat_samples.append(lat)
            slow_sum += slowdown
            slow_n += 1
            util += np.array([tele.gpu_util, tele.sm_activity, tele.mem_used_frac])
        self._lat_sum += lat_num
        self._base_lat_sum += base_num
        self._lat_wsum += lat_den
        self._util_acc += util
        self._util_ticks += 1
        if tput_n:
            self._tput_sum += tput_sum / tput_n
            self._tput_ticks += 1
        if int(t) % 600 == 0:
            n = max(len(self.devices), 1)
            self._timeline["t"].append(t)
            self._timeline["gpu_util"].append(util[0] / n)
            self._timeline["sm_act"].append(util[1] / n)
            self._timeline["mem"].append(util[2] / n)
            self._timeline["slowdown"].append(slow_sum / max(slow_n, 1))
            self._timeline["tput"].append(tput_sum / max(tput_n, 1) if tput_n else 0.0)

    def _policy_perf(self, d: _Device, on, off) -> tuple[float, float]:
        """(online slowdown, offline normalized tput) per policy."""
        pol = self._pol_name
        if pol.startswith("muxflow"):
            return shared_performance(on, off, d.job.sm_share)
        if pol == "time-sharing":
            # fair time slices (Gandiva-style): offline takes ~half the time
            off_duty = 0.5
            slowdown = 1.0 + 0.9 * off_duty * min(1.0, on.gpu_util * 2.2)
            return slowdown, off_duty * 0.9
        if pol == "pb-time-sharing":
            # online priority: offline fills idle *time* only (AntMan/PAI)
            idle = max(0.0, 1.0 - on.gpu_util)
            return 1.05, idle * 0.8
        return 1.0, 0.0

    def _inject_error(self, d: _Device, t: float, kind_u: float) -> None:
        self.errors_injected += 1
        kind = error_from_uniform(kind_u)
        handled = self.err_handler.handle(kind)
        if handled.propagated:
            d.online_outage_until = t + self.cfg.online_outage_s
            self.online_incidents += 1
        if handled.action.value == "graceful_exit":
            # graceful exit checkpoints before releasing
            if d.job is not None:
                d.job.checkpoint_s = d.job.progress_s
        self._evict(d, t, requeue=True, count=False)

    def _evict(self, d: _Device, t: float, requeue: bool, count: bool = True) -> None:
        if d.job is None:
            return
        if count:
            self.evictions += 1
        job = d.job
        d.job = None
        if requeue and job.progress_s < job.spec.duration_s:
            # resume from last checkpoint
            spec = dataclasses.replace(
                job.spec, duration_s=job.spec.duration_s - job.checkpoint_s,
                submit_s=job.spec.submit_s)
            spec.job_id = job.spec.job_id
            self.pending.insert(0, spec)

    # -------------------------------------------------------------- results
    def _results(self, t_end: float) -> SimResults:
        r = SimResults(policy=self._pol_name, trace=self.cfg.trace)
        r.n_jobs = len(self.jobs)
        r.n_finished = len(self.finished)
        if self.finished:
            r.avg_jct_s = float(np.mean([jct for _, jct, _, _ in self.finished]))
            r.makespan_s = float(max(jct + s.submit_s
                                     for s, jct, _, _ in self.finished))
        r.avg_latency_ms = self._lat_sum / max(self._lat_wsum, 1e-9)
        r.base_avg_latency_ms = self._base_lat_sum / max(self._lat_wsum, 1e-9)
        r.avg_slowdown = r.avg_latency_ms / max(r.base_avg_latency_ms, 1e-9)
        if self._lat_samples:
            r.p99_latency_ms = float(np.percentile(self._lat_samples, 99))
        util = self._util_acc / max(self._util_ticks * len(self.devices), 1)
        r.gpu_util, r.sm_activity, r.mem_used = map(float, util)
        r.avg_norm_tput = self._tput_sum / max(self._tput_ticks, 1e-9)
        # Eq. 3: oversold GPU — effective separate-execution seconds delivered
        # per wall-second the offline workloads spent sharing a device
        prog = sum(d.job.progress_s for d in self.devices if d.job)
        wall = sum(d.job.shared_wall_s for d in self.devices if d.job)
        prog += sum(p for _, _, _, p in self.finished)
        wall += sum(w for _, _, w, _ in self.finished)
        r.oversold_gpu = float(min(1.0, prog / max(wall, 1e-9)))
        r.evictions = self.evictions
        r.eviction_frac = self.evictions / max(self.executions, 1)
        r.errors_injected = self.errors_injected
        r.errors_propagated = sum(1 for h in self.err_handler.handled if h.propagated)
        r.online_incidents = self.online_incidents
        r.timeline = self._timeline
        return r
