"""Compiled (XLA) tick engine — the fused counterpart of
:meth:`repro.core.simulator.ClusterSim._dense_core_numpy`.

The dense per-tick math — failure/error/completion state transitions,
progress/wall/checkpoint accrual, outage windows, DCGM-style telemetry, and
the full vectorized SysMonitor state machine — is traced once as a
``FleetState``-in/``FleetState``-out kernel and run through ``jax.lax.scan``
over tick *blocks* with donated buffers.  Python is re-entered only at
sparse event boundaries: job arrivals, scheduling rounds, control-plane
hooks, and fault injections (the accounting pass in ``simulator.py`` replays
each tick's sparse events from the kernel's stacked mask outputs).  The
same replay is what lets the request-level serving plane
(:mod:`repro.serving_plane`) ride block mode unchanged: ``_account`` runs
per tick, in order, on bitwise-identical arrays under both engines, so the
plane's per-tick queue/admission updates — and the report's ``"serving"``
section — are engine-invariant by construction.

Bitwise parity contract
-----------------------
``SimConfig.engine = "xla"`` must produce *byte-identical* ``SimResults``
and scenario reports to the numpy engine at the same seed.  Three things
make that possible:

* every accumulation/reduction and every transcendental stays on the host
  (shared numpy code in ``_tick_inputs`` / ``_account``): the kernel sees
  only IEEE-correctly-rounded elementwise ops (+, −, ×, min, max, select,
  compares, gathers/scatters, integer math), which agree bitwise between
  numpy and XLA CPU;
* no multiply in the kernel ever feeds an add/sub directly — the one
  rewrite LLVM may legally apply to such chains (contracting them into
  FMAs, which changes the rounding) therefore has nothing to bite on.
  Products that the telemetry math needs are formed host-side in
  ``_tick_inputs`` or routed through an intervening min/max (the numpy
  core is written in the same shapes, so the restriction costs nothing);
  a fixed-seed test pins kernel outputs to the numpy core bitwise;
* both engines draw per-tick randomness from one numpy ``Generator``
  stream and read trace/profile/policy inputs from the same host-computed
  arrays.

All state is host-authoritative: the fleet arrays, monitor state codes,
and re-admission timers round-trip through the (donated) kernel arguments
each call, while the Overlimit ring buffer never enters the kernel at all
— its rare, sparse updates run host-side through the same
:class:`VectorSysMonitor` primitives the numpy engine uses (see
``_tick_body``).  That keeps the control plane's between-tick surface
(``force_error``, ``evict_device``, ``set_schedulable_mask`` …)
engine-agnostic: everything it mutates is plain numpy.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.sysmonitor import (S_DISABLED, S_HEALTHY, S_INIT,
                                   S_OVERLIMIT, S_UNHEALTHY)

# one compiled executable per (T, n, n_kinds) — every true scalar (rates,
# thresholds, tick length) and every per-kind outcome table is an argument,
# so one kernel serves every scenario of a given shape without recompiling.
# Blocks are power-of-two sized, so T ∈ {1, 2, 4, …, _MAX_BLOCK}.
_COMPILE_CACHE: dict[tuple, object] = {}

_MAX_BLOCK = 32

# scalar-vector layout (argument `sc`); see _scalars()
_SC = ("dt", "p_fail", "p_err", "repair_s", "outage_s", "ck_interval",
       "err_total", "th_util_h", "th_util_o", "th_sm_h", "th_sm_o",
       "th_mem_h", "th_mem_o", "th_clk_h", "th_clk_o", "th_tmp_h",
       "th_tmp_o", "readmit_base", "readmit_cap", "ol_window", "init_dur",
       "temp_c")
_SCI = {k: i for i, k in enumerate(_SC)}


def _compile(jitted, *args):
    """AOT-compile the kernel (full optimization — the kernel's op graph is
    contraction-free by construction, see the module docstring)."""
    return jitted.lower(*args).compile()


def _tick_body(carry, x, stat, sc, n_kinds: int):
    """One tick of dense state evolution — mirrors
    ``ClusterSim._dense_core_numpy`` + ``VectorSysMonitor.update``
    operation-for-operation (see the bitwise parity contract above).

    The monitor's Overlimit *ring buffer* stays host-side: entries are rare
    (a scatter here would lower to a sequential per-row loop and drag 10 MB
    of buffer copies through every tick), so the kernel only emits the
    ``mon_evict``/``start_wait`` masks and the host applies the sparse ring
    push / re-admission-period math through the same
    :class:`VectorSysMonitor` primitives the numpy engine uses.  A
    ``start_wait`` before the last tick of a block truncates the block (the
    kernel cannot see the period the host assigns), which the driver
    handles by accepting the prefix and re-stepping the rest.
    """
    (has_job, progress, checkpoint, wall, failed_until, outage_until,
     mstate, readmit_at) = carry
    t, u, tput_dt, on_util, on_act, on_mem = x
    (used_min, used62, used45, duration, off_mem, init_at, err_thresh,
     err_propagates, err_graceful_ck) = stat
    fail_u, err_u, kind_u = u[0], u[1], u[2]
    dt = sc[_SCI["dt"]]

    alive = failed_until <= t
    new_fail = alive & (fail_u < sc[_SCI["p_fail"]])
    failed_until = jnp.where(new_fail, t + sc[_SCI["repair_s"]],
                             failed_until)
    act = alive & ~new_fail
    busy = act & has_job
    has_job = has_job & ~new_fail
    # offline progress + periodic checkpoint (tput·dt is a host-side
    # product, so the kernel adds — no mul→add chain to contract)
    progress = jnp.where(busy, progress + tput_dt, progress)
    wall = jnp.where(busy, wall + dt, wall)
    ck = busy & (progress - checkpoint >= sc[_SCI["ck_interval"]])
    checkpoint = jnp.where(ck, progress, checkpoint)
    # offline container errors — kind and §4.2 handling outcome are pure
    # functions of the tick's uniforms; the outcome comes from the
    # per-kind tables the simulator probes out of MixedErrorHandler, so
    # the handler stays the single home of the propagation semantics
    err = busy & (err_u < sc[_SCI["p_err"]])
    r = kind_u * sc[_SCI["err_total"]]
    kind_idx = jnp.minimum(
        (r[:, None] > err_thresh[None, :]).sum(axis=1).astype(jnp.int64),
        n_kinds - 1)
    propagated = err & err_propagates[kind_idx]
    checkpoint = jnp.where(err & err_graceful_ck[kind_idx], progress,
                           checkpoint)
    outage_until = jnp.where(propagated, t + sc[_SCI["outage_s"]],
                             outage_until)
    has_job = has_job & ~err
    # job completion
    fin = busy & has_job & (progress >= duration)
    has_job = has_job & ~fin
    # telemetry (products precomputed host-side / routed through max — the
    # kernel's no-mul-into-add discipline, see module docstring)
    used_off = jnp.where(has_job, used_min, 0.0)
    tele_util = jnp.minimum(1.0, on_util + jnp.where(has_job, used62, 0.0))
    tele_sm = jnp.minimum(1.0, on_act + jnp.where(has_job, used45, 0.0))
    tele_clock = 1590.0 - jnp.maximum(0.0,
                                      420.0 * (on_act + used_off - 0.8))
    tele_mem = jnp.minimum(1.0, on_mem + jnp.where(has_job, off_mem, 0.0))
    # SysMonitor classification (0 healthy / 1 unhealthy / 2 overlimit)
    over = ((tele_util > sc[_SCI["th_util_o"]])
            | (tele_sm > sc[_SCI["th_sm_o"]])
            | (tele_mem > sc[_SCI["th_mem_o"]])
            | (sc[_SCI["temp_c"]] > sc[_SCI["th_tmp_o"]])
            | (tele_clock < sc[_SCI["th_clk_o"]]))
    unhealthy = ((tele_util > sc[_SCI["th_util_h"]])
                 | (tele_sm > sc[_SCI["th_sm_h"]])
                 | (tele_mem > sc[_SCI["th_mem_h"]])
                 | (sc[_SCI["temp_c"]] > sc[_SCI["th_tmp_h"]])
                 | (tele_clock < sc[_SCI["th_clk_h"]]))
    level = jnp.where(over, 2, jnp.where(unhealthy, 1, 0)).astype(jnp.int8)
    # SysMonitor transitions (VectorSysMonitor.update, vector form)
    init_m = act & (mstate == S_INIT)
    promote = init_m & (t - init_at >= sc[_SCI["init_dur"]])
    mstate = jnp.where(promote, S_HEALTHY, mstate).astype(jnp.int8)
    rest = act & ~init_m & (mstate != S_DISABLED)
    healthy_m = rest & (mstate == S_HEALTHY)
    unhealthy_m = rest & (mstate == S_UNHEALTHY)
    over_m = rest & (mstate == S_OVERLIMIT)
    evict = (healthy_m | unhealthy_m) & (level == 2)
    mstate = jnp.where(healthy_m & (level == 1), S_UNHEALTHY, mstate)
    mstate = jnp.where(unhealthy_m & (level == 0), S_HEALTHY, mstate)
    mstate = jnp.where(evict, S_OVERLIMIT, mstate).astype(jnp.int8)
    readmit_at = jnp.where(evict, jnp.nan, readmit_at)
    # Overlimit: wait out the exponential re-admission period (the period
    # itself is assigned host-side from the ring — see module docstring)
    exit_lvl = over_m & (level != 2)
    had_wait = ~jnp.isnan(readmit_at)
    start_wait = exit_lvl & ~had_wait
    readmit = exit_lvl & had_wait & (t >= readmit_at)
    readmit_at = jnp.where(over_m & (level == 2), jnp.nan, readmit_at)
    mstate = jnp.where(readmit, S_UNHEALTHY, mstate).astype(jnp.int8)
    readmit_at = jnp.where(readmit, jnp.nan, readmit_at)
    evict_cand = evict & has_job
    has_job = has_job & ~evict_cand

    carry = (has_job, progress, checkpoint, wall, failed_until,
             outage_until, mstate, readmit_at)
    ys = (new_fail, err, kind_idx, fin, evict_cand, busy, act, tele_util,
          tele_sm, tele_clock, tele_mem, level, progress, wall, checkpoint,
          outage_until, evict, start_wait)
    # per-tick copies of the carry state, needed only by multi-tick blocks
    # (truncation restore); T=1 reads the final carry instead
    ys_state = (has_job, failed_until, mstate, readmit_at)
    return carry, ys, ys_state


_YS = ("new_fail", "err", "kind_idx", "fin", "evict_cand", "busy", "act",
       "tele_util", "tele_sm", "tele_clock", "tele_mem", "level",
       "progress", "wall", "checkpoint", "outage_until", "mon_evict",
       "start_wait")
_YS_STATE = ("has_job", "failed_until", "mstate", "readmit_at")


def _get_kernel(T: int, n: int, n_kinds: int, example_args):
    key = (T, n, n_kinds)
    comp = _COMPILE_CACHE.get(key)
    if comp is None:
        if T == 1:
            # per-tick (control-plane interleaved) mode: no scan (the
            # while-loop's carry plumbing is pure overhead at T=1), and the
            # per-tick state copies are skipped — the caller reads the
            # final carry
            def kernel(carry, stat, sc, xs):
                x1 = jax.tree_util.tree_map(lambda a: a[0], xs)
                carry, ys, _ = _tick_body(carry, x1, stat, sc, n_kinds)
                return carry, jax.tree_util.tree_map(lambda a: a[None], ys)
        else:
            def kernel(carry, stat, sc, xs):
                def body(c, x):
                    c2, ys, ys_state = _tick_body(c, x, stat, sc, n_kinds)
                    return c2, ys + ys_state
                return lax.scan(body, carry, xs)

        jitted = jax.jit(kernel, donate_argnums=(0,))
        comp = _COMPILE_CACHE[key] = _compile(jitted, *example_args)
    return comp


class XlaTickEngine:
    """Drives the compiled tick kernel for one :class:`ClusterSim`.

    Fleet and monitor state stay numpy-authoritative (pushed in / pulled
    out around each kernel call, so the control plane's between-tick
    mutations keep working); the SysMonitor's Overlimit ring never enters
    the kernel — its sparse updates replay host-side per tick.
    """

    def __init__(self, sim):
        self.sim = sim
        cfg = sim.cfg
        mon = sim.monitor
        th = mon.cfg.thresholds
        sc = np.zeros(len(_SC), np.float64)
        sc[_SCI["dt"]] = cfg.tick_s
        sc[_SCI["p_fail"]] = cfg.tick_s / (cfg.device_mtbf_h * 3600.0)
        sc[_SCI["p_err"]] = cfg.error_rate_per_job_hour * cfg.tick_s / 3600.0
        sc[_SCI["repair_s"]] = cfg.device_repair_s
        sc[_SCI["outage_s"]] = cfg.online_outage_s
        sc[_SCI["ck_interval"]] = cfg.checkpoint_interval_s
        sc[_SCI["err_total"]] = sim._err_total
        sc[_SCI["th_util_h"]], sc[_SCI["th_util_o"]] = th.gpu_util
        sc[_SCI["th_sm_h"]], sc[_SCI["th_sm_o"]] = th.sm_activity
        sc[_SCI["th_mem_h"]], sc[_SCI["th_mem_o"]] = th.mem_used_frac
        sc[_SCI["th_clk_h"]], sc[_SCI["th_clk_o"]] = th.sm_clock_min
        sc[_SCI["th_tmp_h"]], sc[_SCI["th_tmp_o"]] = th.temp_c
        sc[_SCI["readmit_base"]] = mon.cfg.readmit_base_s
        sc[_SCI["readmit_cap"]] = mon.cfg.readmit_cap_s
        sc[_SCI["ol_window"]] = mon.cfg.overlimit_window_s
        sc[_SCI["init_dur"]] = mon.cfg.init_duration_s
        sc[_SCI["temp_c"]] = 60.0      # the engines' constant device temp
        self._sc = sc
        self._n_kinds = len(sim._err_kinds)
        self._init_at = mon._init_at            # static after construction
        self._block_hint = _MAX_BLOCK

    # ------------------------------------------------------------- driving
    def tick(self, inp: dict) -> dict:
        """Per-tick mode (control-plane interleaving): a T=1 block."""
        return self.tick_block([inp])[0]

    def tick_block(self, inps: list[dict]) -> list[dict]:
        """Run a scheduling-free run of ticks through kernel calls and
        return per-tick core dicts for the shared accounting pass.

        A ``start_wait`` event before a block's last tick truncates the
        accepted prefix (the host assigns the re-admission period the
        kernel cannot know); the remainder re-steps from the restored state
        — with the *same* already-drawn inputs, so nothing diverges.
        """
        cores: list[dict] = []
        while inps:
            # power-of-two block sizes only: truncation tails re-enter here
            # and must not mint fresh compile shapes per remainder length
            T = min(len(inps), self._block_hint)
            T = 1 << (T.bit_length() - 1)
            accepted = self._run_block(inps[:T], cores)
            # adapt: monitor-event-dense phases shrink blocks (a truncated
            # block discards work past the event), quiet phases regrow them
            self._block_hint = (min(_MAX_BLOCK, max(2 * accepted, 1))
                                if accepted == T
                                else max(1, 1 << max(accepted.bit_length()
                                                     - 1, 0)))
            inps = inps[accepted:]
        return cores

    def _run_block(self, inps: list[dict], cores: list[dict]) -> int:
        # x64 is scoped to the engine's own traces/calls (the fleet math is
        # float64 end to end) so the rest of the process — the float32
        # predictor, models, serving engine — keeps jax's default dtypes
        with enable_x64():
            return self._run_block_x64(inps, cores)

    def _run_block_x64(self, inps: list[dict], cores: list[dict]) -> int:
        sim = self.sim
        s = sim.state
        mon = sim.monitor
        n = sim.cfg.n_devices
        T = len(inps)
        if T == 1:
            inp = inps[0]
            xs = (np.array([inp["t"]]),
                  np.stack((inp["fail_u"], inp["err_u"],
                            inp["kind_u"]))[None],
                  inp["tput_dt"][None], inp["on"]["gpu_util"][None],
                  inp["on"]["sm_activity"][None],
                  inp["on"]["mem_bytes_frac"][None])
        else:
            xs = (np.array([inp["t"] for inp in inps], np.float64),
                  np.stack([np.stack((inp["fail_u"], inp["err_u"],
                                      inp["kind_u"])) for inp in inps]),
                  np.stack([inp["tput_dt"] for inp in inps]),
                  np.stack([inp["on"]["gpu_util"] for inp in inps]),
                  np.stack([inp["on"]["sm_activity"] for inp in inps]),
                  np.stack([inp["on"]["mem_bytes_frac"] for inp in inps]))
        carry = (s.has_job, s.progress, s.checkpoint, s.wall,
                 s.failed_until, s.outage_until, mon.state,
                 mon._readmit_at)
        inp0 = inps[0]
        stat = (inp0["used_min"], inp0["used62"], inp0["used45"],
                s.duration, inp0["off_mem"], self._init_at,
                sim._err_thresh, sim._err_propagates,
                sim._err_graceful_ck)
        comp = _get_kernel(T, n, self._n_kinds,
                           (carry, stat, self._sc, xs))
        carry, ys = comp(carry, stat, self._sc, xs)
        names = _YS if T == 1 else _YS + _YS_STATE
        ys = {k: np.asarray(v) for k, v in zip(names, ys)}
        # accept ticks up to (and including) the first mid-block start_wait
        # (the host assigns re-admission periods the kernel can't see)
        accepted = T
        if T > 1:
            sw_any = ys["start_wait"].any(axis=1)
            for j in range(T - 1):
                if sw_any[j]:
                    accepted = j + 1
                    break
        last = accepted - 1
        # fleet/monitor state back to (writable) numpy — the authoritative
        # copies — from the last accepted tick
        if T == 1:
            (s.has_job, s.progress, s.checkpoint, s.wall, s.failed_until,
             s.outage_until, mon.state, mon._readmit_at) = (
                np.array(a) for a in carry)
        else:
            s.has_job = ys["has_job"][last].copy()
            s.progress = ys["progress"][last].copy()
            s.checkpoint = ys["checkpoint"][last].copy()
            s.wall = ys["wall"][last].copy()
            s.failed_until = ys["failed_until"][last].copy()
            s.outage_until = ys["outage_until"][last].copy()
            mon.state = ys["mstate"][last].copy()
            mon._readmit_at = ys["readmit_at"][last].copy()
        for j in range(accepted):
            inp = inps[j]
            t = inp["t"]
            busy = ys["busy"][j]
            core = {k: ys[k][j] for k in _YS}
            # the host-side masking the numpy core applies (shared formula)
            core["slowdown"] = np.where(busy, inp["slow_raw"], 1.0)
            core["tput"] = np.where(busy, inp["tput_speed"], 0.0)
            # post-tick state snapshots for the obs rollups (core contract
            # shared with the numpy engine): per-tick scan copies in block
            # mode — the synced live arrays hold only the *last* accepted
            # tick's state — and the synced carry at T=1 (where they are
            # one and the same)
            if T == 1:
                core["has_job"] = s.has_job
                core["mstate"] = mon.state
            else:
                core["has_job"] = ys["has_job"][j]
                core["mstate"] = ys["mstate"][j]
            cores.append(core)
            # sparse host-side monitor ring work, per tick and in order —
            # through the same VectorSysMonitor primitives the numpy
            # engine's update() uses
            ei = np.flatnonzero(ys["mon_evict"][j])
            if ei.size:
                mon.push_overlimit(ei, t)
            si = np.flatnonzero(ys["start_wait"][j])
            if si.size:
                mon._readmit_at[si] = t + mon.wait_periods(si, t)
        return accepted
