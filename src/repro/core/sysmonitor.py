"""GPU-level protection: the SysMonitor state machine (§4.1, Fig. 6b).

Five states — Init, Healthy, Unhealthy, Overlimit, Disabled — driven by
multi-dimensional thresholds over the GPU-monitor metrics.  Offline workloads
may only be *scheduled* onto Healthy devices; entering Overlimit *evicts* the
offline workload; re-admission from Overlimit waits an exponentially growing
period in the number of Overlimit entries during the last two hours.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.protection import DeviceTelemetry


class GPUState(enum.Enum):
    INIT = "init"
    HEALTHY = "healthy"
    UNHEALTHY = "unhealthy"
    OVERLIMIT = "overlimit"
    DISABLED = "disabled"


@dataclasses.dataclass(frozen=True)
class MetricThresholds:
    """Per-metric (healthy_max, unhealthy_max) — beyond unhealthy_max is
    Overlimit.  sm_clock is inverted (low clock is bad)."""
    gpu_util: tuple = (0.92, 0.98)
    sm_activity: tuple = (0.85, 0.95)
    mem_used_frac: tuple = (0.90, 0.97)
    sm_clock_min: tuple = (1150.0, 900.0)   # (healthy_min, overlimit_min)
    temp_c: tuple = (82.0, 92.0)


@dataclasses.dataclass
class SysMonitorConfig:
    thresholds: MetricThresholds = dataclasses.field(default_factory=MetricThresholds)
    readmit_base_s: float = 60.0        # base of the exponential backoff
    overlimit_window_s: float = 7200.0  # "during the last two hours"
    readmit_cap_s: float = 3600.0
    init_duration_s: float = 5.0


class SysMonitor:
    """State machine over device telemetry.  `update()` returns the state and
    a list of events: 'evict' (entering Overlimit), 'schedulable' toggles."""

    def __init__(self, cfg: SysMonitorConfig | None = None, now: float = 0.0):
        self.cfg = cfg or SysMonitorConfig()
        self.state = GPUState.INIT
        self._init_at = now
        self._overlimit_entries: list[float] = []
        self._overlimit_since: float | None = None
        self._readmit_at: float | None = None

    # -- classification ----------------------------------------------------
    def _classify(self, m: DeviceTelemetry) -> str:
        t = self.cfg.thresholds
        level = "healthy"

        def worst(value, healthy_max, over_max):
            if value > over_max:
                return "overlimit"
            if value > healthy_max:
                return "unhealthy"
            return "healthy"

        checks = [
            worst(m.gpu_util, *t.gpu_util),
            worst(m.sm_activity, *t.sm_activity),
            worst(m.mem_used_frac, *t.mem_used_frac),
            worst(m.temp_c, *t.temp_c),
        ]
        # clock: below healthy_min unhealthy; below overlimit_min overlimit
        h_min, o_min = t.sm_clock_min
        if m.sm_clock < o_min:
            checks.append("overlimit")
        elif m.sm_clock < h_min:
            checks.append("unhealthy")
        if "overlimit" in checks:
            level = "overlimit"
        elif "unhealthy" in checks:
            level = "unhealthy"
        return level

    def _readmit_period(self, now: float) -> float:
        w = now - self.cfg.overlimit_window_s
        n = sum(1 for ts in self._overlimit_entries if ts >= w)
        return min(self.cfg.readmit_base_s * (2.0 ** max(n - 1, 0)),
                   self.cfg.readmit_cap_s)

    # -- transitions ---------------------------------------------------------
    def update(self, m: DeviceTelemetry, now: float) -> tuple[GPUState, list[str]]:
        events: list[str] = []
        level = self._classify(m)
        s = self.state
        if s == GPUState.DISABLED:
            return s, events
        if s == GPUState.INIT:
            if now - self._init_at >= self.cfg.init_duration_s:
                self.state = GPUState.HEALTHY
                events.append("schedulable")
            return self.state, events
        if s == GPUState.HEALTHY:
            if level == "overlimit":
                self._enter_overlimit(now, events)
            elif level == "unhealthy":
                self.state = GPUState.UNHEALTHY
                events.append("unschedulable")
        elif s == GPUState.UNHEALTHY:
            if level == "overlimit":
                self._enter_overlimit(now, events)
            elif level == "healthy":
                self.state = GPUState.HEALTHY
                events.append("schedulable")
        elif s == GPUState.OVERLIMIT:
            if level != "overlimit":
                if self._readmit_at is None:
                    self._readmit_at = now + self._readmit_period(now)
                elif now >= self._readmit_at:
                    self.state = GPUState.UNHEALTHY
                    self._readmit_at = None
            else:
                self._readmit_at = None   # still over limit: restart the wait
        return self.state, events

    def _enter_overlimit(self, now: float, events: list[str]) -> None:
        self.state = GPUState.OVERLIMIT
        self._overlimit_entries.append(now)
        self._overlimit_since = now
        self._readmit_at = None
        events.append("evict")

    def disable(self) -> None:
        self.state = GPUState.DISABLED

    @property
    def schedulable(self) -> bool:
        """Offline workloads can only be scheduled to Healthy GPUs."""
        return self.state == GPUState.HEALTHY


# ---------------------------------------------------------------------------
# Vectorized fleet monitor (paper-scale simulation hot path)
# ---------------------------------------------------------------------------

# integer state codes for the struct-of-arrays monitor
S_INIT, S_HEALTHY, S_UNHEALTHY, S_OVERLIMIT, S_DISABLED = range(5)

_STATE_BY_CODE = (GPUState.INIT, GPUState.HEALTHY, GPUState.UNHEALTHY,
                  GPUState.OVERLIMIT, GPUState.DISABLED)


class VectorSysMonitor:
    """Struct-of-arrays :class:`SysMonitor` over ``n`` devices.

    One ``update`` call advances every *active* device's state machine with a
    handful of vectorized ops; transition semantics replicate the scalar
    monitor exactly (verified by an equivalence test).  Overlimit entry
    timestamps live in a fixed ring buffer per device — with the exponential
    re-admission backoff a device can physically accumulate only a handful of
    entries inside the two-hour window, so a small ring is lossless.
    """

    def __init__(self, n: int, cfg: SysMonitorConfig | None = None,
                 now: float = 0.0, ring: int = 64):
        self.cfg = cfg or SysMonitorConfig()
        self.n = n
        self.state = np.full(n, S_INIT, np.int8)
        self._init_at = np.full(n, now, np.float64)
        self._readmit_at = np.full(n, np.nan, np.float64)
        self._ol_times = np.full((n, ring), -np.inf, np.float64)
        self._ol_ptr = np.zeros(n, np.int64)

    # -- classification ----------------------------------------------------
    def classify(self, gpu_util, sm_activity, mem_used_frac, sm_clock,
                 temp_c) -> np.ndarray:
        """0 = healthy, 1 = unhealthy, 2 = overlimit (per device)."""
        t = self.cfg.thresholds
        h_min, o_min = t.sm_clock_min
        over = ((gpu_util > t.gpu_util[1]) | (sm_activity > t.sm_activity[1])
                | (mem_used_frac > t.mem_used_frac[1]) | (temp_c > t.temp_c[1])
                | (sm_clock < o_min))
        unhealthy = ((gpu_util > t.gpu_util[0]) | (sm_activity > t.sm_activity[0])
                     | (mem_used_frac > t.mem_used_frac[0])
                     | (temp_c > t.temp_c[0]) | (sm_clock < h_min))
        return np.where(over, 2, np.where(unhealthy, 1, 0)).astype(np.int8)

    # -- transitions -------------------------------------------------------
    def update(self, level: np.ndarray, now: float,
               active: np.ndarray | None = None) -> np.ndarray:
        """Advance active devices one step given their classification levels.
        Returns the eviction-event mask (devices entering Overlimit)."""
        if active is None:
            active = np.ones(self.n, bool)
        state = self.state
        init_m = active & (state == S_INIT)
        promote = init_m & (now - self._init_at >= self.cfg.init_duration_s)
        state[promote] = S_HEALTHY
        # the scalar monitor returns early from INIT, so freshly promoted
        # devices do not run the healthy-state logic until the next sample
        rest = active & ~init_m & (state != S_DISABLED)
        healthy_m = rest & (state == S_HEALTHY)
        unhealthy_m = rest & (state == S_UNHEALTHY)
        over_m = rest & (state == S_OVERLIMIT)
        evict = (healthy_m | unhealthy_m) & (level == 2)
        state[healthy_m & (level == 1)] = S_UNHEALTHY
        state[unhealthy_m & (level == 0)] = S_HEALTHY
        ei = np.flatnonzero(evict)
        if ei.size:
            state[ei] = S_OVERLIMIT
            self._readmit_at[ei] = np.nan
            self.push_overlimit(ei, now)
        # Overlimit: wait out the exponential re-admission period
        exit_lvl = over_m & (level != 2)
        had_wait = ~np.isnan(self._readmit_at)
        start_wait = exit_lvl & ~had_wait
        readmit = exit_lvl & had_wait & (now >= self._readmit_at)
        self._readmit_at[over_m & (level == 2)] = np.nan
        si = np.flatnonzero(start_wait)
        if si.size:
            self._readmit_at[si] = now + self.wait_periods(si, now)
        state[readmit] = S_UNHEALTHY
        self._readmit_at[readmit] = np.nan
        return evict

    # -- ring-buffer primitives (shared with the compiled tick engine,
    #    which keeps the Overlimit ring host-side and sparse) -------------
    def push_overlimit(self, ei: np.ndarray, now: float) -> None:
        """Record Overlimit entries for devices ``ei`` at time ``now``."""
        ring = self._ol_times.shape[1]
        self._ol_times[ei, self._ol_ptr[ei] % ring] = now
        self._ol_ptr[ei] += 1

    def wait_periods(self, si: np.ndarray, now: float) -> np.ndarray:
        """Exponential re-admission periods for devices ``si`` entering the
        wait at ``now`` (doubling per Overlimit entry in the window).  2**k
        is an integer shift (exact; capping the exponent at 52 cannot
        change the min with the cap)."""
        w = now - self.cfg.overlimit_window_s
        n_entries = (self._ol_times[si] >= w).sum(axis=1)
        e = np.minimum(np.maximum(n_entries - 1, 0), 52)
        return np.minimum(
            self.cfg.readmit_base_s * (np.int64(1) << e).astype(np.float64),
            self.cfg.readmit_cap_s)

    def disable(self, idx) -> None:
        self.state[idx] = S_DISABLED

    @property
    def schedulable(self) -> np.ndarray:
        return self.state == S_HEALTHY

    def states(self) -> list[GPUState]:
        return [_STATE_BY_CODE[c] for c in self.state]
