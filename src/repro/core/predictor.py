"""The DL speed predictor (§5): a 4-layer MLP with 64×64 hidden sizes that
maps (online profile, offline profile, assigned SM %) → predicted normalized
offline throughput.  Trained with momentum SGD (the paper's optimizer), one
model per GPU type, ~2 000 samples per type.

Pure JAX; the MLP is also used in the accuracy-sweep benchmark (Fig. 12).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interference import (OFFLINE_MODEL_PROFILES, WorkloadProfile,
                                     online_profile, shared_performance)
from repro.optim.optimizer import MomentumSGD, MomentumSGDConfig

N_FEATURES = 9  # on: util, sm_act, occ, time | off: util, sm_act, occ, time | sm%

# The documented feature contract (per-column [low, high]): occupancy-style
# features live in [0, 1]; the two separate-execution times are in seconds
# and bounded by 10 s (no profiled iteration/request is longer); the
# assigned SM share is a fraction.  ``pair_features`` output must stay in
# these ranges for every valid profile pair — the property tests in
# tests/test_profiling.py pin this.
FEATURE_RANGES = np.array([
    [0.0, 1.0],    # online gpu_util
    [0.0, 1.0],    # online sm_activity
    [0.0, 1.0],    # online sm_occupancy
    [0.0, 10.0],   # online exec time (s)
    [0.0, 1.0],    # offline gpu_util
    [0.0, 1.0],    # offline sm_activity
    [0.0, 1.0],    # offline sm_occupancy
    [0.0, 10.0],   # offline exec time (s)
    [0.0, 1.0],    # assigned offline SM share
], np.float32)


def pair_features(online: WorkloadProfile, offline: WorkloadProfile,
                  sm_off: float) -> np.ndarray:
    """The predictor's input row — see ``FEATURE_RANGES`` for the contract."""
    return np.array([
        online.gpu_util, online.sm_activity, online.sm_occupancy,
        online.exec_time_ms / 1000.0,
        offline.gpu_util, offline.sm_activity, offline.sm_occupancy,
        offline.exec_time_ms / 1000.0,
        sm_off,
    ], dtype=np.float32)


def mlp_init(key, hidden: int = 64, layers: int = 4, in_dim: int = N_FEATURES):
    """`layers` total linear layers (the paper picks 4, hidden 64×64)."""
    dims = [in_dim] + [hidden] * (layers - 1) + [1]
    ks = jax.random.split(key, len(dims) - 1)
    params = []
    for k, din, dout in zip(ks, dims[:-1], dims[1:]):
        w = jax.random.normal(k, (din, dout), jnp.float32) * (2.0 / din) ** 0.5
        params.append({"w": w, "b": jnp.zeros((dout,), jnp.float32)})
    return params


def mlp_apply(params, x):
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return jax.nn.sigmoid(h[..., 0])   # normalized throughput in (0,1)


_mlp_apply_jit = jax.jit(mlp_apply)


@dataclasses.dataclass
class SpeedPredictor:
    """One trained MLP per GPU type (the paper trains per-type models)."""
    params_by_type: dict

    def predict(self, gpu_type: str, feats: np.ndarray) -> np.ndarray:
        """feats: (..., N_FEATURES) -> (...,) normalized throughput.

        Batches run through one jitted apply; rows are padded to the next
        power of two so the scheduler's varying round sizes hit a handful
        of compiled shapes instead of recompiling per batch size.
        """
        params = self.params_by_type[gpu_type]
        feats = np.asarray(feats, np.float32)
        rows = feats.reshape(-1, feats.shape[-1])
        k = rows.shape[0]
        if k == 0:
            return np.zeros(feats.shape[:-1], np.float32)
        pad = 1 << (k - 1).bit_length()
        if pad != k:
            rows = np.concatenate(
                [rows, np.zeros((pad - k, rows.shape[1]), np.float32)])
        out = np.asarray(_mlp_apply_jit(params, jnp.asarray(rows)))[:k]
        return out.reshape(feats.shape[:-1])

    def predict_pair(self, gpu_type: str, online, offline, sm_off) -> float:
        return float(self.predict(gpu_type, pair_features(online, offline, sm_off)))


class CachedSpeedPredictor:
    """Bounded (LRU) memoizing wrapper around :class:`SpeedPredictor` for
    the scheduler's repeated rounds.

    With the paper's workloads a feature row is determined by the (online
    service @ QPS, offline model, SM share) triple, and the same triples
    recur every scheduling interval.  Rows are quantized to ``quantum`` (the
    prediction is computed *on the quantized row*, so the cache is
    self-consistent) and keyed per GPU type by their bytes.

    Each call deduplicates its rows **vectorized** (``np.unique`` over the
    byte rows) before touching the Python-level cache, so a 20 000-device
    round costs a few hundred dict operations instead of one per
    (device × model) pair — this is what keeps weight-grid construction off
    the interpreter at paper scale.  Misses are batched into a single inner
    predictor call.

    The memo is a true LRU bounded by ``max_entries`` (hits refresh
    recency, overflow evicts the least-recently-used row — the unbounded
    growth the earlier clear-on-overflow scheme traded away is gone), and
    ``stats()`` exposes hit/miss/eviction counters for telemetry snapshots.
    """

    def __init__(self, inner: SpeedPredictor, quantum: float = 0.01,
                 max_entries: int = 2_000_000):
        import collections
        self.inner = inner
        self.quantum = float(quantum)
        self.max_entries = int(max_entries)
        self._cache: "collections.OrderedDict[tuple[str, bytes], float]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def params_by_type(self):
        return self.inner.params_by_type

    def predict(self, gpu_type: str, feats: np.ndarray) -> np.ndarray:
        feats = np.asarray(feats, np.float32)
        squeeze = feats.ndim == 1
        rows = feats.reshape(-1, feats.shape[-1])
        if self.quantum > 0:
            rows = (np.round(rows / self.quantum)
                    * self.quantum).astype(np.float32)
        rows = np.ascontiguousarray(rows)
        # dedupe by row *bytes* (matches dict-key semantics: -0.0 != 0.0);
        # a void view makes this one memcmp-argsort instead of the
        # column-by-column lexsort np.unique(axis=0) would run
        nbytes = rows.shape[-1] * rows.itemsize
        voids = rows.view(np.dtype((np.void, nbytes))).reshape(-1)
        uniq_v, inverse = np.unique(voids, return_inverse=True)
        uniq_u8 = uniq_v.view(np.uint8).reshape(uniq_v.shape[0], nbytes)
        uniq_rows = uniq_u8.view(np.float32)
        cache = self._cache
        uniq_vals = np.empty(uniq_rows.shape[0], np.float32)
        miss_u: list[int] = []
        keys = [(gpu_type, uniq_u8[i].tobytes())
                for i in range(uniq_rows.shape[0])]
        for i, key in enumerate(keys):
            val = cache.get(key)
            if val is None:
                miss_u.append(i)
            else:
                cache.move_to_end(key)
                uniq_vals[i] = val
        n_miss = int(np.isin(inverse, miss_u).sum()) if miss_u else 0
        self.misses += n_miss
        self.hits += rows.shape[0] - n_miss
        if miss_u:
            mi = np.asarray(miss_u)
            pred = np.asarray(self.inner.predict(gpu_type, uniq_rows[mi]),
                              np.float32)
            uniq_vals[mi] = pred
            for i, p in zip(miss_u, pred):
                cache[keys[i]] = float(p)
            while len(cache) > self.max_entries:
                cache.popitem(last=False)
                self.evictions += 1
        out = uniq_vals[inverse]
        shaped = out.reshape(feats.shape[:-1])
        return shaped[()] if squeeze else shaped

    def predict_pair(self, gpu_type: str, online, offline, sm_off) -> float:
        return float(self.predict(gpu_type,
                                  pair_features(online, offline, sm_off)))

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        """Deterministic counters for telemetry/report surfaces."""
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._cache),
                "hit_rate": self.hit_rate()}


def make_dataset(rng: np.random.Generator, n: int = 2000,
                 noise: float = 0.02) -> tuple[np.ndarray, np.ndarray]:
    """Synthesize a profiling dataset from the interference model: random
    (online service @ random QPS, offline model, sm%) triples with measured
    (= modeled + measurement noise) shared throughput."""
    feats, targets = [], []
    services = list(("recommend", "translate", "vision"))
    offline_names = list(OFFLINE_MODEL_PROFILES)
    for _ in range(n):
        svc = services[rng.integers(len(services))]
        qps = float(rng.uniform(5.0, 190.0))
        on = online_profile(svc, qps)
        off = OFFLINE_MODEL_PROFILES[offline_names[rng.integers(len(offline_names))]]
        # jitter the offline profile so the dataset covers a family, not 4 points
        off = dataclasses.replace(
            off,
            sm_activity=float(np.clip(off.sm_activity * rng.uniform(0.8, 1.2), 0.05, 1.0)),
            mem_bw=float(np.clip(off.mem_bw * rng.uniform(0.8, 1.2), 0.05, 1.0)),
            exec_time_ms=off.exec_time_ms * float(rng.uniform(0.7, 1.4)))
        sm = float(rng.uniform(0.05, 1.0))
        _, tput = shared_performance(on, off, sm)
        feats.append(pair_features(on, off, sm))
        targets.append(tput + rng.normal(0.0, noise))
    return np.stack(feats), np.clip(np.array(targets, np.float32), 0.0, 1.0)


def train_predictor(key, feats: np.ndarray, targets: np.ndarray, *,
                    hidden: int = 64, layers: int = 4, epochs: int = 200,
                    batch_size: int = 128, lr: float = 0.05,
                    val_frac: float = 0.2, seed: int = 0):
    """Momentum-SGD training.  Returns (params, history dict)."""
    n = len(feats)
    n_val = int(n * val_frac)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    feats, targets = feats[perm], targets[perm]
    xv, yv = jnp.asarray(feats[:n_val]), jnp.asarray(targets[:n_val])
    xt, yt = jnp.asarray(feats[n_val:]), jnp.asarray(targets[n_val:])
    params = mlp_init(key, hidden=hidden, layers=layers)
    opt = MomentumSGD(MomentumSGDConfig(lr=lr, momentum=0.9))
    state = opt.init(params)

    @jax.jit
    def step(params, state, xb, yb):
        def loss_fn(p):
            pred = mlp_apply(p, xb)
            return jnp.mean((pred - yb) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(params, grads, state)
        return params, state, loss

    @jax.jit
    def mae(params, x, y):
        return jnp.mean(jnp.abs(mlp_apply(params, x) - y))

    n_train = len(xt)
    steps_per_epoch = max(1, n_train // batch_size)
    history = {"val_mae": [], "train_loss": []}
    for ep in range(epochs):
        order = rng.permutation(n_train)
        ep_loss = 0.0
        for s in range(steps_per_epoch):
            idx = order[s * batch_size:(s + 1) * batch_size]
            params, state, loss = step(params, state, xt[idx], yt[idx])
            ep_loss += float(loss)
        history["train_loss"].append(ep_loss / steps_per_epoch)
        history["val_mae"].append(float(mae(params, xv, yv)))
    return params, history


def build_speed_predictor(gpu_types=("T4", "A10"), n: int = 2000,
                          epochs: int = 120, seed: int = 0) -> SpeedPredictor:
    """Train one MLP per GPU type (A10 modeled as a 1.35× faster T4 with
    different contention noise seed)."""
    params_by_type = {}
    for i, t in enumerate(gpu_types):
        rng = np.random.default_rng(seed + i)
        feats, targets = make_dataset(rng, n=n)
        params, _ = train_predictor(jax.random.PRNGKey(seed + i), feats, targets,
                                    epochs=epochs, seed=seed + i)
        params_by_type[t] = params
    return SpeedPredictor(params_by_type)
