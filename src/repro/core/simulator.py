"""Trace-driven cluster simulator (§7.1: "Inspired by [Tiresias, Muri], we
build a simulator to evaluate a broader set of configurations, traces, and
baselines").

Fixed-tick discrete-event simulation of a GPU cluster where every device
hosts one online workload (diurnal QPS) and at most one offline workload.
Implements the full MuxFlow stack — dynamic SM allocation, the speed
predictor + KM matching scheduler, SysMonitor protection/eviction, the mixed
error handler, checkpoint/restart fault tolerance — and the paper's
baselines: Online-only, Time-sharing (Gandiva-style), and Priority-based
time-sharing (AntMan/PAI-style), plus the MuxFlow-S/-M/-S-M ablations.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.errors import ERROR_MIX, ErrorKind, MixedErrorHandler, sample_error
from repro.core.interference import (OFFLINE_MODEL_PROFILES, memory_feasible,
                                     online_profile, shared_performance)
from repro.core.predictor import SpeedPredictor
from repro.core.protection import DeviceTelemetry
from repro.core.scheduler import (Assignment, OfflineJob, OnlineSlot,
                                  SchedulerConfig, schedule)
from repro.core.sysmonitor import GPUState, SysMonitor
from repro.core.traces import SERVICES, OfflineJobSpec, OnlineQPS, make_trace

POLICIES = ("muxflow", "muxflow-s", "muxflow-m", "muxflow-s-m",
            "online-only", "time-sharing", "pb-time-sharing")


@dataclasses.dataclass
class SimConfig:
    policy: str = "muxflow"
    n_devices: int = 200
    horizon_s: float = 12 * 3600.0
    tick_s: float = 30.0
    schedule_interval_s: float = 900.0        # 15 min (paper's testbed)
    checkpoint_interval_s: float = 300.0
    restart_delay_s: float = 90.0             # image pull + restore
    trace: str = "A"
    seed: int = 0
    gpu_types: tuple = ("T4", "T4", "T4", "A10")   # heterogeneous mix
    error_rate_per_job_hour: float = 0.05      # offline container errors
    graceful_exit: bool = True                 # MuxFlow's §4.2 mechanism
    device_mtbf_h: float = 4000.0              # hardware failures
    device_repair_s: float = 1800.0
    online_outage_s: float = 120.0             # when an error propagates
    memory_quota: float = 0.4


@dataclasses.dataclass
class _Device:
    idx: int
    gpu_type: str
    service: str
    qps: OnlineQPS
    monitor: SysMonitor
    job: "_RunningJob | None" = None
    failed_until: float = -1.0
    online_outage_until: float = -1.0
    base_latency_ms: float = 50.0
    speed: float = 1.0                         # A10 runs offline 1.35x faster


@dataclasses.dataclass
class _RunningJob:
    spec: OfflineJobSpec
    progress_s: float                          # in separate-execution seconds
    checkpoint_s: float                        # last checkpointed progress
    sm_share: float
    started_at: float
    shared_wall_s: float = 0.0                 # wall seconds on a device


@dataclasses.dataclass
class SimResults:
    policy: str
    trace: str
    # online
    avg_latency_ms: float = 0.0
    p99_latency_ms: float = 0.0
    base_avg_latency_ms: float = 0.0
    avg_slowdown: float = 1.0
    # offline
    n_jobs: int = 0
    n_finished: int = 0
    avg_jct_s: float = 0.0
    makespan_s: float = 0.0
    oversold_gpu: float = 0.0                  # Eq. 3
    avg_norm_tput: float = 0.0
    evictions: int = 0
    eviction_frac: float = 0.0
    # utilization (cluster averages)
    gpu_util: float = 0.0
    sm_activity: float = 0.0
    mem_used: float = 0.0
    # safety
    errors_injected: int = 0
    errors_propagated: int = 0
    online_incidents: int = 0
    # timeline (downsampled) for figure benchmarks
    timeline: dict = dataclasses.field(default_factory=dict)


class ClusterSim:
    def __init__(self, cfg: SimConfig, predictor: SpeedPredictor | None = None):
        assert cfg.policy in POLICIES, cfg.policy
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.predictor = predictor
        if cfg.policy.startswith("muxflow") and predictor is None:
            raise ValueError("MuxFlow policies need a speed predictor")
        self.devices = [
            _Device(
                idx=i,
                gpu_type=cfg.gpu_types[i % len(cfg.gpu_types)],
                service=SERVICES[i % len(SERVICES)],
                qps=OnlineQPS(self.rng),
                monitor=SysMonitor(now=0.0),
                base_latency_ms={"recommend": 38.0, "translate": 55.0,
                                 "vision": 70.0}[SERVICES[i % len(SERVICES)]],
                speed=1.35 if cfg.gpu_types[i % len(cfg.gpu_types)] == "A10" else 1.0,
            )
            for i in range(cfg.n_devices)
        ]
        self.jobs = make_trace(cfg.trace, cfg.n_devices, cfg.horizon_s, cfg.seed)
        self.pending: list[OfflineJobSpec] = []
        self.err_handler = MixedErrorHandler(graceful_enabled=cfg.graceful_exit)
        self.finished: list[tuple[OfflineJobSpec, float]] = []   # (spec, jct)
        self.evictions = 0
        self.executions = 0
        self.errors_injected = 0
        self.online_incidents = 0
        # accumulators
        self._lat_sum = self._lat_wsum = 0.0
        self._lat_samples: list[float] = []
        self._base_lat_sum = 0.0
        self._util_acc = np.zeros(3)          # gpu_util, sm_act, mem
        self._util_ticks = 0
        self._tput_sum = self._tput_ticks = 0.0
        self._timeline: dict[str, list] = {"t": [], "gpu_util": [], "sm_act": [],
                                           "mem": [], "slowdown": [], "tput": []}

    # ------------------------------------------------------------------ run
    def run(self) -> SimResults:
        cfg = self.cfg
        t = 0.0
        job_i = 0
        next_sched = 0.0
        n_ticks = int(cfg.horizon_s / cfg.tick_s)
        for _ in range(n_ticks):
            # job arrivals
            while job_i < len(self.jobs) and self.jobs[job_i].submit_s <= t:
                self.pending.append(self.jobs[job_i])
                job_i += 1
            # scheduling interval
            if cfg.policy != "online-only" and t >= next_sched:
                self._schedule(t)
                next_sched = t + cfg.schedule_interval_s
            self._tick(t)
            t += cfg.tick_s
        return self._results(t)

    # ------------------------------------------------------------- schedule
    def _schedule(self, t: float) -> None:
        cfg = self.cfg
        if cfg.policy in ("time-sharing", "pb-time-sharing"):
            # greedy FIFO packing: any alive device without a job
            for d in self.devices:
                if not self.pending:
                    break
                if d.job is None and d.failed_until <= t:
                    spec = self.pending.pop(0)
                    self._start_job(d, spec, 0.5, t)
            return
        sched_cfg = SchedulerConfig(
            use_dynamic_sm=cfg.policy in ("muxflow", "muxflow-m"),
            use_matching=cfg.policy in ("muxflow", "muxflow-s"))
        # free healthy devices (the paper only schedules onto Healthy GPUs)
        slots, free_devs = [], []
        for d in self.devices:
            if d.job is None and d.failed_until <= t and d.monitor.schedulable:
                on = online_profile(d.service, d.qps.qps(t))
                slots.append(OnlineSlot(d.idx, d.gpu_type, on))
                free_devs.append(d)
        jobs = [OfflineJob(s.job_id, OFFLINE_MODEL_PROFILES[s.model],
                           s.duration_s) for s in self.pending]
        quota_ok = {
            (sl.device_id, jb.job_id)
            for sl in slots for jb in jobs
            if memory_feasible(sl.profile, jb.profile, cfg.memory_quota)}
        assignments = schedule(slots, jobs, self.predictor, sched_cfg)
        by_job = {s.job_id: s for s in self.pending}
        dev_by_id = {d.idx: d for d in self.devices}
        for a in assignments:
            if (a.device_id, a.job_id) not in quota_ok:
                continue  # xCUDA memory quota rejects the pairing
            spec = by_job.pop(a.job_id, None)
            if spec is None:
                continue
            self.pending.remove(spec)
            self._start_job(dev_by_id[a.device_id], spec, a.sm_share, t)

    def _start_job(self, d: _Device, spec: OfflineJobSpec, share: float,
                   t: float) -> None:
        d.job = _RunningJob(spec=spec, progress_s=0.0, checkpoint_s=0.0,
                            sm_share=share, started_at=t)
        self.executions += 1

    # ----------------------------------------------------------------- tick
    def _tick(self, t: float) -> None:
        cfg = self.cfg
        dt = cfg.tick_s
        lat_num = lat_den = 0.0
        base_num = 0.0
        util = np.zeros(3)
        tput_sum, tput_n = 0.0, 0
        slow_sum, slow_n = 0.0, 0
        for d in self.devices:
            # hardware failure / recovery
            if d.failed_until > t:
                continue
            if self.rng.random() < dt / (cfg.device_mtbf_h * 3600.0):
                d.failed_until = t + cfg.device_repair_s
                self._evict(d, t, requeue=True, count=False)
                continue
            qps = d.qps.qps(t)
            on = online_profile(d.service, qps)
            slowdown, tput = 1.0, 0.0
            if d.job is not None:
                off = OFFLINE_MODEL_PROFILES[d.job.spec.model]
                slowdown, tput = self._policy_perf(d, on, off)
                tput *= d.speed
                # offline progress + periodic checkpoint
                d.job.progress_s += tput * dt
                d.job.shared_wall_s += dt
                if (d.job.progress_s - d.job.checkpoint_s
                        >= cfg.checkpoint_interval_s):
                    d.job.checkpoint_s = d.job.progress_s
                tput_sum += tput
                tput_n += 1
                # error injection (offline container errors)
                p_err = cfg.error_rate_per_job_hour * dt / 3600.0
                if self.rng.random() < p_err:
                    self._inject_error(d, t)
                if d.job is not None and d.job.progress_s >= d.job.spec.duration_s:
                    self.finished.append((d.job.spec, t - d.job.spec.submit_s,
                                          d.job.shared_wall_s, d.job.progress_s))
                    d.job = None
            # telemetry + SysMonitor
            used_off = (min(d.job.sm_share,
                            OFFLINE_MODEL_PROFILES[d.job.spec.model].sm_activity)
                        if d.job else 0.0)
            tele = DeviceTelemetry(
                ts=t,
                gpu_util=min(1.0, on.gpu_util + (0.62 * used_off if d.job else 0.0)),
                sm_activity=min(1.0, on.sm_activity + used_off * 0.45),
                sm_clock=1590.0 - 420.0 * max(0.0, on.sm_activity + used_off - 0.8),
                mem_used_frac=min(1.0, on.mem_bytes_frac
                                  + (OFFLINE_MODEL_PROFILES[d.job.spec.model].mem_bytes_frac
                                     if d.job else 0.0)),
            )
            state, events = d.monitor.update(tele, t)
            if "evict" in events and d.job is not None:
                self._evict(d, t, requeue=True)
            # online latency accounting (weighted by qps)
            outage = d.online_outage_until > t
            lat = d.base_latency_ms * slowdown * (10.0 if outage else 1.0)
            if outage:
                self.online_incidents += 0  # counted at injection
            lat_num += lat * qps
            base_num += d.base_latency_ms * qps
            lat_den += qps
            self._lat_samples.append(lat)
            slow_sum += slowdown
            slow_n += 1
            util += np.array([tele.gpu_util, tele.sm_activity, tele.mem_used_frac])
        self._lat_sum += lat_num
        self._base_lat_sum += base_num
        self._lat_wsum += lat_den
        self._util_acc += util
        self._util_ticks += 1
        if tput_n:
            self._tput_sum += tput_sum / tput_n
            self._tput_ticks += 1
        if int(t) % 600 == 0:
            n = max(len(self.devices), 1)
            self._timeline["t"].append(t)
            self._timeline["gpu_util"].append(util[0] / n)
            self._timeline["sm_act"].append(util[1] / n)
            self._timeline["mem"].append(util[2] / n)
            self._timeline["slowdown"].append(slow_sum / max(slow_n, 1))
            self._timeline["tput"].append(tput_sum / max(tput_n, 1) if tput_n else 0.0)

    def _policy_perf(self, d: _Device, on, off) -> tuple[float, float]:
        """(online slowdown, offline normalized tput) per policy."""
        pol = self.cfg.policy
        if pol.startswith("muxflow"):
            return shared_performance(on, off, d.job.sm_share)
        if pol == "time-sharing":
            # fair time slices (Gandiva-style): offline takes ~half the time
            off_duty = 0.5
            slowdown = 1.0 + 0.9 * off_duty * min(1.0, on.gpu_util * 2.2)
            return slowdown, off_duty * 0.9
        if pol == "pb-time-sharing":
            # online priority: offline fills idle *time* only (AntMan/PAI)
            idle = max(0.0, 1.0 - on.gpu_util)
            return 1.05, idle * 0.8
        return 1.0, 0.0

    def _inject_error(self, d: _Device, t: float) -> None:
        self.errors_injected += 1
        kind = sample_error(self.rng)
        handled = self.err_handler.handle(kind)
        if handled.propagated:
            d.online_outage_until = t + self.cfg.online_outage_s
            self.online_incidents += 1
        if handled.action.value == "graceful_exit":
            # graceful exit checkpoints before releasing
            if d.job is not None:
                d.job.checkpoint_s = d.job.progress_s
        self._evict(d, t, requeue=True, count=False)

    def _evict(self, d: _Device, t: float, requeue: bool, count: bool = True) -> None:
        if d.job is None:
            return
        if count:
            self.evictions += 1
        job = d.job
        d.job = None
        if requeue and job.progress_s < job.spec.duration_s:
            # resume from last checkpoint
            spec = dataclasses.replace(
                job.spec, duration_s=job.spec.duration_s - job.checkpoint_s,
                submit_s=job.spec.submit_s)
            spec.job_id = job.spec.job_id
            self.pending.insert(0, spec)

    # -------------------------------------------------------------- results
    def _results(self, t_end: float) -> SimResults:
        r = SimResults(policy=self.cfg.policy, trace=self.cfg.trace)
        r.n_jobs = len(self.jobs)
        r.n_finished = len(self.finished)
        if self.finished:
            r.avg_jct_s = float(np.mean([jct for _, jct, _, _ in self.finished]))
            r.makespan_s = float(max(jct + s.submit_s
                                     for s, jct, _, _ in self.finished))
        r.avg_latency_ms = self._lat_sum / max(self._lat_wsum, 1e-9)
        r.base_avg_latency_ms = self._base_lat_sum / max(self._lat_wsum, 1e-9)
        r.avg_slowdown = r.avg_latency_ms / max(r.base_avg_latency_ms, 1e-9)
        if self._lat_samples:
            r.p99_latency_ms = float(np.percentile(self._lat_samples, 99))
        util = self._util_acc / max(self._util_ticks * len(self.devices), 1)
        r.gpu_util, r.sm_activity, r.mem_used = map(float, util)
        r.avg_norm_tput = self._tput_sum / max(self._tput_ticks, 1e-9)
        # Eq. 3: oversold GPU — effective separate-execution seconds delivered
        # per wall-second the offline workloads spent sharing a device
        prog = sum(d.job.progress_s for d in self.devices if d.job)
        wall = sum(d.job.shared_wall_s for d in self.devices if d.job)
        prog += sum(p for _, _, _, p in self.finished)
        wall += sum(w for _, _, w, _ in self.finished)
        r.oversold_gpu = float(min(1.0, prog / max(wall, 1e-9)))
        r.evictions = self.evictions
        r.eviction_frac = self.evictions / max(self.executions, 1)
        r.errors_injected = self.errors_injected
        r.errors_propagated = sum(1 for h in self.err_handler.handled if h.propagated)
        r.online_incidents = self.online_incidents
        r.timeline = self._timeline
        return r


def run_policy(policy: str, predictor: SpeedPredictor | None = None,
               **overrides) -> SimResults:
    cfg = SimConfig(policy=policy, **overrides)
    return ClusterSim(cfg, predictor).run()
