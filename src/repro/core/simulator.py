"""Trace-driven cluster simulator (§7.1: "Inspired by [Tiresias, Muri], we
build a simulator to evaluate a broader set of configurations, traces, and
baselines").

Fixed-tick discrete-event simulation of a GPU cluster where every device
hosts one online workload (diurnal QPS) and at most one offline workload.
Implements the full MuxFlow stack — dynamic SM allocation, the speed
predictor + KM matching scheduler, SysMonitor protection/eviction, the mixed
error handler, checkpoint/restart fault tolerance.  GPU-sharing behavior
(what gets scheduled, with what SM shares, and how a sharing pair performs)
is delegated to a pluggable :class:`repro.policies.SharingPolicy` resolved
through the policy registry — the paper's baselines (Online-only,
Gandiva-style time-sharing, AntMan/PAI-style priority time-sharing, the
MuxFlow-S/-M/-S-M ablations) and the related-work policies all live in
:mod:`repro.policies`, not here.

This module holds the *vectorized* engine: device state lives in
struct-of-arrays numpy form (:class:`FleetState`) and each 30 s tick is a
handful of array ops, so a 20 000-device × 12-hour trace simulates in minutes
on CPU.  Scheduling rounds go through the partitioned (sharded) matcher in
``core/scheduler.py``.  The original per-device reference engine survives in
``core/simulator_legacy.py``; a fixed-seed parity test pins this engine to
it.  Both engines draw per-tick randomness as (3, n_devices) uniform blocks
from one stream and read trace/profile inputs from the same vectorized
providers, so their trajectories are reproducible against each other.
"""
from __future__ import annotations

import collections.abc
import dataclasses
import time

import numpy as np

from repro.core.dynamic_sm import dynamic_sm_array, fixed_sm
from repro.core.errors import ERROR_MIX, MixedErrorHandler
from repro.core.interference import (OFFLINE_MODEL_PROFILES,
                                     ONLINE_SERVICE_PROFILES,
                                     memory_feasible, online_profile,
                                     online_profile_arrays)
from repro.core.matching import IncrementalMatcher
from repro.core.predictor import CachedSpeedPredictor, SpeedPredictor
from repro.core.scheduler import (OfflineJob, build_weight_grid_arrays,
                                  solve_matching, static_weight_grid)
from repro.core.sysmonitor import VectorSysMonitor
from repro.core.traces import (SERVICES, OfflineJobSpec, OnlineQPS, QPSBank,
                               make_trace)
from repro.policies import SharingPolicy
from repro.policies import resolve as resolve_policy

DEFAULT_HBM_GB = 16.0     # T4-class device the workload profiles are scaled to

_BASE_LATENCY_MS = {s: ONLINE_SERVICE_PROFILES[s]["base_latency_ms"]
                    for s in ONLINE_SERVICE_PROFILES}
_P99_BIN_MS = 0.05
_P99_MAX_MS = 10_000.0


ENGINES = ("numpy", "xla")


@dataclasses.dataclass
class SimConfig:
    # registry name (see repro.policies.available()) or a SharingPolicy
    # instance; resolved once at engine construction
    policy: str | SharingPolicy = "muxflow"
    n_devices: int = 200
    horizon_s: float = 12 * 3600.0
    tick_s: float = 30.0
    schedule_interval_s: float = 900.0        # 15 min (paper's testbed)
    checkpoint_interval_s: float = 300.0
    restart_delay_s: float = 90.0             # image pull + restore
    trace: str = "A"
    seed: int = 0
    gpu_types: tuple = ("T4", "T4", "T4", "A10")   # heterogeneous mix
    error_rate_per_job_hour: float = 0.05      # offline container errors
    graceful_exit: bool = True                 # MuxFlow's §4.2 mechanism
    device_mtbf_h: float = 4000.0              # hardware failures
    device_repair_s: float = 1800.0
    online_outage_s: float = 120.0             # when an error propagates
    memory_quota: float = 0.4
    # paper-scale knobs
    shard_size: int = 256                      # matcher partition bound
    predictor_cache_quantum: float = 0.02      # >0: memoize quantized rows
    # tick-engine backend: "numpy" (reference) or "xla" (compiled tick
    # kernel, bitwise-identical trajectories — see core/engine_xla.py)
    engine: str = "numpy"
    incremental_matching: bool = True          # reuse clean shards per round


@dataclasses.dataclass
class SimResults:
    policy: str
    trace: str
    # online
    avg_latency_ms: float = 0.0
    p99_latency_ms: float = 0.0
    base_avg_latency_ms: float = 0.0
    avg_slowdown: float = 1.0
    # offline
    n_jobs: int = 0
    n_finished: int = 0
    avg_jct_s: float = 0.0
    makespan_s: float = 0.0
    oversold_gpu: float = 0.0                  # Eq. 3
    avg_norm_tput: float = 0.0
    evictions: int = 0
    eviction_frac: float = 0.0
    # utilization (cluster averages)
    gpu_util: float = 0.0
    sm_activity: float = 0.0
    mem_used: float = 0.0
    # safety
    errors_injected: int = 0
    errors_propagated: int = 0
    online_incidents: int = 0
    # timeline (downsampled) for figure benchmarks
    timeline: dict = dataclasses.field(default_factory=dict)


class SimHooks:
    """Observation/control seam for the :mod:`repro.cluster` control plane.

    Subclass and override any subset; every method is a no-op by default, and
    the simulator only calls them when a hooks object is installed, so the
    default (hook-less) run is byte-identical to the pre-hook engine.  All
    callbacks receive the simulator itself so implementations can read fleet
    state without the engine having to marshal it per event.
    """

    def on_job_start(self, sim: "ClusterSim", t: float, device: int,
                     spec, share: float) -> None:
        """An offline job was placed on ``device`` with SM share ``share``."""

    def on_job_finish(self, sim: "ClusterSim", t: float, device: int,
                      spec, jct_s: float, wall_s: float,
                      progress_s: float) -> None:
        """An offline job ran to completion."""

    def on_job_evict(self, sim: "ClusterSim", t: float, device: int,
                     spec, reason: str, progress_s: float,
                     checkpoint_s: float, requeued: bool) -> None:
        """An offline job was evicted (``reason`` in ``{"overlimit", "error",
        "device_failure", "autoscale", "external"}``)."""

    def on_error(self, sim: "ClusterSim", t: float, device: int,
                 handled) -> None:
        """An offline container error was injected (``handled`` is the
        :class:`~repro.core.errors.HandledError`)."""

    def on_device_fail(self, sim: "ClusterSim", t: float, device: int,
                       until: float) -> None:
        """A hardware failure took ``device`` down until ``until``."""

    def on_schedule(self, sim: "ClusterSim", t: float, n_free: int,
                    n_pending_before: int, n_assigned: int,
                    wall_s: float) -> None:
        """A scheduling round completed (``wall_s`` is real wall time)."""

    def on_tick_end(self, sim: "ClusterSim", t: float,
                    telemetry: dict) -> None:
        """End of a tick; ``telemetry`` holds per-device arrays (qps,
        gpu_util, sm_activity, mem_used, sm_clock, level, busy, active,
        slowdown, tput).  Arrays are the engine's own buffers — copy what you
        keep."""


@dataclasses.dataclass
class FleetState:
    """Struct-of-arrays device state — the vectorized engine's hot data."""
    has_job: np.ndarray          # bool (n,)
    model_idx: np.ndarray        # int64 (n,) — offline model of current job
    sm_share: np.ndarray         # float64 (n,)
    progress: np.ndarray         # float64 (n,) separate-execution seconds
    checkpoint: np.ndarray       # float64 (n,) last checkpointed progress
    started: np.ndarray          # float64 (n,)
    wall: np.ndarray             # float64 (n,) shared wall seconds
    duration: np.ndarray         # float64 (n,) remaining-at-start duration
    failed_until: np.ndarray     # float64 (n,)
    outage_until: np.ndarray     # float64 (n,)

    @classmethod
    def zeros(cls, n: int) -> "FleetState":
        return cls(
            has_job=np.zeros(n, bool),
            model_idx=np.zeros(n, np.int64),
            sm_share=np.zeros(n, np.float64),
            progress=np.zeros(n, np.float64),
            checkpoint=np.zeros(n, np.float64),
            started=np.zeros(n, np.float64),
            wall=np.zeros(n, np.float64),
            duration=np.zeros(n, np.float64),
            failed_until=np.full(n, -1.0, np.float64),
            outage_until=np.full(n, -1.0, np.float64),
        )


class _OfflineView(collections.abc.Mapping):
    """Lazy per-device offline-profile gather handed to
    :meth:`SharingPolicy.shared_performance` as the ``off`` mapping.

    Each key (``gpu_util``, ``sm_activity``, ``sm_occupancy``, ``mem_bw``,
    ``exec_time_ms``, ``mem_bytes_frac``) is gathered from the per-model
    constant arrays on first access and memoized, so policies that ignore
    their offline partner's profile (time-sharing, dedicated, tally) cost
    nothing here.  The engine hands in a cache dict that survives across
    ticks until a placement changes ``model_idx`` (gathers are pure
    functions of it), so steady ticks skip the gathers entirely.  A real
    Mapping, so policies written against the documented dict-like contract
    (``.get``, iteration) work too.
    """

    __slots__ = ("_arrs", "_idx", "_cache")

    def __init__(self, arrs: dict[str, np.ndarray], model_idx: np.ndarray,
                 cache: dict[str, np.ndarray] | None = None):
        self._arrs = arrs
        self._idx = model_idx
        self._cache: dict[str, np.ndarray] = ({} if cache is None
                                              else cache)

    def __getitem__(self, key: str) -> np.ndarray:
        v = self._cache.get(key)
        if v is None:
            v = self._cache[key] = self._arrs[key][self._idx]
            # cached across ticks (until the next placement): freeze so a
            # policy mutating its inputs fails loudly, not silently
            v.flags.writeable = False
        return v

    def __iter__(self):
        return iter(self._arrs)

    def __len__(self) -> int:
        return len(self._arrs)


class ClusterSim:
    """Vectorized MuxFlow cluster simulator (paper-scale capable)."""

    def __init__(self, cfg: SimConfig, predictor: SpeedPredictor | None = None,
                 *, fleet=None, hooks: SimHooks | None = None,
                 external_jobs: bool = False):
        # registry resolution raises ValueError (listing every registered
        # policy) on unknown names — a real error, not an assert, so it
        # survives ``python -O``
        self.policy = resolve_policy(cfg.policy)
        self.cfg = cfg
        self.hooks = hooks
        self.rng = np.random.default_rng(cfg.seed)
        if self.policy.needs_predictor and predictor is None:
            raise ValueError(
                f"policy {self.policy.name!r} needs a speed predictor")
        if predictor is not None and cfg.predictor_cache_quantum > 0:
            predictor = CachedSpeedPredictor(
                predictor, quantum=cfg.predictor_cache_quantum)
        self.predictor = predictor
        n = cfg.n_devices
        # per-device static attributes (same construction order as the
        # reference engine so the RNG stream is shared)
        self.qps_bank = QPSBank([OnlineQPS(self.rng) for _ in range(n)])
        self.service_idx = np.array([i % len(SERVICES) for i in range(n)],
                                    np.int64)
        if fleet is not None:
            # heterogeneous fleet: duck-typed spec with per-device gpu_type /
            # speed / hbm_gb and a pool partition (see repro.cluster.fleet)
            assert len(fleet.gpu_type) == n, "fleet size != n_devices"
            self.gpu_type = list(fleet.gpu_type)
            self.speed = np.asarray(fleet.speed, np.float64)
            self.pool_of = np.asarray(fleet.pool_of, np.int64)
            self.pool_names = list(fleet.pool_names)
            hbm = np.asarray(fleet.hbm_gb, np.float64)
        else:
            self.gpu_type = [cfg.gpu_types[i % len(cfg.gpu_types)]
                             for i in range(n)]
            self.speed = np.array([1.35 if t == "A10" else 1.0
                                   for t in self.gpu_type], np.float64)
            self.pool_of = np.zeros(n, np.int64)
            self.pool_names = ["default"]
            hbm = np.full(n, DEFAULT_HBM_GB, np.float64)
        self.hbm_gb = hbm
        self.base_latency = np.array(
            [_BASE_LATENCY_MS[SERVICES[s]] for s in self.service_idx],
            np.float64)
        self.monitor = VectorSysMonitor(n, now=0.0)
        self.state = FleetState.zeros(n)
        self.job_spec: list[OfflineJobSpec | None] = [None] * n
        # offline model constants
        self.models = tuple(OFFLINE_MODEL_PROFILES)
        self.model_of = {m: i for i, m in enumerate(self.models)}
        profs = [OFFLINE_MODEL_PROFILES[m] for m in self.models]
        self.off_arrs = {
            "gpu_util": np.array([p.gpu_util for p in profs]),
            "sm_activity": np.array([p.sm_activity for p in profs]),
            "sm_occupancy": np.array([p.sm_occupancy for p in profs]),
            "mem_bw": np.array([p.mem_bw for p in profs]),
            "exec_time_ms": np.array([p.exec_time_ms for p in profs]),
            "mem_bytes_frac": np.array([p.mem_bytes_frac for p in profs]),
        }
        # xCUDA memory-quota feasibility per (pool, service, model) — memory
        # footprint fractions are profiled on a DEFAULT_HBM_GB device, so a
        # pool with more (less) HBM scales the fractions down (up)
        pool_hbm = np.array([hbm[self.pool_of == p].mean() if
                             (self.pool_of == p).any() else DEFAULT_HBM_GB
                             for p in range(len(self.pool_names))])
        self.feasible = np.array(
            [[[memory_feasible(
                self._scale_mem(online_profile(svc, 50.0), ph),
                self._scale_mem(OFFLINE_MODEL_PROFILES[m], ph),
                cfg.memory_quota)
               for m in self.models] for svc in SERVICES]
             for ph in pool_hbm])
        self.jobs = ([] if external_jobs
                     else make_trace(cfg.trace, n, cfg.horizon_s, cfg.seed))
        self.pending: list[OfflineJobSpec] = []
        self.err_handler = MixedErrorHandler(graceful_enabled=cfg.graceful_exit)
        # vectorized error-kind mapping: cumulative thresholds accumulated in
        # the exact order error_from_uniform walks them, so the mask-based
        # kind lookup is bitwise-faithful to the scalar path
        self._err_kinds = list(ERROR_MIX)
        probs = [ERROR_MIX[k] for k in self._err_kinds]
        self._err_total = sum(probs)
        acc, thresh = 0.0, []
        for p in probs:
            acc += p
            thresh.append(acc)
        self._err_thresh = np.array(thresh, np.float64)
        # per-kind handling-outcome tables, derived by probing the actual
        # §4.2 policy (a scratch handler with this run's flags) — the tick
        # cores consume only these tables, so MixedErrorHandler.handle
        # stays the single home of the propagation/graceful semantics
        probe = MixedErrorHandler(
            graceful_enabled=self.err_handler.graceful_enabled,
            detector_enabled=self.err_handler.detector_enabled)
        handled = [probe.handle(k) for k in self._err_kinds]
        self._err_propagates = np.array([h.propagated for h in handled])
        self._err_graceful_ck = np.array(
            [h.action.value == "graceful_exit" for h in handled])
        self.finished: list[tuple] = []            # (spec, jct, wall, progress)
        self.evictions = 0
        self.executions = 0
        self.errors_injected = 0
        self.online_incidents = 0
        # accumulators
        self._lat_sum = self._lat_wsum = 0.0
        self._base_lat_sum = 0.0
        self._lat_hist = np.zeros(int(_P99_MAX_MS / _P99_BIN_MS), np.int64)
        self._util_acc = np.zeros(3)
        self._util_ticks = 0
        self._tput_sum = self._tput_ticks = 0.0
        self._timeline: dict[str, list] = {"t": [], "gpu_util": [], "sm_act": [],
                                           "mem": [], "slowdown": [], "tput": []}
        # instrumentation for the scale benchmarks
        self.schedule_latencies: list[float] = []
        # optional request-level serving plane (repro.serving_plane); driven
        # from the engine-agnostic accounting epilogue so both tick engines
        # feed it identical arrays
        self.serving = None
        # optional observability plane (repro.obs) on the same epilogue
        # seam, and an opt-in wall-clock phase profiler — both None checks,
        # zero cost when disabled
        self.obs = None
        self.phases = None
        # optional chaos-plane campaign (repro.chaos.ChaosCampaign, set by
        # the control plane): _schedule consults it for predictor-outage /
        # matcher-budget fallbacks; None = the byte-identical no-chaos path
        self.chaos = None
        # step-loop state (the control plane drives ticks one at a time)
        self._job_i = 0
        self._next_sched = 0.0
        self._n_injected = 0
        self._ext_mask: np.ndarray | None = None
        # shared per-tick input caches (both engines read identical values)
        from repro.core.interference import online_profile_consts
        self._on_consts = online_profile_consts(self.service_idx, SERVICES)
        self._qps_memo: tuple[float, np.ndarray] | None = None
        self._gpu_type_arr = np.asarray(self.gpu_type)
        self._matcher = (IncrementalMatcher(shard_size=cfg.shard_size)
                         if cfg.incremental_matching else None)
        # per-placement-version caches of model-indexed gathers/products
        # (model_idx/sm_share change only in _start_job, which bumps
        # self.executions — the version stamp)
        self._off_cache: dict[str, np.ndarray] = {}
        self._off_cache_ver = -1
        # compiled tick engine (built lazily on the first xla tick)
        if cfg.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {cfg.engine!r}; available: {ENGINES}")
        self._xla = None

    def attach_serving(self, plane) -> None:
        """Attach a :class:`repro.serving_plane.ServingPlane`.  Its
        ``on_tick(t, slowdown, act, outage)`` runs inside :meth:`_account`
        — after the core arrays exist, before the tick closes — so request
        accounting sees exactly what the results accounting sees."""
        self.serving = plane

    def attach_obs(self, plane) -> None:
        """Attach a :class:`repro.obs.ObsPlane` (anything with
        ``on_tick(sim, inp, core)``).  Runs at the very end of
        :meth:`_account`, so rollups see the tick's final counter state.
        It must consume only the engine-agnostic per-tick arrays — the
        ``core`` dict carries post-tick ``has_job``/``mstate`` snapshots
        both engines export for exactly this purpose (live monitor/fleet
        state holds *block-end* values during xla block replay)."""
        self.obs = plane

    def attach_phases(self, profiler) -> None:
        """Attach a :class:`repro.obs.PhaseProfiler`.  Wall-clock only:
        its numbers are quarantined from every deterministic artifact
        (they surface in BENCH_sim.json and on stderr, never in reports)."""
        self.phases = profiler

    @staticmethod
    def _scale_mem(profile, hbm_gb: float):
        """Rescale a profile's memory fraction to a pool's HBM size."""
        if hbm_gb == DEFAULT_HBM_GB:
            return profile
        return dataclasses.replace(
            profile, mem_bytes_frac=min(
                1.0, profile.mem_bytes_frac * DEFAULT_HBM_GB / hbm_gb))

    # ------------------------------------------------------------------ run
    def run(self) -> SimResults:
        cfg = self.cfg
        t = 0.0
        n_ticks = int(cfg.horizon_s / cfg.tick_s)
        if cfg.engine == "xla":
            # compiled path: tick *blocks* run through one jitted
            # lax.scan between scheduling rounds (sparse events are
            # replayed from the kernel's stacked outputs)
            i = 0
            while i < n_ticks:
                n_block = n_ticks - i
                if self.policy.wants_scheduling:
                    # run up to the next scheduling boundary (a block whose
                    # first tick schedules extends to the boundary after
                    # it).  The boundary is found by replaying the per-tick
                    # engine's exact accumulated-float predicate
                    # (t >= next_sched) — an arithmetic shortcut (ceil of a
                    # division) lands on different ticks once tick_s is not
                    # exactly representable, silently breaking cross-engine
                    # byte-identity
                    ns = (t + cfg.schedule_interval_s
                          if t >= self._next_sched else self._next_sched)
                    n_block = 1
                    tj = t + cfg.tick_s
                    while n_block < n_ticks - i and tj < ns:
                        n_block += 1
                        tj += cfg.tick_s
                t = self._step_block(t, n_block)
                i += n_block
            return self._results(t)
        for _ in range(n_ticks):
            t = self.step(t)
        return self._results(t)

    def step(self, t: float) -> float:
        """Advance the engine one tick from time ``t``; returns the next tick
        time.  External drivers (the :mod:`repro.cluster` control plane) call
        this directly and interleave their own work between ticks."""
        return self._step_block(t, 1)

    def _step_block(self, t: float, n_block: int) -> float:
        """Advance ``n_block`` ticks; scheduling may only occur at the first
        tick of a block (callers align blocks to scheduling boundaries)."""
        cfg = self.cfg
        while (self._job_i < len(self.jobs)
               and self.jobs[self._job_i].submit_s <= t):
            self.pending.append(self.jobs[self._job_i])
            self._job_i += 1
        if self.policy.wants_scheduling and t >= self._next_sched:
            t0 = time.perf_counter()
            n_free, n_before = self._schedule(t)
            wall = time.perf_counter() - t0
            self.schedule_latencies.append(wall)
            if self.hooks is not None:
                self.hooks.on_schedule(self, t, n_free, n_before,
                                       n_before - len(self.pending), wall)
            self._next_sched = t + cfg.schedule_interval_s
        if n_block == 1:
            self._tick(t)
            return t + cfg.tick_s
        # multi-tick block: batch job arrivals tick-exactly (nothing reads
        # the pending queue until the next scheduling boundary)
        ts = [t]
        for _ in range(n_block - 1):
            ts.append(ts[-1] + cfg.tick_s)
        for tj in ts[1:]:
            while (self._job_i < len(self.jobs)
                   and self.jobs[self._job_i].submit_s <= tj):
                self.pending.append(self.jobs[self._job_i])
                self._job_i += 1
        self._tick_block(ts)
        return ts[-1] + cfg.tick_s

    # ------------------------------------------------- control-plane surface
    def inject_jobs(self, specs: list[OfflineJobSpec]) -> None:
        """Mid-run job submission (the control plane's JobManager path):
        specs join the pending queue immediately and count toward n_jobs."""
        self._n_injected += len(specs)
        self.pending.extend(specs)

    def force_error(self, i: int, t: float, kind):
        """Inject a specific :class:`~repro.core.errors.ErrorKind` on busy
        device ``i`` (fault-campaign entry point).  Routes through the mixed
        error handler exactly like the engine's own error process; returns
        the :class:`HandledError`, or None if the device has no offline job."""
        if not self.state.has_job[i]:
            return None
        requeues: list[tuple[int, OfflineJobSpec]] = []
        handled = self._handle_error(i, t, kind, requeues)
        if requeues:
            self.pending[:0] = [spec for _, spec in reversed(requeues)]
        return handled

    def evict_device(self, i: int, t: float, reason: str = "external",
                     count: bool = True) -> None:
        """Evict the offline job on device ``i`` (if any), requeueing it from
        its last checkpoint.  Used by autoscaler scale-ups and fault
        campaigns between ticks."""
        requeues: list[tuple[int, OfflineJobSpec]] = []
        self._evict(i, t, requeues, reason=reason, count=count)
        if requeues:
            self.pending[:0] = [spec for _, spec in reversed(requeues)]

    def set_schedulable_mask(self, mask: np.ndarray | None) -> None:
        """Extra per-device schedulability constraint ANDed into every
        scheduling round (e.g. node-agent heartbeat staleness).  Pass None to
        clear."""
        self._ext_mask = mask

    def pool_view(self, t: float) -> list[dict]:
        """Per-pool state snapshot (counts + load) for the control plane."""
        s = self.state
        alive = s.failed_until <= t
        qps = self.qps_bank.qps(t)
        sched = self.monitor.schedulable
        views = []
        for p, name in enumerate(self.pool_names):
            m = self.pool_of == p
            busy = m & s.has_job
            views.append({
                "pool": name,
                "n": int(m.sum()),
                "alive": int((m & alive).sum()),
                "busy": int(busy.sum()),
                "schedulable": int((m & sched).sum()),
                "mean_sm_share": (float(s.sm_share[busy].mean())
                                  if busy.any() else 0.0),
                "qps_sum": float(qps[m].sum()),
                "hbm_gb": float(self.hbm_gb[m].mean()) if m.any() else 0.0,
            })
        return views

    def finalize(self, t_end: float) -> SimResults:
        """Aggregate results after an externally driven step loop."""
        return self._results(t_end)

    # ------------------------------------------------------------- schedule
    def _schedule(self, t: float) -> tuple[int, int]:
        """One scheduling round; returns (n_free, n_pending_before)."""
        cfg = self.cfg
        s = self.state
        n_before = len(self.pending)
        sched_cfg = self.policy.scheduler_config(shard_size=cfg.shard_size)
        if sched_cfg is None:
            # greedy FIFO packing: any alive device without a job, SM share
            # handed out by the policy
            ok = ~s.has_job & (s.failed_until <= t)
            if self._ext_mask is not None:
                ok &= self._ext_mask
            free = np.flatnonzero(ok)
            take = free[:len(self.pending)]
            if take.size:
                qps = self.tick_qps(t)
                on = online_profile_arrays(self.service_idx, qps, SERVICES,
                                           consts=self._on_consts)
                shares = self.policy.sm_shares(on, take)
                for k, i in enumerate(take):
                    self._start_job(int(i), self.pending.pop(0),
                                    float(shares[k]), t)
            return int(free.size), n_before
        if not self.pending:
            return 0, n_before
        # free healthy devices (the paper only schedules onto Healthy GPUs)
        ok = ~s.has_job & (s.failed_until <= t) & self.monitor.schedulable
        if self._ext_mask is not None:
            ok &= self._ext_mask
        free = np.flatnonzero(ok)
        if free.size == 0:
            return 0, n_before
        qps = self.tick_qps(t)
        on = online_profile_arrays(self.service_idx, qps, SERVICES,
                                   consts=self._on_consts)
        jobs = [OfflineJob(sp.job_id, OFFLINE_MODEL_PROFILES[sp.model],
                           sp.duration_s) for sp in self.pending]
        # array-native Algorithm 1: weight grid without per-slot objects,
        # matching warm-started from the previous round's clean shards
        if sched_cfg.use_dynamic_sm:
            shares = dynamic_sm_array(on["sm_activity"][free])
        else:
            shares = np.full(free.size, fixed_sm(sched_cfg.fixed_sm_share),
                             np.float64)
        on_feats = np.stack(
            [on["gpu_util"][free], on["sm_activity"][free],
             on["sm_occupancy"][free], on["exec_time_ms"][free] / 1000.0],
            axis=1).astype(np.float32)
        ph = self.phases
        chaos = self.chaos

        def _grid():
            # degradation ladder: during a predictor outage the round runs
            # on the §4.3 static share table — no predictor call at all
            if chaos is not None and chaos.predictor_down(t):
                chaos.note_predictor_fallback(t)
                return static_weight_grid(shares, jobs, sched_cfg)
            return build_weight_grid_arrays(
                self._gpu_type_arr[free], on_feats, shares, jobs,
                self.predictor, sched_cfg)

        def _pairs(values, col_group):
            # degradation ladder: an exhausted matching time budget falls
            # back to greedy-FIFO placement (the MuxFlow-M ablation path)
            if chaos is not None and chaos.matcher_exhausted(t):
                chaos.note_matcher_fallback(t, free.size, len(jobs))
                greedy = dataclasses.replace(sched_cfg, use_matching=False)
                return solve_matching(values, col_group, greedy)
            return solve_matching(values, col_group, sched_cfg,
                                  row_ids=free, matcher=self._matcher)

        # _schedule runs in plain Python on both tick engines, so the
        # chaos consults above are engine-invariant by construction
        if ph is None:
            values, col_group = _grid()
            pairs = _pairs(values, col_group)
        else:
            with ph.phase("predict"):
                values, col_group = _grid()
            with ph.phase("match"):
                pairs = _pairs(values, col_group)
        by_job = {sp.job_id: sp for sp in self.pending}
        assigned: set[int] = set()
        for i, j in pairs:
            device_id = int(free[i])
            job_id = jobs[j].job_id
            spec = by_job.get(job_id)
            if spec is None or job_id in assigned:
                continue
            if not self.feasible[self.pool_of[device_id],
                                 self.service_idx[device_id],
                                 self.model_of[spec.model]]:
                continue  # xCUDA memory quota rejects the pairing
            assigned.add(job_id)
            self._start_job(device_id, spec, float(shares[i]), t)
        if assigned:
            self.pending = [sp for sp in self.pending
                            if sp.job_id not in assigned]
        return int(free.size), n_before

    def _start_job(self, i: int, spec: OfflineJobSpec, share: float,
                   t: float) -> None:
        s = self.state
        s.has_job[i] = True
        s.model_idx[i] = self.model_of[spec.model]
        s.sm_share[i] = share
        s.progress[i] = 0.0
        s.checkpoint[i] = 0.0
        s.started[i] = t
        s.wall[i] = 0.0
        s.duration[i] = spec.duration_s
        self.job_spec[i] = spec
        self.executions += 1
        if self.hooks is not None:
            self.hooks.on_job_start(self, t, i, spec, share)

    # ----------------------------------------------------------------- tick
    def tick_qps(self, t: float) -> np.ndarray:
        """Fleet QPS at tick time ``t``, memoized — the tick engine, the
        scheduler, and the control plane's autoscaler all read one row."""
        memo = self._qps_memo
        if memo is not None and memo[0] == t:
            return memo[1]
        row = self.qps_bank.qps(t)
        self._qps_memo = (t, row)
        return row

    def _tick_inputs(self, t: float) -> dict:
        """The tick's dense inputs: one (3, n) uniform block (the shared RNG
        contract with the reference engine: rows are hw-failure, error,
        error-kind), the trace/profile arrays, and the policy's vectorized
        shared-performance surfaces.  Both tick cores consume these verbatim,
        so their inputs are bitwise-identical by construction."""
        s = self.state
        fail_u, err_u, kind_u = self.rng.random((3, self.cfg.n_devices))
        qps = self.tick_qps(t)
        on = online_profile_arrays(self.service_idx, qps, SERVICES,
                                   consts=self._on_consts)
        # gathers/products below are pure functions of (model_idx, sm_share)
        # which only _start_job changes (version-stamped by `executions`) —
        # steady ticks reuse them outright
        if self._off_cache_ver != self.executions:
            self._off_cache = {}
            self._off_cache_ver = self.executions
        off = _OfflineView(self.off_arrs, s.model_idx, cache=self._off_cache)
        slow_raw, tput_raw = self.policy.shared_performance(on, off,
                                                           s.sm_share)
        tput_speed = tput_raw * self.speed
        prods = self._off_cache.get("_products")
        if prods is None:
            # telemetry products precomputed host-side: the compiled tick
            # core may contain no multiply that feeds an add/sub (LLVM
            # would be free to contract it into an FMA, breaking bitwise
            # engine parity), so every such product is formed here and
            # only *added* in the cores
            used_min = np.minimum(s.sm_share, off["sm_activity"])
            prods = (used_min, 0.62 * used_min, 0.45 * used_min,
                     off["mem_bytes_frac"])
            for arr in prods[:3]:
                arr.flags.writeable = False      # cached across ticks
            self._off_cache["_products"] = prods
        used_min, used62, used45, off_mem = prods
        return dict(t=t, qps=qps, on=on, fail_u=fail_u, err_u=err_u,
                    kind_u=kind_u, slow_raw=slow_raw, tput_speed=tput_speed,
                    tput_dt=tput_speed * self.cfg.tick_s,
                    used_min=used_min, used62=used62, used45=used45,
                    off_mem=off_mem)

    def _dense_core_numpy(self, inp: dict) -> dict:
        """One tick of dense per-device state evolution — the reference
        implementation of the tick core.  ``core/engine_xla.py`` compiles the
        exact same operations; a fixed-seed test pins the two cores to
        bitwise-identical outputs.  Mutates fleet/monitor state and returns
        the per-tick arrays the (engine-agnostic) accounting pass consumes.
        """
        cfg = self.cfg
        s = self.state
        t = inp["t"]
        dt = cfg.tick_s
        on = inp["on"]
        alive = s.failed_until <= t
        new_fail = alive & (inp["fail_u"] < dt / (cfg.device_mtbf_h * 3600.0))
        s.failed_until = np.where(new_fail, t + cfg.device_repair_s,
                                  s.failed_until)
        act = alive & ~new_fail
        busy = act & s.has_job
        has_job = s.has_job & ~new_fail
        slowdown = np.where(busy, inp["slow_raw"], 1.0)
        tput = np.where(busy, inp["tput_speed"], 0.0)
        # offline progress + periodic checkpoint
        s.progress = np.where(busy, s.progress + inp["tput_dt"], s.progress)
        s.wall = np.where(busy, s.wall + dt, s.wall)
        ck = busy & (s.progress - s.checkpoint >= cfg.checkpoint_interval_s)
        s.checkpoint = np.where(ck, s.progress, s.checkpoint)
        # error injection (offline container errors): kind + handling
        # outcome are pure functions of the uniforms — outcome via the
        # per-kind tables probed from MixedErrorHandler (see __init__)
        p_err = cfg.error_rate_per_job_hour * dt / 3600.0
        err = busy & (inp["err_u"] < p_err)
        # kind_idx is only meaningful where err is set (the xla core
        # computes the full array; the contract is mask-scoped)
        kind_idx = np.zeros(cfg.n_devices, np.int64)
        ei = np.flatnonzero(err)
        if ei.size:
            r = inp["kind_u"][ei] * self._err_total
            kind_idx[ei] = np.minimum(
                (r[:, None] > self._err_thresh[None, :]).sum(axis=1),
                len(self._err_kinds) - 1)
        propagated = err & self._err_propagates[kind_idx]
        s.outage_until = np.where(propagated, t + cfg.online_outage_s,
                                  s.outage_until)
        # graceful exit checkpoints before releasing
        s.checkpoint = np.where(err & self._err_graceful_ck[kind_idx],
                                s.progress, s.checkpoint)
        has_job = has_job & ~err
        # job completion (error-evicted devices dropped has_job already)
        fin = busy & has_job & (s.progress >= s.duration)
        has_job = has_job & ~fin
        # telemetry + SysMonitor.  Each expression is written so no product
        # directly feeds an add/sub (see _tick_inputs): ``c·used_off`` terms
        # use the host-precomputed products masked by has_job (bitwise equal
        # to scaling after masking, since c·0 == 0), and the clock scales
        # inside the max (bitwise equal: 420·max(0, z) == max(0, 420·z))
        used_off = np.where(has_job, inp["used_min"], 0.0)
        tele_util = np.minimum(
            1.0, on["gpu_util"] + np.where(has_job, inp["used62"], 0.0))
        tele_sm = np.minimum(
            1.0, on["sm_activity"] + np.where(has_job, inp["used45"], 0.0))
        tele_clock = 1590.0 - np.maximum(
            0.0, 420.0 * (on["sm_activity"] + used_off - 0.8))
        tele_mem = np.minimum(
            1.0, on["mem_bytes_frac"] + np.where(has_job, inp["off_mem"],
                                                 0.0))
        level = self.monitor.classify(tele_util, tele_sm, tele_mem,
                                      tele_clock, 60.0)
        evict_ev = self.monitor.update(level, t, active=act)
        evict_cand = evict_ev & has_job
        s.has_job = has_job & ~evict_cand
        # has_job/mstate: post-tick snapshots for the obs rollups — part of
        # the cross-engine core contract (the xla engine exports its
        # per-tick scan copies; live state would hold block-end values)
        return dict(new_fail=new_fail, err=err, kind_idx=kind_idx, fin=fin,
                    evict_cand=evict_cand, busy=busy, act=act,
                    slowdown=slowdown, tput=tput, tele_util=tele_util,
                    tele_sm=tele_sm, tele_clock=tele_clock, tele_mem=tele_mem,
                    level=level, progress=s.progress, wall=s.wall,
                    checkpoint=s.checkpoint, outage_until=s.outage_until,
                    has_job=s.has_job, mstate=self.monitor.state)

    def _account(self, inp: dict, core: dict) -> None:
        """The engine-agnostic tick epilogue: sparse event bookkeeping
        (hooks, requeues, counters) and every reduction that lands in
        :class:`SimResults`.  Runs in numpy for both engines, on core output
        arrays that are bitwise-identical between them — so results and
        event streams cannot drift across engines."""
        cfg = self.cfg
        t = inp["t"]
        n = cfg.n_devices
        progress, wall = core["progress"], core["wall"]
        checkpoint = core["checkpoint"]
        requeues: list[tuple[int, OfflineJobSpec]] = []
        for i in np.flatnonzero(core["new_fail"]):
            i = int(i)
            if self.hooks is not None:
                self.hooks.on_device_fail(self, t, i,
                                          t + cfg.device_repair_s)
            self._record_evict(i, t, requeues, reason="device_failure",
                               count=False, progress=float(progress[i]),
                               checkpoint=float(checkpoint[i]))
        for i in np.flatnonzero(core["err"]):
            i = int(i)
            kind = self._err_kinds[int(core["kind_idx"][i])]
            self.errors_injected += 1
            handled = self.err_handler.handle(kind)
            if handled.propagated:
                self.online_incidents += 1
            if self.hooks is not None:
                self.hooks.on_error(self, t, i, handled)
            self._record_evict(i, t, requeues, reason="error", count=False,
                               progress=float(progress[i]),
                               checkpoint=float(checkpoint[i]))
        for i in np.flatnonzero(core["fin"]):
            i = int(i)
            spec = self.job_spec[i]
            self.finished.append((spec, t - spec.submit_s,
                                  float(wall[i]), float(progress[i])))
            self.job_spec[i] = None
            if self.hooks is not None:
                self.hooks.on_job_finish(self, t, i, spec,
                                         t - spec.submit_s, float(wall[i]),
                                         float(progress[i]))
        for i in np.flatnonzero(core["evict_cand"]):
            i = int(i)
            self._record_evict(i, t, requeues, reason="overlimit",
                               count=True, progress=float(progress[i]),
                               checkpoint=float(checkpoint[i]))
        # requeues resume from checkpoint, at the head of the queue in the
        # reference engine's order (reverse device order)
        if requeues:
            requeues.sort(key=lambda e: e[0])
            self.pending[:0] = [spec for _, spec in reversed(requeues)]
        # online latency accounting (weighted by qps)
        act, busy = core["act"], core["busy"]
        slowdown, tput = core["slowdown"], core["tput"]
        tput_n = int(busy.sum())
        tput_sum = float(tput[busy].sum())
        outage = core["outage_until"] > t
        if self.serving is not None:
            if self.phases is None:
                self.serving.on_tick(t, slowdown, act, outage)
            else:
                with self.phases.phase("serving"):
                    self.serving.on_tick(t, slowdown, act, outage)
        lat = self.base_latency * slowdown * np.where(outage, 10.0, 1.0)
        lat_a, qps_a = lat[act], inp["qps"][act]
        self._lat_sum += float((lat_a * qps_a).sum())
        self._base_lat_sum += float((self.base_latency[act] * qps_a).sum())
        self._lat_wsum += float(qps_a.sum())
        np.add.at(self._lat_hist,
                  np.minimum((lat_a / _P99_BIN_MS).astype(np.int64),
                             self._lat_hist.size - 1), 1)
        tele_util, tele_sm = core["tele_util"], core["tele_sm"]
        tele_mem = core["tele_mem"]
        util = np.array([tele_util[act].sum(), tele_sm[act].sum(),
                         tele_mem[act].sum()])
        self._util_acc += util
        self._util_ticks += 1
        if tput_n:
            self._tput_sum += tput_sum / tput_n
            self._tput_ticks += 1
        if self.hooks is not None:
            self.hooks.on_tick_end(self, t, {
                "qps": inp["qps"], "gpu_util": tele_util,
                "sm_activity": tele_sm, "mem_used": tele_mem,
                "sm_clock": core["tele_clock"], "level": core["level"],
                "busy": busy, "active": act, "slowdown": slowdown,
                "tput": tput})
        if int(t) % 600 == 0:
            slow_n = int(act.sum())
            self._timeline["t"].append(t)
            self._timeline["gpu_util"].append(util[0] / max(n, 1))
            self._timeline["sm_act"].append(util[1] / max(n, 1))
            self._timeline["mem"].append(util[2] / max(n, 1))
            self._timeline["slowdown"].append(
                float(slowdown[act].sum()) / max(slow_n, 1))
            self._timeline["tput"].append(
                tput_sum / max(tput_n, 1) if tput_n else 0.0)
        if self.obs is not None:
            self.obs.on_tick(self, inp, core)

    def _tick(self, t: float) -> None:
        ph = self.phases
        if ph is None:
            inp = self._tick_inputs(t)
            if self.cfg.engine == "xla":
                core = self._xla_engine().tick(inp)
            else:
                core = self._dense_core_numpy(inp)
            self._account(inp, core)
            return
        with ph.phase("inputs"):
            inp = self._tick_inputs(t)
        with ph.phase("dense_core"):
            core = (self._xla_engine().tick(inp)
                    if self.cfg.engine == "xla"
                    else self._dense_core_numpy(inp))
        with ph.phase("account", exclude=("serving",)):
            self._account(inp, core)

    def _tick_block(self, ts: list[float]) -> None:
        """A scheduling-free run of consecutive ticks.  The xla engine scans
        the whole block through one compiled kernel call and the accounting
        pass replays each tick from the stacked outputs; the numpy engine
        simply ticks."""
        if self.cfg.engine != "xla":
            for t in ts:
                self._tick(t)
            return
        ph = self.phases
        if ph is None:
            inps = [self._tick_inputs(t) for t in ts]
            for inp, core in zip(inps, self._xla_engine().tick_block(inps)):
                self._account(inp, core)
            return
        with ph.phase("inputs"):
            inps = [self._tick_inputs(t) for t in ts]
        with ph.phase("dense_core"):
            cores = self._xla_engine().tick_block(inps)
        with ph.phase("account", exclude=("serving",)):
            for inp, core in zip(inps, cores):
                self._account(inp, core)

    def _xla_engine(self):
        if self._xla is None:
            from repro.core.engine_xla import XlaTickEngine
            self._xla = XlaTickEngine(self)
        return self._xla

    def _handle_error(self, i: int, t: float, kind, requeues: list):
        """One offline-container error on device ``i`` — the *between-tick*
        path (``force_error``/fault campaigns).  In-tick errors evolve
        state inside the dense cores via the per-kind outcome tables
        probed from :class:`MixedErrorHandler` in ``__init__`` (handler
        semantics have one home) and book-keep through the same
        ``err_handler.handle`` call in ``_account``, so the two paths'
        injected/propagated accounting cannot drift."""
        self.errors_injected += 1
        handled = self.err_handler.handle(kind)
        if handled.propagated:
            self.state.outage_until[i] = t + self.cfg.online_outage_s
            self.online_incidents += 1
        if handled.action.value == "graceful_exit":
            # graceful exit checkpoints before releasing
            self.state.checkpoint[i] = self.state.progress[i]
        if self.hooks is not None:
            self.hooks.on_error(self, t, i, handled)
        self._evict(i, t, requeues, reason="error", count=False)
        return handled

    def _evict(self, i: int, t: float, requeues: list, *,
               reason: str = "overlimit", count: bool = True) -> None:
        """Mutating eviction — the between-tick path (autoscaler, fault
        campaigns, external callers).  In-tick evictions clear state inside
        the dense core and only book-keep via :meth:`_record_evict`."""
        s = self.state
        if not s.has_job[i]:
            return
        s.has_job[i] = False
        self._record_evict(i, t, requeues, reason=reason, count=count,
                           progress=float(s.progress[i]),
                           checkpoint=float(s.checkpoint[i]))

    def _record_evict(self, i: int, t: float, requeues: list, *,
                      reason: str, count: bool, progress: float,
                      checkpoint: float) -> None:
        """Eviction bookkeeping: counters, requeue from checkpoint, hook."""
        spec = self.job_spec[i]
        if spec is None:
            return
        if count:
            self.evictions += 1
        self.job_spec[i] = None
        requeued = progress < spec.duration_s
        if requeued:
            # resume from last checkpoint
            requeues.append((i, dataclasses.replace(
                spec, duration_s=spec.duration_s - checkpoint)))
        if self.hooks is not None:
            self.hooks.on_job_evict(self, t, i, spec, reason, progress,
                                    checkpoint, requeued)

    # -------------------------------------------------------------- results
    def _results(self, t_end: float) -> SimResults:
        s = self.state
        r = SimResults(policy=self.policy.name, trace=self.cfg.trace)
        r.n_jobs = len(self.jobs) + self._n_injected
        r.n_finished = len(self.finished)
        if self.finished:
            r.avg_jct_s = float(np.mean([jct for _, jct, _, _ in self.finished]))
            r.makespan_s = float(max(jct + sp.submit_s
                                     for sp, jct, _, _ in self.finished))
        r.avg_latency_ms = self._lat_sum / max(self._lat_wsum, 1e-9)
        r.base_avg_latency_ms = self._base_lat_sum / max(self._lat_wsum, 1e-9)
        r.avg_slowdown = r.avg_latency_ms / max(r.base_avg_latency_ms, 1e-9)
        total = int(self._lat_hist.sum())
        if total:
            k = int(np.searchsorted(np.cumsum(self._lat_hist),
                                    np.ceil(0.99 * total)))
            r.p99_latency_ms = (k + 1) * _P99_BIN_MS
        util = self._util_acc / max(self._util_ticks * self.cfg.n_devices, 1)
        r.gpu_util, r.sm_activity, r.mem_used = map(float, util)
        r.avg_norm_tput = self._tput_sum / max(self._tput_ticks, 1e-9)
        # Eq. 3: oversold GPU — effective separate-execution seconds delivered
        # per wall-second the offline workloads spent sharing a device
        prog = float(s.progress[s.has_job].sum())
        wall = float(s.wall[s.has_job].sum())
        prog += sum(p for _, _, _, p in self.finished)
        wall += sum(w for _, _, w, _ in self.finished)
        r.oversold_gpu = float(min(1.0, prog / max(wall, 1e-9)))
        r.evictions = self.evictions
        r.eviction_frac = self.evictions / max(self.executions, 1)
        r.errors_injected = self.errors_injected
        r.errors_propagated = sum(1 for h in self.err_handler.handled
                                  if h.propagated)
        r.online_incidents = self.online_incidents
        r.timeline = self._timeline
        return r


def build_sim_config(policy: str | SharingPolicy,
                     **overrides) -> tuple[SimConfig, SharingPolicy]:
    """The one shared config-resolution path for every ``run_policy*``
    entry point (this module's and the control plane's): the policy resolves
    through the registry here — unknown names raise ``ValueError`` listing
    every registered policy — and lands in the config as the resolved
    object, so policy validation cannot drift between entry points.
    (Predictor validation has a single home too: ``ClusterSim.__init__``.)
    """
    pol = resolve_policy(policy)
    return SimConfig(policy=pol, **overrides), pol


def run_policy(policy: str | SharingPolicy,
               predictor: SpeedPredictor | None = None,
               **overrides) -> SimResults:
    cfg, _ = build_sim_config(policy, **overrides)
    return ClusterSim(cfg, predictor).run()
