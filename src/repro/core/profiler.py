"""Workload profiler (§3): when an offline workload is first submitted it is
dry-run for a few iterations on a dedicated device; the measured execution
info feeds the speed predictor.  Works on real step callables (timed) or on
trace metadata (simulated).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.interference import OFFLINE_MODEL_PROFILES, WorkloadProfile


@dataclasses.dataclass
class ProfileStore:
    """The paper stores measured profiles in a database keyed by workload."""
    profiles: dict = dataclasses.field(default_factory=dict)

    def get(self, key: str) -> WorkloadProfile | None:
        return self.profiles.get(key)

    def put(self, key: str, profile: WorkloadProfile) -> None:
        self.profiles[key] = profile


def profile_step_fn(step_fn: Callable[[], None], *, name: str,
                    warmup: int = 2, iters: int = 5,
                    flops_per_step: float = 0.0,
                    bytes_per_step: float = 0.0,
                    peak_flops: float = 197e12,
                    peak_bw: float = 819e9,
                    mem_bytes: int = 0,
                    device_bytes: int = 16 << 30) -> WorkloadProfile:
    """Run a few iterations and derive the profile features.  On CPU the
    'SM activity' analogue is estimated from the step's achieved FLOP and
    byte rates against the device peaks (duty fractions)."""
    for _ in range(warmup):
        step_fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        step_fn()
    dt = (time.perf_counter() - t0) / iters
    compute_frac = min(1.0, (flops_per_step / peak_flops) / max(dt, 1e-9))
    bw_frac = min(1.0, (bytes_per_step / peak_bw) / max(dt, 1e-9))
    return WorkloadProfile(
        name=name, gpu_util=0.95, sm_activity=max(compute_frac, 0.05),
        sm_occupancy=0.5, mem_bw=max(bw_frac, 0.05), exec_time_ms=dt * 1e3,
        mem_bytes_frac=mem_bytes / device_bytes)


def profile_from_trace(model: str) -> WorkloadProfile:
    return OFFLINE_MODEL_PROFILES[model]
