"""Deprecated shim — the workload profiler moved to
:mod:`repro.profiling.workloads` (the single metrics-sampling path).

``ProfileStore``, ``profile_step_fn`` and ``profile_from_trace`` are
re-exported unchanged so existing imports keep working; new code should use
the catalog (:func:`repro.profiling.workloads.build_catalog` /
:func:`~repro.profiling.workloads.execute`) instead.
"""
from __future__ import annotations

import warnings

from repro.profiling.workloads import (ProfileStore, profile_from_trace,  # noqa: F401
                                       profile_step_fn)

warnings.warn(
    "repro.core.profiler is deprecated; use repro.profiling.workloads",
    DeprecationWarning, stacklevel=2)
