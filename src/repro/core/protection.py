"""Workload-level performance protection (§4.1, the xCUDA analogue).

Three pieces, verbatim from the paper where math is given:

  * GPU-load law (Eq. 1–2): U_GPU = U_SM · a_C with the piecewise clock factor
    a_C around the SM-clock threshold T_SM (a_L ≫ a_H so raising a depressed
    clock dominates raising utilization).
  * A PID controller turning the GPU-load error into the offline duty
    fraction (kernel-launch delay on GPUs; microstep duty on TPU pods).
  * A memory-quota ledger that intercepts offline allocations (xCUDA
    intercepts ~800 CUDA driver APIs; here the allocation seam is explicit).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Protocol


class Clock(Protocol):
    """Injectable time source for the PID/duty loop and telemetry buffers.

    Production uses :class:`WallClock`; profiling runs and tests inject a
    :class:`VirtualClock` so every timestamp and PID ``dt`` is an exact
    function of the inputs (no ``time.time()`` in the control loops)."""

    def time(self) -> float: ...


class WallClock:
    """The default clock: real wall time."""

    @staticmethod
    def time() -> float:
        return time.time()


class VirtualClock:
    """Deterministic, manually advanced clock."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def time(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        self._t += float(dt)
        return self._t


@dataclasses.dataclass(frozen=True)
class ClockFactorConfig:
    """Eq. 2 parameters.  a_L >> a_H (paper: prefer clock recovery)."""
    t_sm: float = 1350.0       # SM clock threshold (MHz, T4-like)
    c_high: float = 1590.0     # highest SM clock
    a_l: float = 4.0           # low-clock weight (a_L >> a_H)
    a_h: float = 0.5           # high-clock weight


def clock_factor(c_sm: float, cfg: ClockFactorConfig = ClockFactorConfig()) -> float:
    """Eq. 2: a_C as a function of the current SM clock."""
    if c_sm < cfg.t_sm:
        return 1.0 + cfg.a_l * (cfg.t_sm - c_sm) / cfg.t_sm
    return 1.0 - cfg.a_h * (c_sm - cfg.t_sm) / max(cfg.c_high - cfg.t_sm, 1e-9)


def gpu_load(u_sm: float, a_c: float) -> float:
    """Eq. 1: U_GPU = U_SM × a_C."""
    return u_sm * a_c


@dataclasses.dataclass
class PIDConfig:
    kp: float = 0.8
    ki: float = 0.15
    kd: float = 0.05
    setpoint: float = 0.85      # target GPU load
    out_min: float = 0.0
    out_max: float = 1.0
    integral_clamp: float = 2.0


class PIDController:
    """Classic PID on the GPU-load error; output = offline duty fraction.
    (The paper: 'xCUDA leverages the PID algorithm to provide more stable and
    robust controlling.')"""

    def __init__(self, cfg: PIDConfig = PIDConfig(), initial: float = 0.4):
        self.cfg = cfg
        self.integral = 0.0
        self.prev_error: float | None = None
        self.output = initial

    def update(self, measured_load: float, dt: float = 1.0) -> float:
        cfg = self.cfg
        error = cfg.setpoint - measured_load    # >0: room for more offline work
        self.integral = max(-cfg.integral_clamp,
                            min(cfg.integral_clamp, self.integral + error * dt))
        deriv = 0.0 if self.prev_error is None else (error - self.prev_error) / dt
        self.prev_error = error
        delta = cfg.kp * error + cfg.ki * self.integral + cfg.kd * deriv
        self.output = max(cfg.out_min, min(cfg.out_max, self.output + delta * dt))
        return self.output


class QuotaExceeded(RuntimeError):
    pass


class MemoryQuota:
    """Allocation ledger for the offline workload (paper: quota fixed to 40 %
    of device memory, because ~90 % of online workloads use < 60 %)."""

    def __init__(self, device_bytes: int, quota_frac: float = 0.4):
        self.device_bytes = int(device_bytes)
        self.quota_bytes = int(device_bytes * quota_frac)
        self.used = 0
        self._allocs: dict[int, int] = {}
        self._next = 0

    def alloc(self, nbytes: int) -> int:
        if self.used + nbytes > self.quota_bytes:
            raise QuotaExceeded(
                f"offline alloc {nbytes} exceeds quota "
                f"({self.used}/{self.quota_bytes} used)")
        self._next += 1
        self._allocs[self._next] = int(nbytes)
        self.used += int(nbytes)
        return self._next

    def free(self, handle: int) -> None:
        self.used -= self._allocs.pop(handle)

    def would_fit(self, nbytes: int) -> bool:
        return self.used + nbytes <= self.quota_bytes

    @property
    def frac_used(self) -> float:
        return self.used / max(self.device_bytes, 1)


class KernelThrottle:
    """The kernel-launch gate: xCUDA delays offline launches when U_GPU is
    high and releases them when it is low.  `should_launch` is consulted
    before every offline quantum; the PID keeps the duty near the allowance.
    """

    def __init__(self, pid: PIDController | None = None,
                 clock_cfg: ClockFactorConfig = ClockFactorConfig(),
                 clock: Clock | None = None):
        self.pid = pid or PIDController()
        self.clock_cfg = clock_cfg
        self.clock = clock or WallClock()
        self.duty = self.pid.output       # offline duty fraction in [0,1]
        self._credit = 0.0
        self._last_obs: float | None = None
        self.frozen = False               # graceful-exit freeze (§4.2)

    def observe(self, u_sm: float, c_sm: float, dt: float = 1.0) -> float:
        """Feed telemetry; returns the updated duty fraction."""
        load = gpu_load(u_sm, clock_factor(c_sm, self.clock_cfg))
        self.duty = self.pid.update(load, dt)
        return self.duty

    # below this, a sample is coalesced into the previous one: feeding the
    # PID a near-zero dt would blow up the derivative term (error delta
    # divided by dt) and slam the duty to a rail
    MIN_OBSERVE_DT_S = 1e-3

    def observe_now(self, u_sm: float, c_sm: float) -> float:
        """Feed telemetry stamped by the injected clock: ``dt`` is the time
        since the previous observation (1.0 on the first).  The duty loop
        never reads wall time directly — swap in a :class:`VirtualClock` and
        the whole PID trajectory is deterministic.  Samples arriving within
        ``MIN_OBSERVE_DT_S`` of the previous one are dropped (duty
        unchanged) rather than fed to the PID with an explosive dt."""
        now = self.clock.time()
        if self._last_obs is None:
            dt = 1.0
        else:
            dt = now - self._last_obs
            if dt < self.MIN_OBSERVE_DT_S:
                return self.duty
        self._last_obs = now
        return self.observe(u_sm, c_sm, dt)

    def should_launch(self, quantum: float = 1.0) -> bool:
        """Credit-based gate: offline work may take `duty` fraction of time."""
        if self.frozen:
            return False
        self._credit += self.duty * quantum
        if self._credit >= quantum:
            self._credit -= quantum
            return True
        return False

    def freeze(self) -> None:
        self.frozen = True


@dataclasses.dataclass
class DeviceTelemetry:
    """One GPU-monitor sample (collection interval is milliseconds-level)."""
    ts: float
    gpu_util: float
    sm_activity: float
    sm_clock: float
    mem_used_frac: float
    power_w: float = 70.0
    temp_c: float = 60.0


class GPUMonitor:
    """Rolling telemetry buffer: 'stores the metrics for only several minutes
    because old data ... are useless for timely workload management.'"""

    def __init__(self, horizon_s: float = 300.0, clock: Clock | None = None):
        self.horizon_s = horizon_s
        self.clock = clock or WallClock()
        self.samples: list[DeviceTelemetry] = []

    def sample(self, gpu_util: float, sm_activity: float, sm_clock: float,
               mem_used_frac: float, **kw) -> DeviceTelemetry:
        """Record a sample stamped by the injected clock."""
        s = DeviceTelemetry(ts=self.clock.time(), gpu_util=gpu_util,
                            sm_activity=sm_activity, sm_clock=sm_clock,
                            mem_used_frac=mem_used_frac, **kw)
        self.record(s)
        return s

    def record(self, sample: DeviceTelemetry) -> None:
        self.samples.append(sample)
        cutoff = sample.ts - self.horizon_s
        while self.samples and self.samples[0].ts < cutoff:
            self.samples.pop(0)

    def latest(self) -> DeviceTelemetry | None:
        return self.samples[-1] if self.samples else None

    def mean(self, attr: str, window_s: float = 30.0) -> float:
        if not self.samples:
            return 0.0
        cutoff = self.samples[-1].ts - window_s
        vals = [getattr(s, attr) for s in self.samples if s.ts >= cutoff]
        return sum(vals) / max(len(vals), 1)
