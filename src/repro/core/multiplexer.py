"""On-device space-sharing executor — the TPU-native xCUDA analogue.

One device loop interleaves an *online* serving function (priority; batched
decode requests with an SLO) and an *offline* training function (best-effort
microsteps).  The offline duty fraction plays the SM-percentage role:

  * the PID-driven KernelThrottle (protection.py, Eq. 1–2) gates offline
    microsteps from device telemetry (duty cycle ↔ U_SM, clock factor),
  * the MemoryQuota ledger enforces the offline HBM quota before the offline
    state is ever allocated,
  * GracefulExit freezes offline launches and checkpoints on SIGINT/SIGTERM,
  * an SLO guard (latency-based eviction) mirrors SysMonitor's Overlimit.

Runs on a virtual clock by default (deterministic tests) or wall-clock.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable

import numpy as np

from repro.core.errors import GracefulExit
from repro.core.protection import (KernelThrottle, MemoryQuota, PIDConfig,
                                   PIDController, QuotaExceeded)


@dataclasses.dataclass
class Request:
    arrival: float
    request_id: int
    done: float | None = None

    @property
    def latency(self) -> float:
        return (self.done - self.arrival) if self.done is not None else float("inf")


@dataclasses.dataclass
class MuxConfig:
    slo_slowdown: float = 1.2        # protect online latency to <= 1.2x base
    max_batch: int = 8               # online serving batch cap
    quantum_s: float = 0.010         # scheduling quantum (one decode step)
    telemetry_interval_s: float = 0.1
    evict_after_violations: int = 50  # SysMonitor-style overlimit -> evict
    latency_budget_s: float | None = None   # absolute end-to-end budget
    quota_frac: float = 0.4
    device_bytes: int = 16 << 30


@dataclasses.dataclass
class MuxStats:
    served: int = 0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    base_ms: float = 0.0
    offline_steps: int = 0
    offline_duty: float = 0.0
    oversold: float = 0.0            # offline steps / steps it would do alone
    evicted: bool = False
    slo_violations: int = 0


class Multiplexer:
    """Interleaves online serving with offline training on one device.

    online_fn(batch_size) -> latency_s of one serving step (measured or
    modeled); offline_fn() -> duration_s of one training microstep.  With
    real JAX step functions, pass wrappers that execute and time them.
    """

    def __init__(self, online_fn: Callable[[int], float],
                 offline_fn: Callable[[], float],
                 base_step_s: float,
                 offline_step_s: float,
                 cfg: MuxConfig = MuxConfig(),
                 offline_state_bytes: int = 0):
        self.online_fn = online_fn
        self.offline_fn = offline_fn
        self.base_step_s = base_step_s
        self.offline_step_s = offline_step_s
        self.cfg = cfg
        self.quota = MemoryQuota(cfg.device_bytes, cfg.quota_frac)
        if offline_state_bytes:
            self.quota.alloc(offline_state_bytes)  # raises QuotaExceeded
        # PID setpoint: keep measured online latency at slo
        self.throttle = KernelThrottle(PIDController(
            PIDConfig(setpoint=cfg.slo_slowdown, kp=0.6, ki=0.1, kd=0.0,
                      out_min=0.0, out_max=0.95), initial=0.5))
        self.stats = MuxStats(base_ms=base_step_s * 1e3)
        self._latencies: list[float] = []
        self._violations = 0
        # callers may install a GracefulExit wired with their own
        # checkpoint/release callbacks (examples/serve_multiplex.py); the
        # run loop falls back to a bare freeze-only harness otherwise
        self.graceful: GracefulExit | None = None

    def run(self, arrivals: list[float], horizon_s: float,
            max_offline_steps: int | None = None) -> MuxStats:
        """Simulated-clock loop: serve `arrivals` (sorted times), fill idle
        quanta with offline microsteps while the PID allows."""
        cfg = self.cfg
        queue: list[Request] = []
        pending = [Request(a, i) for i, a in enumerate(sorted(arrivals))]
        t = 0.0
        i = 0
        offline_steps = 0
        duty_acc = duty_n = 0.0
        gex = self.graceful or GracefulExit(throttle=self.throttle)
        if gex.throttle is None:
            gex.throttle = self.throttle
        with gex:
            while t < horizon_s:
                while i < len(pending) and pending[i].arrival <= t:
                    heapq.heappush(queue, (pending[i].arrival, pending[i]))
                    i += 1
                if queue:
                    batch = [heapq.heappop(queue)[1]
                             for _ in range(min(cfg.max_batch, len(queue)))]
                    dt = self.online_fn(len(batch))
                    t += dt
                    budget = (cfg.latency_budget_s
                              or cfg.slo_slowdown * self.base_step_s * 4)
                    for r in batch:
                        r.done = t
                        self._latencies.append(r.latency)
                        if r.latency > budget:
                            self._violations += 1
                    # telemetry -> PID: measured slowdown of this step
                    slowdown = dt / max(self.base_step_s, 1e-9)
                    # PID drives duty so that slowdown tracks the SLO bound:
                    self.throttle.pid.cfg.setpoint = cfg.slo_slowdown
                    self.throttle.duty = self.throttle.pid.update(slowdown, dt)
                    duty_acc += self.throttle.duty
                    duty_n += 1
                    if self._violations >= cfg.evict_after_violations:
                        self.stats.evicted = True   # SysMonitor Overlimit
                        break
                elif (not self.throttle.frozen
                      and self.throttle.should_launch(cfg.quantum_s)
                      and (max_offline_steps is None
                           or offline_steps < max_offline_steps)):
                    dt = self.offline_fn()
                    t += dt
                    offline_steps += 1
                else:
                    # idle quantum (throttled): time still passes in quanta so
                    # the throttle keeps accruing offline credit
                    t += cfg.quantum_s
        s = self.stats
        s.served = len(self._latencies)
        if self._latencies:
            lat = np.array(self._latencies) * 1e3
            s.p50_ms = float(np.percentile(lat, 50))
            s.p99_ms = float(np.percentile(lat, 99))
        s.offline_steps = offline_steps
        s.offline_duty = duty_acc / max(duty_n, 1)
        alone = horizon_s / max(self.offline_step_s, 1e-9)
        s.oversold = offline_steps / max(alone, 1e-9)
        s.slo_violations = self._violations
        return s
