"""Workload traces for the trace-driven simulator (§7.1).

Online: per-device services with diurnal QPS curves in the paper's 20–190
range ("requests ... periodical in days, smooth in minutes").  Offline: a
Microsoft-Philly-like job trace (lognormal durations, bursty Poisson
submissions, four DL models: ResNet50 / VGG16 / DenseNet201 / Inception-V3),
split into virtual-cluster sub-traces A–D like the paper splits the public
trace by virtual cluster ID.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np

from repro.core.interference import OFFLINE_MODEL_PROFILES

DAY_S = 86400.0

SERVICES = ("recommend", "translate", "vision")


@dataclasses.dataclass(frozen=True)
class OnlineTraceCfg:
    qps_lo: float = 20.0
    qps_hi: float = 190.0
    noise: float = 0.04          # minute-scale smoothness
    burst_rate_per_day: float = 1.5
    burst_mult: float = 1.9
    burst_len_s: float = 600.0


class OnlineQPS:
    """Deterministic diurnal QPS for one device: sinusoid + slow noise +
    occasional bursts ('the online requests may suddenly burst')."""

    def __init__(self, rng: np.random.Generator, cfg: OnlineTraceCfg = OnlineTraceCfg()):
        self.cfg = cfg
        self.base = rng.uniform(cfg.qps_lo * 1.4, cfg.qps_hi * 0.55)
        self.amp = self.base * rng.uniform(0.35, 0.6)
        self.phase = rng.uniform(0, DAY_S)
        self.noise_seed = rng.integers(1 << 30)
        n_bursts = rng.poisson(cfg.burst_rate_per_day)
        self.bursts = [(rng.uniform(0, DAY_S), cfg.burst_len_s,
                        rng.uniform(1.3, cfg.burst_mult)) for _ in range(n_bursts)]

    def qps(self, t: float) -> float:
        c = self.cfg
        v = self.base + self.amp * math.sin(2 * math.pi * (t - self.phase) / DAY_S)
        # slow, smooth noise (period ~13 min, deterministic)
        v *= 1.0 + c.noise * math.sin(2 * math.pi * t / 777.0 + self.noise_seed % 7)
        for start, ln, mult in self.bursts:
            if start <= (t % DAY_S) < start + ln:
                v *= mult
        return float(np.clip(v, c.qps_lo, c.qps_hi * 1.3))


class QPSBank:
    """Struct-of-arrays view over a fleet of :class:`OnlineQPS` curves.

    ``qps(t)`` evaluates the whole fleet in a handful of numpy ops; this is
    what all simulator engines consume, which keeps the vectorized engine,
    the compiled-tick engine, and the per-device reference engine on
    identical trace inputs.

    The diurnal sinusoid is evaluated through the angle-addition identity
    ``sin(a - b) = sin(a)·cos(b) - cos(a)·sin(b)`` with the per-device phase
    terms (``sin(b)``, ``cos(b)``) precomputed at construction — one pair of
    scalar trig calls per tick instead of an ``n_devices``-wide ``sin``,
    which at 20 000 devices is the difference between ~5 ms and ~0.2 ms per
    tick.  The minute-scale noise term's argument takes only seven distinct
    values (``noise_seed % 7``), so it is evaluated on a small table and
    gathered.  :meth:`qps_block` delegates to :meth:`qps` row by row, so
    single-tick and block evaluation are one code path and bitwise-identical
    by construction.
    """

    def __init__(self, curves: list[OnlineQPS]):
        self.n = len(curves)
        cfg = curves[0].cfg if curves else OnlineTraceCfg()
        self.cfg = cfg
        self.base = np.array([q.base for q in curves], np.float64)
        self.amp = np.array([q.amp for q in curves], np.float64)
        self.phase = np.array([q.phase for q in curves], np.float64)
        ang = 2 * np.pi * self.phase / DAY_S
        self._sin_ph = np.sin(ang)
        self._cos_ph = np.cos(ang)
        self._noise_idx = np.array([q.noise_seed % 7 for q in curves],
                                   np.int64)
        self.noise_mod = self._noise_idx.astype(np.float64)
        n_b = max((len(q.bursts) for q in curves), default=0)
        # padded bursts: inactive slots get start past any (t % DAY_S)
        self.burst_start = np.full((self.n, n_b), 2.0 * DAY_S, np.float64)
        self.burst_len = np.zeros((self.n, n_b), np.float64)
        self.burst_mult = np.ones((self.n, n_b), np.float64)
        for i, q in enumerate(curves):
            for b, (start, ln, mult) in enumerate(q.bursts):
                self.burst_start[i, b] = start
                self.burst_len[i, b] = ln
                self.burst_mult[i, b] = mult

    def qps(self, t: float) -> np.ndarray:
        """Fleet QPS at time ``t`` — the 1-D hot path; bitwise-identical to
        the corresponding :meth:`qps_block` row (same elementwise ops)."""
        c = self.cfg
        t = np.float64(t)
        a = 2 * np.pi * t / DAY_S
        sin_a, cos_a = np.sin(a), np.cos(a)
        diurnal = sin_a * self._cos_ph - cos_a * self._sin_ph
        v = self.base + self.amp * diurnal
        noise_tab = np.sin(2 * np.pi * t / 777.0
                           + np.arange(7, dtype=np.float64))
        v = v * (1.0 + c.noise * noise_tab[self._noise_idx])
        tmod = t % DAY_S
        for b in range(self.burst_start.shape[1]):
            active = ((self.burst_start[:, b] <= tmod)
                      & (tmod < self.burst_start[:, b]
                         + self.burst_len[:, b]))
            v = np.where(active, v * self.burst_mult[:, b], v)
        return np.clip(v, c.qps_lo, c.qps_hi * 1.3)

    def qps_block(self, ts: np.ndarray) -> np.ndarray:
        """Fleet QPS for a block of tick times: (T,) -> (T, n).

        Row ``j`` *is* ``qps(ts[j])`` (delegation, not a parallel
        implementation), so block consumers see exactly — bitwise — the
        values a per-tick caller sees.  Convenience/analysis surface: the
        engines themselves read ``ClusterSim.tick_qps`` one tick at a time.
        """
        ts = np.asarray(ts, np.float64)
        return np.stack([self.qps(float(t)) for t in ts])


@dataclasses.dataclass
class OfflineJobSpec:
    job_id: int
    submit_s: float
    duration_s: float            # separate-execution duration (T^sep)
    model: str


def philly_like_trace(rng: np.random.Generator, *, n_jobs: int,
                      horizon_s: float, min_dur_s: float = 600.0,
                      max_dur_s: float = 8 * 3600.0) -> list[OfflineJobSpec]:
    """Synthetic Philly-style trace: diurnally modulated Poisson submissions,
    lognormal durations (median ~40 min), models sampled uniformly from the
    paper's four offline DL models."""
    models = list(OFFLINE_MODEL_PROFILES)
    # submissions concentrated in the first 2/3 of the horizon so traces can
    # drain (the paper's traces finish within the experiment window)
    sub_horizon = horizon_s * 0.66
    raw = np.sort(rng.uniform(0, sub_horizon, n_jobs))
    # diurnal thinning: more submissions during "work hours"
    keep_p = 0.6 + 0.4 * np.sin(2 * np.pi * raw / DAY_S) ** 2
    jitter = rng.random(n_jobs)
    submit = np.where(jitter < keep_p, raw, raw * 0.5)
    submit = np.sort(submit)
    durs = np.clip(rng.lognormal(mean=math.log(2400), sigma=0.9, size=n_jobs),
                   min_dur_s, max_dur_s)
    return [OfflineJobSpec(job_id=i, submit_s=float(submit[i]),
                           duration_s=float(durs[i]),
                           model=models[int(rng.integers(len(models)))])
            for i in range(n_jobs)]


def philly_request_times(rng: np.random.Generator, *, rate: float,
                         horizon_s: float, diurnal_amp: float = 0.4,
                         burst_rate_per_day: float = 6.0,
                         burst_mult: float = 2.5,
                         burst_len_s: float = 300.0) -> np.ndarray:
    """Philly-style *request* arrival trace: skewed, bursty timestamps.

    The Philly study (and the paper's "requests may suddenly burst")
    motivates judging serving on realistic arrivals, not a smooth curve:
    a diurnally modulated Poisson base (mean ``rate`` requests/s, relative
    amplitude ``diurnal_amp``) overlaid with short heavy burst episodes
    (``× burst_mult`` for ``burst_len_s``, ~``burst_rate_per_day`` per day).
    Sampled by thinning against the peak rate — exact for an inhomogeneous
    Poisson process — so the result is a pure function of (rng state,
    parameters).
    """
    if rate <= 0 or horizon_s <= 0:
        return np.empty(0, np.float64)
    n_bursts = int(rng.poisson(burst_rate_per_day * horizon_s / DAY_S))
    starts = np.sort(rng.uniform(0, horizon_s, n_bursts))
    peak = rate * (1.0 + diurnal_amp) * max(burst_mult, 1.0)
    # candidate stream at the peak rate (topped up to cover the horizon)
    size = max(int(2 * horizon_s * peak), 8)
    cand = np.cumsum(rng.exponential(1.0 / peak, size))
    while cand.size and cand[-1] < horizon_s:
        cand = np.concatenate(
            [cand, cand[-1] + np.cumsum(rng.exponential(1.0 / peak, size))])
    cand = cand[cand < horizon_s]
    local = rate * (1.0 + diurnal_amp * np.sin(2 * np.pi * cand / DAY_S))
    if n_bursts:
        k = np.searchsorted(starts, cand, side="right") - 1
        in_burst = (k >= 0) & (cand - starts[np.clip(k, 0, None)]
                               < burst_len_s)
        local = np.where(in_burst, local * burst_mult, local)
    keep = rng.random(cand.size) * peak <= local
    return cand[keep]


def make_trace(name: str, n_devices: int, horizon_s: float,
               seed: int = 0) -> list[OfflineJobSpec]:
    """Traces A–D: different load factors (jobs per device per 12 h),
    mirroring the paper's virtual-cluster splits (1 410–7 287 jobs / 1 000
    GPUs)."""
    load = {"A": 1.6, "B": 2.8, "C": 4.6, "D": 7.0}[name]
    n_jobs = max(4, int(n_devices * load * (horizon_s / (12 * 3600.0))))
    # stable digest, NOT builtin hash(): str hashing is randomized per
    # process (PYTHONHASHSEED), which would make traces — and every scenario
    # report built on them — irreproducible across runs
    name_seed = int.from_bytes(hashlib.sha256(name.encode()).digest()[:4],
                               "little")
    rng = np.random.default_rng(name_seed % (1 << 31) + seed)
    return philly_like_trace(rng, n_jobs=n_jobs, horizon_s=horizon_s)
