"""SLO-aware admission control — the serving plane's policy seam.

An :class:`AdmissionPolicy` decides, each tick and per service, how many
queued requests to *shed* before the continuous-batching drain runs.  The
contract is vectorized over the service's queued cohorts (oldest first):
given each cohort's age and size plus the lane's SLO/service-time/capacity
context, return per-cohort shed counts.

Like :mod:`repro.policies`, policies are string-keyed through a registry so
scenarios name them declaratively (``ServingConfig.admission``) and tests /
users can register their own without touching the plane.

Built-ins:

``none``
    Never sheds — queues grow without bound under overload; the SLO
    attainment column shows what that costs.
``deadline``
    Deadline-based shedding: a request whose queueing delay has already
    exceeded ``slack × SLO − service_time`` cannot possibly meet its SLO,
    so serving it wastes capacity that fresher requests could meet their
    deadline with.  Shedding is monotone in load by construction (pinned by
    a unit test): queues only age past the deadline when arrivals outrun
    capacity.
"""
from __future__ import annotations

import numpy as np


class AdmissionPolicy:
    """Base class: decide per-cohort sheds for one service lane."""

    #: registry key (subclasses set it)
    name = "abstract"

    def shed(self, t: float, ages_s: np.ndarray, counts: np.ndarray, *,
             slo_s: float, service_s: float,
             capacity_rps: float) -> np.ndarray:
        """Per-cohort shed counts (``0 <= shed[k] <= counts[k]``).

        ``ages_s``/``counts`` walk the queue oldest-first; ``service_s`` is
        the current service time (base latency × slowdown) and
        ``capacity_rps`` the lane's effective fleet capacity this tick.
        """
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__doc__ or self.name


class NoAdmission(AdmissionPolicy):
    """Admit everything; never shed."""

    name = "none"

    def shed(self, t, ages_s, counts, *, slo_s, service_s, capacity_rps):
        return np.zeros_like(counts)


class DeadlineAdmission(AdmissionPolicy):
    """Shed requests whose wait already makes the SLO unreachable.

    The deadline is ``max(slack × slo_s − service_s, 0)``: once a request
    has queued longer than that, even immediate service lands past the SLO,
    so it is dropped (the client has long since timed out anyway).
    ``slack > 1`` keeps doomed requests around longer (softer shedding);
    ``slack < 1`` sheds ahead of the deadline (harder protection).
    """

    name = "deadline"

    def __init__(self, slack: float = 1.0):
        if slack <= 0:
            raise ValueError(f"slack must be positive, got {slack}")
        self.slack = slack

    def shed(self, t, ages_s, counts, *, slo_s, service_s, capacity_rps):
        deadline_s = max(self.slack * slo_s - service_s, 0.0)
        return np.where(ages_s > deadline_s, counts, 0)


_REGISTRY: dict[str, type[AdmissionPolicy]] = {}


def register_admission(cls: type[AdmissionPolicy]) -> type[AdmissionPolicy]:
    """Register an :class:`AdmissionPolicy` subclass under ``cls.name``."""
    name = cls.name
    if not name or name == "abstract":
        raise ValueError("admission policy needs a concrete .name")
    _REGISTRY[name] = cls
    return cls


def admission_available() -> list[str]:
    return sorted(_REGISTRY)


def resolve_admission(name_or_policy, **kwargs) -> AdmissionPolicy:
    """Name → constructed policy (kwargs forwarded); instances pass
    through.  Unknown names raise ``ValueError`` listing the registry."""
    if isinstance(name_or_policy, AdmissionPolicy):
        return name_or_policy
    cls = _REGISTRY.get(name_or_policy)
    if cls is None:
        raise ValueError(
            f"unknown admission policy {name_or_policy!r}; "
            f"available: {admission_available()}")
    if cls is NoAdmission:
        kwargs = {}          # the null policy takes no knobs
    return cls(**kwargs)


register_admission(NoAdmission)
register_admission(DeadlineAdmission)
