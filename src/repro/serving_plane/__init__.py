"""repro.serving_plane — the request-level serving layer over the cluster sim.

MuxFlow's whole point is protecting *online* workloads while space-sharing,
so policies must be judged on user-visible latency, not a proxy QPS curve.
This package adds that judgment layer:

* :mod:`repro.serving_plane.arrivals` — :class:`ArrivalProcess`, the one
  shared definition of "requests arrive" (``poisson`` / ``diurnal`` /
  ``trace-replay`` / ``burst``), consumed by the pair-profiling harness,
  the §4.2 multiplexer demo, and the cluster serving plane alike;
* :mod:`repro.serving_plane.admission` — the SLO-aware admission-control
  seam (:class:`AdmissionPolicy` registry: ``none`` / ``deadline``);
* :mod:`repro.serving_plane.plane` — :class:`ServingPlane`: per-service
  request queues drained by continuous batching on the sim's tick clock,
  with per-request enqueue/start/finish accounting, deadline shedding, and
  a schema-versioned ``"serving"`` report section (per-service p50/p99,
  SLO-attainment %, shed counts).

Everything is a pure function of (scenario, seed): serving sections are
byte-identical across processes and across the numpy/xla tick engines.
"""
from repro.serving_plane.admission import (AdmissionPolicy, DeadlineAdmission,
                                           NoAdmission, admission_available,
                                           register_admission,
                                           resolve_admission)
from repro.serving_plane.arrivals import ARRIVAL_KINDS, ArrivalProcess
from repro.serving_plane.plane import (SERVING_SCHEMA, ServingConfig,
                                       ServingPlane)

__all__ = [
    "ARRIVAL_KINDS", "ArrivalProcess",
    "AdmissionPolicy", "DeadlineAdmission", "NoAdmission",
    "admission_available", "register_admission", "resolve_admission",
    "SERVING_SCHEMA", "ServingConfig", "ServingPlane",
]
