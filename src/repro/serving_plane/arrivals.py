"""ArrivalProcess — the one shared definition of "requests arrive".

The repo had grown two arrival implementations: the pair-profiling
harness's seeded Poisson stream (``profiling/harness.py``) and the ad-hoc
exponential-gap loop in ``examples/serve_multiplex.py`` — while the cluster
sim modeled online load as a QPS *curve* (:class:`repro.core.traces.QPSBank`)
with no requests at all.  This module unifies the three: one seeded process
object with two consumption surfaces,

* :meth:`times` / :meth:`first_n` — per-request timestamps, for
  request-level consumers (the pair profiler's quantum loop, the §4.2
  multiplexer demo, property tests);
* :meth:`counts_at` — per-tick arrival *counts* drawn in tick order, for
  fleet-scale consumers (the cluster :class:`~repro.serving_plane.plane.
  ServingPlane`, where per-service rates reach tens of thousands of
  requests per second and individual timestamps would not fit in memory).

Kinds
-----
``poisson``
    Homogeneous rate.  ``times()`` reproduces the profiling harness's exact
    gap-sampling stream (``rng.exponential`` gaps, cumulative sum) so the
    speed-matrix artifact is unchanged by the migration.
``diurnal``
    Inhomogeneous rate driven by a ``rate_fn(t)``; :meth:`from_qps_bank`
    builds the canonical one — ``scale × bank.qps(t)[mask].sum()`` — so the
    serving plane's request stream and the sim's QPS curve are one
    definition (``rate()`` parity with :class:`QPSBank` is pinned by a
    property test).
``burst``
    Homogeneous base rate with periodic burst windows (``× mult``) — the
    paper's "online requests may suddenly burst".
``trace-replay``
    Replays an explicit, sorted timestamp array (e.g. a Philly-style skewed
    request trace from :func:`repro.core.traces.philly_request_times`).

Determinism: every random draw goes through ``numpy``'s ``SeedSequence`` —
no builtin ``hash()`` — so the same (kind, params, seed) produces the same
stream in every process.  ``counts_at`` is a *stream* (one Poisson draw per
call, in tick order); :meth:`reset` rewinds it for replay.
"""
from __future__ import annotations

import numpy as np

ARRIVAL_KINDS = ("poisson", "diurnal", "trace-replay", "burst")

# gap-sampling draws this multiple of the expected count per batch; the
# profiling harness's historical stream used exactly 2x (kept for artifact
# stability), topped up in the rare case the batch falls short of horizon
_GAP_BATCH_FACTOR = 2


def _rng(seed) -> np.random.Generator:
    """Seed an independent Generator from an int or a sequence of ints."""
    if isinstance(seed, (tuple, list)):
        return np.random.default_rng(np.random.SeedSequence(list(seed)))
    return np.random.default_rng(np.random.SeedSequence(seed))


class ArrivalProcess:
    """A seeded request-arrival process (see module docstring).

    Build through the classmethod constructors (:meth:`poisson`,
    :meth:`diurnal`, :meth:`from_qps_bank`, :meth:`burst`,
    :meth:`trace_replay`) rather than ``__init__``.
    """

    def __init__(self, kind: str, *, seed=0, mean_gap: float | None = None,
                 rate_fn=None, times: np.ndarray | None = None,
                 burst_mult: float = 1.0, burst_period_s: float = 0.0,
                 burst_len_s: float = 0.0):
        if kind not in ARRIVAL_KINDS:
            raise ValueError(
                f"unknown arrival kind {kind!r}; available: {ARRIVAL_KINDS}")
        self.kind = kind
        self.seed = seed
        self.mean_gap = mean_gap
        self._rate_fn = rate_fn
        self._times = times
        self.burst_mult = burst_mult
        self.burst_period_s = burst_period_s
        self.burst_len_s = burst_len_s
        self.reset()

    # ------------------------------------------------------------ builders
    @classmethod
    def poisson(cls, rate: float | None = None, *,
                mean_gap: float | None = None, seed=0) -> "ArrivalProcess":
        """Homogeneous Poisson process; give ``rate`` (arrivals per unit
        time) or ``mean_gap`` (its reciprocal, passed through exactly — the
        profiling harness's parameterization)."""
        if (rate is None) == (mean_gap is None):
            raise ValueError("give exactly one of rate / mean_gap")
        if mean_gap is None:
            mean_gap = 1.0 / rate
        if mean_gap <= 0:
            raise ValueError(f"mean_gap must be positive, got {mean_gap}")
        return cls("poisson", seed=seed, mean_gap=mean_gap)

    @classmethod
    def diurnal(cls, rate_fn, *, seed=0) -> "ArrivalProcess":
        """Inhomogeneous Poisson process with rate ``rate_fn(t)`` (arrivals
        per unit time)."""
        return cls("diurnal", seed=seed, rate_fn=rate_fn)

    @classmethod
    def from_qps_bank(cls, bank, *, mask=None, scale: float = 1.0,
                      seed=0) -> "ArrivalProcess":
        """The canonical diurnal process: rate(t) = ``scale ×
        bank.qps(t)[mask].sum()`` — arrivals follow the exact QPS curve the
        simulator engines read, so the request stream and the proxy load
        are one definition (parity is pinned by a property test)."""
        if mask is not None:
            mask = np.asarray(mask, bool)

        def rate_fn(t, _bank=bank, _mask=mask, _scale=scale):
            row = _bank.qps(t)
            if _mask is not None:
                row = row[_mask]
            return _scale * float(row.sum())

        return cls.diurnal(rate_fn, seed=seed)

    @classmethod
    def burst(cls, rate: float, *, mult: float = 3.0,
              period_s: float = 3600.0, burst_len_s: float = 300.0,
              seed=0) -> "ArrivalProcess":
        """Base rate with a burst window (``rate × mult``) of
        ``burst_len_s`` at the start of every ``period_s``."""
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        return cls("burst", seed=seed, mean_gap=1.0 / rate, burst_mult=mult,
                   burst_period_s=period_s, burst_len_s=burst_len_s)

    @classmethod
    def trace_replay(cls, times) -> "ArrivalProcess":
        """Replay an explicit arrival-timestamp array (sorted copy taken)."""
        times = np.sort(np.asarray(times, np.float64))
        return cls("trace-replay", times=times)

    # ---------------------------------------------------------------- rate
    def rate(self, t: float) -> float:
        """Expected arrivals per unit time at time ``t``."""
        if self.kind == "poisson":
            return 1.0 / self.mean_gap
        if self.kind == "diurnal":
            return float(self._rate_fn(t))
        if self.kind == "burst":
            base = 1.0 / self.mean_gap
            if self.burst_period_s > 0 and \
                    (t % self.burst_period_s) < self.burst_len_s:
                return base * self.burst_mult
            return base
        # trace-replay: the empirical mean rate over the trace span
        ts = self._times
        if ts.size < 2:
            return 0.0
        span = float(ts[-1] - ts[0])
        return ts.size / span if span > 0 else 0.0

    # -------------------------------------------------------------- counts
    def reset(self) -> None:
        """Rewind the :meth:`counts_at` stream (replay from the start)."""
        self._stream = (None if self.kind == "trace-replay"
                        else _rng(self.seed))

    def counts_at(self, t: float, dt: float) -> int:
        """Arrivals in ``[t, t + dt)``.  For random kinds this is a
        *streaming* draw — call in tick order (and :meth:`reset` to replay);
        for ``trace-replay`` it is a pure window count."""
        if self.kind == "trace-replay":
            lo = int(np.searchsorted(self._times, t, side="left"))
            hi = int(np.searchsorted(self._times, t + dt, side="left"))
            return hi - lo
        lam = self.rate(t) * dt
        return int(self._stream.poisson(lam)) if lam > 0 else 0

    # --------------------------------------------------------------- times
    def times(self, horizon: float) -> np.ndarray:
        """Arrival timestamps in ``[0, horizon)``.  A pure function of
        (process, horizon): every call re-derives the stream from the seed.

        For ``poisson`` this is the profiling harness's historical
        gap-sampling stream bit-for-bit (same ``SeedSequence``, same batch
        size, same cumulative sum); ``diurnal``/``burst`` use thinning
        against the kind's peak rate; ``trace-replay`` returns the trace.
        """
        if self.kind == "trace-replay":
            ts = self._times
            return ts[ts < horizon].copy()
        rng = _rng(self.seed)
        if self.kind == "poisson":
            return self._gap_times(rng, self.mean_gap, horizon)
        if self.kind == "burst":
            base = 1.0 / self.mean_gap
            peak = base * max(self.burst_mult, 1.0)
            cand = self._gap_times(rng, 1.0 / peak, horizon)
            in_burst = (self.burst_period_s > 0) & (
                (cand % max(self.burst_period_s, 1e-9)) < self.burst_len_s)
            local = np.where(in_burst, base * self.burst_mult, base)
            keep = rng.random(cand.size) * peak <= local
            return cand[keep]
        # diurnal: thin against the peak of rate_fn sampled on a 60 s grid
        grid = np.arange(0.0, horizon + 60.0, 60.0)
        rates = np.array([self.rate(float(g)) for g in grid])
        peak = float(rates.max()) * 1.05
        if peak <= 0:
            return np.empty(0, np.float64)
        cand = self._gap_times(rng, 1.0 / peak, horizon)
        local = np.array([self.rate(float(c)) for c in cand])
        keep = rng.random(cand.size) * peak <= local
        return cand[keep]

    @staticmethod
    def _gap_times(rng: np.random.Generator, mean_gap: float,
                   horizon: float) -> np.ndarray:
        size = max(int(_GAP_BATCH_FACTOR * horizon / mean_gap), 8)
        gaps = rng.exponential(mean_gap, size=size)
        times = np.cumsum(gaps)
        # top up in the (vanishingly rare, E[total] = 2×horizon) case the
        # batch falls short — the historical code would silently truncate
        while times.size and times[-1] < horizon:
            more = np.cumsum(rng.exponential(mean_gap, size=size))
            times = np.concatenate([times, times[-1] + more])
        return times[times < horizon]

    def first_n(self, n: int) -> np.ndarray:
        """The first ``n`` arrival timestamps.  For ``poisson`` this is one
        ``rng.exponential(mean_gap, n)`` cumulative sum — bit-for-bit the
        stream ``examples/serve_multiplex.py`` historically built ad hoc."""
        if self.kind == "poisson":
            return np.cumsum(_rng(self.seed).exponential(self.mean_gap, n))
        if self.kind == "trace-replay":
            if self._times.size < n:
                raise ValueError(
                    f"trace holds {self._times.size} arrivals, need {n}")
            return self._times[:n].copy()
        # inhomogeneous kinds: grow the horizon until n arrivals land
        horizon = n * self.mean_gap * 2 if self.mean_gap else n * 2.0
        for _ in range(20):
            ts = self.times(horizon)
            if ts.size >= n:
                return ts[:n]
            horizon *= 2
        raise ValueError(f"could not generate {n} arrivals (rate ~ 0?)")

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"ArrivalProcess(kind={self.kind!r}, seed={self.seed!r}, "
                f"mean_gap={self.mean_gap})")


def expected_count(process: ArrivalProcess, horizon: float,
                   dt: float = 60.0) -> float:
    """Trapezoid estimate of E[arrivals in [0, horizon)] — the rate-
    conservation contract ``times()``/``counts_at()`` are tested against."""
    grid = np.arange(0.0, horizon + dt, dt)
    rates = np.array([process.rate(float(g)) for g in grid])
    trapezoid = getattr(np, "trapezoid", np.trapz)   # numpy<2 fallback
    return float(trapezoid(rates, grid[:rates.size]))
