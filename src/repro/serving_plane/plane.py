"""The request-level serving plane: per-service queues on the sim tick clock.

Each online service gets a *lane*: an :class:`ArrivalProcess` feeding a FIFO
request queue that is drained by continuous batching against the fleet
capacity the simulator's own telemetry implies each tick.  Requests are
accounted enqueue → start → finish:

* **enqueue** — arrivals land as sub-tick cohorts (``subcohorts`` equal
  slices per tick, each stamped at its slice midpoint), optionally carrying
  a Philly-style skewed per-request size multiplier (mean-1 lognormal);
* **start** — the admission policy sheds SLO-doomed requests first, then
  FIFO capacity ``C_s(t) · tick_s`` drains the queue.  Capacity is derived
  from the engine's byte-identical per-tick arrays: active, non-outage
  devices of the service contribute ``qps_capacity × speed / slowdown``
  requests per second, so interference, faults, agent staleness, and
  autoscaling all move user-visible latency;
* **finish** — a served cohort's latency is its queueing delay (backlog
  ahead of it over this tick's capacity, floored at its own arrival time)
  plus the service time (base latency × the service's mean slowdown — the
  simulator's own latency model).  Latencies land in a fixed-bin histogram
  per service, from which p50/p99, SLO attainment, and means are derived.

Determinism: lanes draw arrival counts and size multipliers from dedicated
``SeedSequence`` streams in tick order, and consume only engine arrays that
are bitwise-identical across the numpy and xla tick engines — so the
``"serving"`` report section is byte-identical across processes and across
engines (CI ``cmp``s both).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.interference import ONLINE_SERVICE_PROFILES
from repro.core.traces import SERVICES, philly_request_times
from repro.serving_plane.admission import resolve_admission
from repro.serving_plane.arrivals import ARRIVAL_KINDS, ArrivalProcess, _rng

SERVING_SCHEMA = "repro.serving/v1"

_BIN_MS = 0.5                  # latency histogram resolution
_MAX_MS = 600_000.0            # 10 min clip (overflow lands in the last bin)
_N_BINS = int(_MAX_MS / _BIN_MS)
# per-metrics-window histogram: coarser bins keep the reset cheap while a
# 4 ms-quantized p99 is plenty for burn-rate alerting
_WIN_BIN_MS = 4.0
_WIN_BINS = int(_MAX_MS / _WIN_BIN_MS)
# trace-replay materializes timestamps; refuse silly sizes instead of OOMing
_MAX_TRACE_REQUESTS = 3_000_000


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Declarative serving-plane spec (a :class:`Scenario` field).

    ``load`` targets mean utilization against the fleet's *nominal*
    capacity (``qps_capacity × speed`` summed per service); the arrival
    kind shapes it over time.  ``slo_latency_mult`` sets each service's SLO
    to that multiple of its base latency unless ``slo_ms`` pins an explicit
    value.  ``request_size_sigma > 0`` draws mean-1 lognormal per-cohort
    request-size multipliers (Philly-style skew: most requests small, a
    heavy tail 2–5× the mean).
    """
    arrivals: str = "diurnal"            # an ARRIVAL_KINDS member
    load: float = 0.7
    rate_rps: float | None = None        # explicit fleet-total rate override
    slo_latency_mult: float = 6.0
    slo_ms: tuple = ()                   # (("vision", 400.0), ...) overrides
    admission: str = "deadline"
    admission_slack: float = 1.0
    request_size_sigma: float = 0.0
    subcohorts: int = 4
    burst_mult: float = 3.0
    burst_period_s: float = 3600.0
    burst_len_s: float = 300.0

    def __post_init__(self):
        if self.arrivals not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival kind {self.arrivals!r}; "
                             f"available: {ARRIVAL_KINDS}")
        if not 0 < self.load:
            raise ValueError(f"load must be positive, got {self.load}")
        if self.subcohorts < 1:
            raise ValueError("subcohorts must be >= 1")


class _Lane:
    """One service's queue, histogram, and counters."""

    def __init__(self, service: str, idx: np.ndarray, speed: np.ndarray,
                 process: ArrivalProcess,
                 admission, *, slo_ms: float, base_latency_ms: float,
                 qps_capacity: float, size_rng, sigma: float, sub: int):
        self.service = service
        self.idx = idx                       # device indices of this service
        self.speed = speed                   # per-device speed grade
        self.process = process
        self.admission = admission
        self.slo_ms = slo_ms
        self.base_latency_ms = base_latency_ms
        self.qps_capacity = qps_capacity
        self.size_rng = size_rng
        self.sigma = sigma
        self.sub = sub
        # queue of [t_arr, n_requests, work_per_request]
        self.queue: deque[list] = deque()
        self.hist = np.zeros(_N_BINS, np.int64)
        self.arrived = self.served = self.shed = 0
        self.within_slo = 0
        # chaos ladder: requests dropped by tiered brownout (a subset of
        # `shed`; surfaced in the report's "resilience" section)
        self.brownout_shed = 0
        # per-metrics-window counters (reset by window_snapshot)
        self.win_hist = np.zeros(_WIN_BINS, np.int64)
        self.win_arrived = self.win_served = self.win_shed = 0
        self.win_within = 0
        self.lat_sum_ms = 0.0
        self.max_ms = 0.0
        self.peak_queue = 0
        self.cap_sum = 0.0
        self.ticks = 0
        # optional request tracer (repro.obs.RequestTracer) + batch ids
        self.tracer = None
        self._batch_seq = 0

    # ------------------------------------------------------------- per-tick
    def step(self, t: float, dt: float, capacity_rps: float,
             service_ms: float, *, demand_mult: float = 1.0,
             brownout_frac: float = 0.0) -> None:
        self.ticks += 1
        self.cap_sum += capacity_rps
        # enqueue: sub-tick cohorts at slice midpoints, skewed sizes.
        # A chaos overload burst multiplies demand AFTER the arrival draw,
        # so the lane's RNG stream is identical with and without chaos.
        n_new = self.process.counts_at(t, dt)
        if demand_mult != 1.0:
            n_new = int(round(n_new * demand_mult))
        if n_new > 0:
            self.arrived += n_new
            self.win_arrived += n_new
            work = 1.0
            if self.sigma > 0:
                work = float(self.size_rng.lognormal(
                    -0.5 * self.sigma * self.sigma, self.sigma))
            base, extra = divmod(n_new, self.sub)
            for j in range(self.sub):
                n_j = base + (1 if j < extra else 0)
                if n_j:
                    t_arr = t + (j + 0.5) * dt / self.sub
                    self.queue.append([t_arr, n_j, work])
        q_len = sum(c[1] for c in self.queue)
        self.peak_queue = max(self.peak_queue, q_len)
        # chaos ladder: tiered brownout sheds the oldest queued fraction
        # before admission/drain burn capacity on doomed work
        if brownout_frac > 0.0 and q_len:
            self._brownout(t, q_len, brownout_frac)
        service_s = service_ms / 1e3
        # admission: shed SLO-doomed requests before burning capacity
        if self.queue:
            ages = np.array([t - c[0] for c in self.queue])
            counts = np.array([c[1] for c in self.queue])
            sheds = np.minimum(
                self.admission.shed(t, ages, counts,
                                    slo_s=self.slo_ms / 1e3,
                                    service_s=service_s,
                                    capacity_rps=capacity_rps),
                counts)
            if sheds.any():
                for c, k in zip(list(self.queue), sheds):
                    c[1] -= int(k)
                    if self.tracer is not None and k:
                        self.tracer.shed(self.service, t, c[0], int(k))
                self.shed += int(sheds.sum())
                self.win_shed += int(sheds.sum())
                while self.queue and self.queue[0][1] == 0:
                    self.queue.popleft()
        # continuous batching: FIFO drain of K = C·dt request-work units
        if capacity_rps <= 0 or not self.queue:
            return
        budget = capacity_rps * dt
        cum = 0.0
        while self.queue and budget > 1e-12:
            t_arr, n, work = self.queue[0]
            n_fit = int(min(n, (budget + 1e-9) // work))
            if n_fit <= 0:
                break
            # finish when the backlog ahead (+ half this batch) drains,
            # never before the requests actually arrived
            finish = t + (cum + n_fit * work * 0.5) / capacity_rps
            wait_s = max(finish, t_arr) - t_arr
            lat_ms = wait_s * 1e3 + service_ms
            self._record(lat_ms, n_fit)
            if self.tracer is not None:
                self._batch_seq += 1
                self.tracer.batch(self.service, self._batch_seq, t, t_arr,
                                  n_fit, work, wait_s * 1e3, service_ms,
                                  lat_ms)
            cum += n_fit * work
            budget -= n_fit * work
            if n_fit == n:
                self.queue.popleft()
            else:
                self.queue[0][1] = n - n_fit
                break

    def _brownout(self, t: float, q_len: int, frac: float) -> None:
        """Shed ``frac`` of the queue oldest-first (tiered brownout)."""
        target = int(q_len * frac)
        shed = 0
        while target > 0 and self.queue:
            c = self.queue[0]
            k = min(c[1], target)
            c[1] -= k
            target -= k
            shed += k
            if self.tracer is not None:
                self.tracer.shed(self.service, t, c[0], k)
            if c[1] == 0:
                self.queue.popleft()
        if shed:
            self.shed += shed
            self.win_shed += shed
            self.brownout_shed += shed

    def _record(self, lat_ms: float, n: int) -> None:
        self.served += n
        self.win_served += n
        self.lat_sum_ms += lat_ms * n
        self.max_ms = max(self.max_ms, lat_ms)
        if lat_ms <= self.slo_ms:
            self.within_slo += n
            self.win_within += n
        self.hist[min(int(lat_ms / _BIN_MS), _N_BINS - 1)] += n
        self.win_hist[min(int(lat_ms / _WIN_BIN_MS), _WIN_BINS - 1)] += n

    def window_snapshot(self) -> dict:
        """Per-window counters + coarse p99 for the metrics-window rollup
        and the alert engine's attainment/burn-rate feed; resets the
        window.  Driven by the metrics recorder at window boundaries."""
        snap = {"arrived": self.win_arrived, "served": self.win_served,
                "shed": self.win_shed, "within_slo": self.win_within,
                "p99_ms": _percentile(self.win_hist, 0.99, _WIN_BIN_MS)}
        self.win_arrived = self.win_served = self.win_shed = 0
        self.win_within = 0
        self.win_hist[:] = 0
        return snap

    # -------------------------------------------------------------- summary
    def summary(self) -> dict:
        done = self.served + self.shed      # requests with a known outcome
        return {
            "arrived": int(self.arrived),
            "served": int(self.served),
            "shed": int(self.shed),
            "queued_end": int(sum(c[1] for c in self.queue)),
            "slo_ms": round(self.slo_ms, 3),
            "p50_ms": _percentile(self.hist, 0.50),
            "p99_ms": _percentile(self.hist, 0.99),
            "mean_ms": (round(self.lat_sum_ms / self.served, 4)
                        if self.served else 0.0),
            "max_ms": round(self.max_ms, 4),
            # shed requests definitionally miss their SLO
            "slo_attainment": (round(self.within_slo / done, 6)
                               if done else 1.0),
            "peak_queue": int(self.peak_queue),
            "mean_capacity_rps": (round(self.cap_sum / self.ticks, 3)
                                  if self.ticks else 0.0),
        }


def _percentile(hist: np.ndarray, q: float, bin_ms: float = _BIN_MS) -> float:
    total = int(hist.sum())
    if total == 0:
        return 0.0
    k = int(np.searchsorted(np.cumsum(hist), np.ceil(q * total)))
    return (k + 1) * bin_ms


class ServingPlane:
    """All service lanes + the report section (see module docstring)."""

    def __init__(self, cfg: ServingConfig, lanes: list[_Lane],
                 tick_s: float):
        self.cfg = cfg
        self.lanes = lanes
        self.tick_s = tick_s
        # chaos seam: optional FaultInjector (overload-burst demand
        # multiplier + tiered brownout shedding); None = no-chaos path
        self.fault_injector = None

    # --------------------------------------------------------- construction
    @classmethod
    def from_sim(cls, sim, cfg: ServingConfig, *, seed: int) -> "ServingPlane":
        """Build lanes from a :class:`ClusterSim`'s fleet layout.  Arrival
        seeds derive from ``seed`` per lane (decoupled from the engine's
        trace/failure stream, like fault campaigns and agents)."""
        tick_s = sim.cfg.tick_s
        horizon_s = sim.cfg.horizon_s
        lanes: list[_Lane] = []
        nominal = {}
        for si, svc in enumerate(SERVICES):
            idx = np.flatnonzero(sim.service_idx == si)
            if idx.size:
                nominal[si] = (ONLINE_SERVICE_PROFILES[svc]["qps_capacity"]
                               * float(sim.speed[idx].sum()))
        nominal_total = sum(nominal.values())
        slo_overrides = dict(cfg.slo_ms)
        for si, svc in enumerate(SERVICES):
            if si not in nominal:
                continue
            idx = np.flatnonzero(sim.service_idx == si)
            prof = ONLINE_SERVICE_PROFILES[svc]
            # target mean rate: the load knob against nominal capacity,
            # or an explicit fleet rate split capacity-proportionally
            rate = (cfg.load * nominal[si] if cfg.rate_rps is None
                    else cfg.rate_rps * nominal[si] / nominal_total)
            process = cls._build_process(cfg, sim, si, idx, rate,
                                         horizon_s, seed)
            lanes.append(_Lane(
                svc, idx, sim.speed[idx].astype(np.float64), process,
                resolve_admission(cfg.admission, slack=cfg.admission_slack),
                slo_ms=slo_overrides.get(
                    svc, cfg.slo_latency_mult * prof["base_latency_ms"]),
                base_latency_ms=prof["base_latency_ms"],
                qps_capacity=prof["qps_capacity"],
                size_rng=_rng([seed, si, 1]),
                sigma=cfg.request_size_sigma,
                sub=cfg.subcohorts))
        return cls(cfg, lanes, tick_s)

    @staticmethod
    def _build_process(cfg: ServingConfig, sim, si: int, idx: np.ndarray,
                       rate: float, horizon_s: float,
                       seed: int) -> ArrivalProcess:
        if cfg.arrivals == "poisson":
            return ArrivalProcess.poisson(rate, seed=[seed, si])
        if cfg.arrivals == "burst":
            return ArrivalProcess.burst(
                rate, mult=cfg.burst_mult, period_s=cfg.burst_period_s,
                burst_len_s=cfg.burst_len_s, seed=[seed, si])
        if cfg.arrivals == "diurnal":
            # the canonical coupling: arrivals follow the exact QPS curve
            # the engines read (sim.tick_qps memoizes the row per tick),
            # rescaled so the mean lands at load × nominal capacity
            mask = sim.service_idx == si
            base_sum = float(sim.qps_bank.base[mask].sum())
            scale = rate / max(base_sum, 1e-9)

            def rate_fn(t, _qps=sim.tick_qps, _mask=mask, _scale=scale):
                return _scale * float(_qps(t)[_mask].sum())

            return ArrivalProcess.diurnal(rate_fn, seed=[seed, si])
        # trace-replay: materialized Philly-style skewed request trace
        expect = rate * horizon_s
        if expect > _MAX_TRACE_REQUESTS:
            raise ValueError(
                f"trace-replay would materialize ~{expect:.0f} request "
                f"timestamps (> {_MAX_TRACE_REQUESTS}); use the 'diurnal' "
                f"kind for fleet-scale serving runs")
        times = philly_request_times(_rng([seed, si, 7]), rate=rate,
                                     horizon_s=horizon_s)
        return ArrivalProcess.trace_replay(times)

    def attach_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.RequestTracer` to every lane: one
        ``request_batch`` row per continuous-batching drain, one
        ``request_shed`` row per admission shed, in deterministic
        lane/tick order."""
        for lane in self.lanes:
            lane.tracer = tracer

    # ------------------------------------------------------------- per-tick
    def on_tick(self, t: float, slowdown: np.ndarray, act: np.ndarray,
                outage: np.ndarray) -> None:
        """Advance every lane one tick.  Called from the engine-agnostic
        accounting epilogue (:meth:`ClusterSim._account`) with per-tick
        arrays that are bitwise-identical across tick engines."""
        dt = self.tick_s
        inj = self.fault_injector
        demand_mult = inj.serving_burst_mult(t) if inj is not None else 1.0
        brownout = inj.brownout_frac(t) if inj is not None else 0.0
        for lane in self.lanes:
            idx = lane.idx
            up = act[idx] & ~outage[idx]
            if up.any():
                slow = slowdown[idx][up]
                capacity = lane.qps_capacity * float(
                    (lane.speed[up] / slow).sum())
                service_ms = lane.base_latency_ms * float(slow.mean())
            else:
                capacity = 0.0
                service_ms = lane.base_latency_ms
            lane.step(t, dt, capacity, service_ms,
                      demand_mult=demand_mult, brownout_frac=brownout)

    # -------------------------------------------------------------- summary
    def summary(self) -> dict:
        """The schema-versioned ``"serving"`` report section."""
        services = {ln.service: ln.summary() for ln in self.lanes}
        hist = np.zeros(_N_BINS, np.int64)
        for ln in self.lanes:
            hist += ln.hist
        served = sum(s["served"] for s in services.values())
        shed = sum(s["shed"] for s in services.values())
        within = sum(ln.within_slo for ln in self.lanes)
        done = served + shed
        return {
            "schema": SERVING_SCHEMA,
            "arrivals": self.cfg.arrivals,
            "admission": self.cfg.admission,
            "load": round(self.cfg.load, 6),
            "request_size_sigma": round(self.cfg.request_size_sigma, 6),
            "services": services,
            "total": {
                "arrived": sum(s["arrived"] for s in services.values()),
                "served": served,
                "shed": shed,
                "queued_end": sum(s["queued_end"] for s in services.values()),
                "p50_ms": _percentile(hist, 0.50),
                "p99_ms": _percentile(hist, 0.99),
                "slo_attainment": round(within / done, 6) if done else 1.0,
            },
        }
