"""Seeded chaos campaigns: deterministic control-plane fault injection.

Where :class:`repro.cluster.faults.FaultCampaign` injects *GPU-side*
errors (the paper's xid/ECC/signal mix), :class:`ChaosCampaign` perturbs
the **infrastructure around the simulator** — node agents, the WAL, the
predictor, the matcher, the serving lanes — through the
:class:`~repro.chaos.injector.FaultInjector` seams.  Every fault class is
paired with a typed recovery on the graceful-degradation ladder:

================  ==============================  =======================
fault kind        injected where                  degradation / recovery
================  ==============================  =======================
``agent_crash``   agent misses heartbeats         restart after
                                                  ``agent_restart_s``
``clock_skew``    heartbeat timestamps skewed     skew episode expires
``wal_io``        transient append/flush/fsync    store's bounded retry
                  IO errors                       ladder absorbs them
``predictor_outage``  trained predictor down      static share-table
                                                  weight grid
``matcher_budget``    KM time budget exhausted    greedy-FIFO placement
``serving_burst``     arrival overload burst      tiered brownout shed
================  ==============================  =======================

Determinism contract (same as the fault campaign): the campaign owns a
dedicated RNG stream decoupled from scenario/fleet/serving seeds, draws a
**fixed shape** of randomness per active tick regardless of what fires,
and emits :data:`~repro.cluster.events.EventKind.CHAOS_INJECT` /
``RECOVERY`` event pairs so a report can prove every injected fault was
matched by a recovery.  WAL faults are consumed *inside* ``bus.emit``
(the store sink appends there), so their events are deferred one tick and
drained at the next ``inject()`` — the bus is never re-entered.
"""
from __future__ import annotations

import dataclasses

import numpy as np

CHAOS_SCHEMA = "repro.chaos/v1"

#: fault kinds a campaign can inject (report keys; sorted in summaries)
CHAOS_KINDS = ("agent_crash", "clock_skew", "matcher_budget",
               "predictor_outage", "serving_burst", "wal_io")


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Declarative chaos-campaign parameters (all rates are expected
    events per hour; ``0.0`` disables that fault class).

    Keep ``end_s`` at least a few episode lengths before the scenario
    horizon so every open episode can close and pair with its recovery
    event — the verification harness clamps and asserts this.
    """

    #: per-device agent crash rate; a crashed agent misses heartbeats
    agent_crash_rate_per_hour: float = 0.0
    #: how long a crashed agent stays down before its supervisor restarts it
    agent_restart_s: float = 240.0
    #: per-device clock-skew episode rate
    clock_skew_rate_per_hour: float = 0.0
    #: skew magnitude (heartbeats stamped this far in the past)
    clock_skew_s: float = 120.0
    #: skew episode length
    clock_skew_len_s: float = 600.0
    #: run-level transient WAL IO fault-burst rate
    wal_fault_rate_per_hour: float = 0.0
    #: consecutive IO attempts failed per burst — keep it at most the
    #: store's ``max_io_retries`` so the ladder always absorbs the burst
    wal_fault_burst: int = 2
    #: run-level predictor outage rate
    predictor_outage_rate_per_hour: float = 0.0
    #: predictor outage length
    predictor_outage_s: float = 900.0
    #: run-level matcher time-budget exhaustion rate (one round each)
    matcher_budget_rate_per_hour: float = 0.0
    #: run-level serving overload-burst rate
    serving_burst_rate_per_hour: float = 0.0
    #: overload burst length
    serving_burst_s: float = 600.0
    #: arrival demand multiplier while a burst is open
    serving_burst_mult: float = 2.5
    #: brownout shed fraction per tier (tiers escalate 1→3 over the burst)
    brownout_shed_frac: float = 0.10
    #: campaign window (defaults JSON-safe, like FaultCampaignConfig)
    start_s: float = 0.0
    end_s: float = 1e18

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s


class ChaosCampaign:
    """Drives one seeded chaos campaign against a ControlPlane's stack.

    Implements the full :class:`~repro.chaos.injector.FaultInjector`
    protocol; the control plane hands ``self`` to every seam
    (agents/store/sim/serving) and calls :meth:`inject` once per tick,
    right after the GPU fault campaign and before agents observe.
    """

    def __init__(self, cfg: ChaosConfig, sim, seed: int, bus=None):
        self.cfg = cfg
        self.sim = sim
        self.bus = bus
        #: serving plane (set by the control plane) for brownout accounting
        self.serving = None
        self._n = sim.cfg.n_devices
        self.rng = np.random.default_rng(seed)
        # episode state: *_until timestamps (0 = closed; the sim clock
        # starts at tick_s > 0 so 0 is never an open episode)
        self.agent_down_until = np.zeros(self._n)
        self.skew_until = np.zeros(self._n)
        self.predictor_down_until = 0.0
        self.serving_burst_until = 0.0
        self._burst_started = 0.0
        self._matcher_armed = False
        # WAL fault plumbing (consumed inside bus.emit → drained next tick)
        self._wal_pending = 0
        self._wal_consumed = 0
        self._wal_retries = 0
        self._wal_reported_faults = 0
        self._wal_reported_retries = 0
        # ladder counters / recovery-event marks
        self._pred_fallback_rounds = 0
        self._pred_mark = 0
        self._matcher_fallbacks = 0
        self._brownout_mark = 0
        self.injected_by_kind: dict[str, int] = {}
        self.recovered_by_kind: dict[str, int] = {}
        self._sig_prev: dict[str, float] = {}

    # ------------------------------------------------------------ injection
    def inject(self, t: float, tick_s: float) -> None:
        """Advance the campaign one tick: close expired episodes (emitting
        their recovery events), drain deferred WAL fault events, then draw
        this tick's fixed-shape randomness and maybe open new episodes."""
        cfg = self.cfg
        self._expire(t)
        self._drain_wal(t)
        if not cfg.active(t):
            # outside the window nothing new arms, and any un-consumed WAL
            # burst is disarmed so the post-run flush can't fire it
            self._wal_pending = 0
            self._matcher_armed = False
            return
        # fixed-shape draws every active tick, independent of what fires
        dev_u = self.rng.random((2, self._n))
        fleet_u = self.rng.random(4)
        p = tick_s / 3600.0
        if cfg.agent_crash_rate_per_hour > 0:
            up = self.agent_down_until <= 0
            crash = up & (dev_u[0] < cfg.agent_crash_rate_per_hour * p)
            for i in np.flatnonzero(crash):
                self.agent_down_until[i] = t + cfg.agent_restart_s
                self._fire(t, "agent_crash", device=int(i),
                           data=(("restart_s", cfg.agent_restart_s),))
        if cfg.clock_skew_rate_per_hour > 0 and cfg.clock_skew_s > 0:
            calm = self.skew_until <= 0
            skew = calm & (dev_u[1] < cfg.clock_skew_rate_per_hour * p)
            for i in np.flatnonzero(skew):
                self.skew_until[i] = t + cfg.clock_skew_len_s
                self._fire(t, "clock_skew", device=int(i),
                           data=(("skew_s", cfg.clock_skew_s),))
        if (cfg.wal_fault_rate_per_hour > 0 and self._wal_pending == 0
                and fleet_u[0] < cfg.wal_fault_rate_per_hour * p):
            self._wal_pending = int(cfg.wal_fault_burst)
        if (cfg.predictor_outage_rate_per_hour > 0
                and self.predictor_down_until <= 0
                and fleet_u[1] < cfg.predictor_outage_rate_per_hour * p):
            self.predictor_down_until = t + cfg.predictor_outage_s
            self._pred_mark = self._pred_fallback_rounds
            self._fire(t, "predictor_outage",
                       data=(("outage_s", cfg.predictor_outage_s),))
        if (cfg.matcher_budget_rate_per_hour > 0 and not self._matcher_armed
                and fleet_u[2] < cfg.matcher_budget_rate_per_hour * p):
            self._matcher_armed = True
        if (cfg.serving_burst_rate_per_hour > 0
                and self.serving_burst_until <= 0
                and fleet_u[3] < cfg.serving_burst_rate_per_hour * p):
            self.serving_burst_until = t + cfg.serving_burst_s
            self._burst_started = t
            self._brownout_mark = self.brownout_total()
            self._fire(t, "serving_burst",
                       data=(("mult", cfg.serving_burst_mult),
                             ("burst_s", cfg.serving_burst_s)))

    def _expire(self, t: float) -> None:
        back = (self.agent_down_until > 0) & (self.agent_down_until <= t)
        for i in np.flatnonzero(back):
            self._recover(t, "agent_crash", device=int(i),
                          action="agent_restart")
        self.agent_down_until[back] = 0.0
        calm = (self.skew_until > 0) & (self.skew_until <= t)
        for i in np.flatnonzero(calm):
            self._recover(t, "clock_skew", device=int(i),
                          action="skew_cleared")
        self.skew_until[calm] = 0.0
        if 0 < self.predictor_down_until <= t:
            self._recover(
                t, "predictor_outage", action="static_share_table",
                data=(("fallback_rounds",
                       self._pred_fallback_rounds - self._pred_mark),))
            self.predictor_down_until = 0.0
        if 0 < self.serving_burst_until <= t:
            self._recover(
                t, "serving_burst", action="brownout_shed",
                data=(("shed", self.brownout_total() - self._brownout_mark),))
            self.serving_burst_until = 0.0

    def _drain_wal(self, t: float) -> None:
        """Emit the CHAOS_INJECT/RECOVERY pair for WAL faults consumed
        since the last tick.  Deferred because the store consumes faults
        inside ``bus.emit`` (the sink appends there) and the bus must not
        be re-entered; marks are advanced *before* emitting so faults the
        emission itself consumes are picked up next tick."""
        faults = self._wal_consumed - self._wal_reported_faults
        if faults <= 0:
            return
        retries = self._wal_retries - self._wal_reported_retries
        self._wal_reported_faults += faults
        self._wal_reported_retries += retries
        self._fire(t, "wal_io", data=(("faults", faults),))
        self._recover(t, "wal_io", action="bounded_retry",
                      data=(("retries", retries),))

    # ------------------------------------------------------------- events
    # EventKind is imported lazily: repro.chaos must stay importable on its
    # own (scenario/control both import from it), and repro.cluster.events
    # pulls in the whole cluster package, which imports back into chaos.
    def _fire(self, t, fault, device=-1, data=()):
        self.injected_by_kind[fault] = self.injected_by_kind.get(fault, 0) + 1
        if self.bus is not None:
            from repro.cluster.events import EventKind
            self.bus.emit(t, EventKind.CHAOS_INJECT, device=device,
                          data=(("fault", fault),) + tuple(data))

    def _recover(self, t, fault, action, device=-1, data=()):
        self.recovered_by_kind[fault] = (
            self.recovered_by_kind.get(fault, 0) + 1)
        if self.bus is not None:
            from repro.cluster.events import EventKind
            self.bus.emit(t, EventKind.RECOVERY, device=device,
                          data=(("fault", fault), ("action", action))
                          + tuple(data))

    # -------------------------------------------- FaultInjector protocol
    def agent_outage(self, t):
        if self.cfg.agent_crash_rate_per_hour <= 0:
            return None
        return self.agent_down_until > t

    def heartbeat_skew(self, t):
        if self.cfg.clock_skew_rate_per_hour <= 0:
            return None
        return np.where(self.skew_until > t, self.cfg.clock_skew_s, 0.0)

    def store_fault(self, op):
        if self._wal_pending <= 0:
            return False
        self._wal_pending -= 1
        self._wal_consumed += 1
        return True

    def note_io_recovered(self, op, attempts):
        self._wal_retries += int(attempts)

    def predictor_down(self, t):
        return t < self.predictor_down_until

    def note_predictor_fallback(self, t):
        self._pred_fallback_rounds += 1

    def matcher_exhausted(self, t):
        return self._matcher_armed

    def note_matcher_fallback(self, t, n_free, n_jobs):
        # one-shot: the armed budget exhaustion is consumed by this round.
        # _schedule runs in plain Python on both tick engines and outside
        # bus.emit, so emitting the pair immediately here is safe.
        self._matcher_armed = False
        self._matcher_fallbacks += 1
        self._fire(t, "matcher_budget",
                   data=(("free", int(n_free)), ("jobs", int(n_jobs))))
        self._recover(t, "matcher_budget", action="greedy_fifo")

    def serving_burst_mult(self, t):
        if t < self.serving_burst_until:
            return self.cfg.serving_burst_mult
        return 1.0

    def brownout_frac(self, t):
        """Tiered brownout: the shed fraction escalates 1×→3× the base
        fraction over thirds of the burst window."""
        if not t < self.serving_burst_until:
            return 0.0
        frac = (t - self._burst_started) / max(self.cfg.serving_burst_s, 1.0)
        tier = 1 + min(2, int(3.0 * frac))
        return tier * self.cfg.brownout_shed_frac

    # ------------------------------------------------------------ reporting
    def brownout_total(self) -> int:
        if self.serving is None:
            return 0
        return int(sum(lane.brownout_shed for lane in self.serving.lanes))

    def open_faults(self) -> int:
        """Episodes currently open (every one must close before the run
        ends for the fault↔recovery pairing invariant to hold)."""
        n = int((self.agent_down_until > 0).sum())
        n += int((self.skew_until > 0).sum())
        n += 1 if self.predictor_down_until > 0 else 0
        n += 1 if self.serving_burst_until > 0 else 0
        n += 1 if self._wal_consumed > self._wal_reported_faults else 0
        return n

    def summary(self) -> dict:
        """The report's ``"resilience"`` section (JSON-safe, sorted)."""
        inj = dict(sorted(self.injected_by_kind.items()))
        rec = dict(sorted(self.recovered_by_kind.items()))
        unmatched = {k: v - rec.get(k, 0) for k, v in inj.items()
                     if v - rec.get(k, 0)}
        return {
            "schema": CHAOS_SCHEMA,
            "injected": sum(inj.values()),
            "recovered": sum(rec.values()),
            "unmatched": sum(unmatched.values()),
            "unmatched_by_kind": unmatched,
            "open_end": self.open_faults(),
            "injected_by_kind": inj,
            "recovered_by_kind": rec,
            "ladder": {
                "store_faults": self._wal_consumed,
                "store_retries": self._wal_retries,
                "predictor_fallback_rounds": self._pred_fallback_rounds,
                "matcher_fallback_rounds": self._matcher_fallbacks,
                "brownout_shed": self.brownout_total(),
                "agent_restarts": rec.get("agent_crash", 0),
            },
        }

    def window_signals(self) -> dict:
        """Per-window alerting signals (deltas since the last window plus
        the open-fault gauge) merged into the fleet signal dict."""
        cur = {
            "chaos_faults": float(sum(self.injected_by_kind.values())),
            "chaos_recoveries": float(sum(self.recovered_by_kind.values())),
            "chaos_store_retries": float(self._wal_retries),
            "chaos_brownout_shed": float(self.brownout_total()),
        }
        out = {k: v - self._sig_prev.get(k, 0.0) for k, v in cur.items()}
        out["chaos_open_faults"] = float(self.open_faults())
        self._sig_prev = cur
        return out

    # ------------------------------------------------------- snapshotting
    def capture(self) -> dict:
        """Mutable campaign state for tick-boundary snapshots."""
        return {
            "rng": self.rng.bit_generator.state,
            "agent_down_until": np.copy(self.agent_down_until),
            "skew_until": np.copy(self.skew_until),
            "predictor_down_until": self.predictor_down_until,
            "serving_burst_until": self.serving_burst_until,
            "burst_started": self._burst_started,
            "matcher_armed": self._matcher_armed,
            "wal_pending": self._wal_pending,
            "wal_consumed": self._wal_consumed,
            "wal_retries": self._wal_retries,
            "wal_reported_faults": self._wal_reported_faults,
            "wal_reported_retries": self._wal_reported_retries,
            "pred_fallback_rounds": self._pred_fallback_rounds,
            "pred_mark": self._pred_mark,
            "matcher_fallbacks": self._matcher_fallbacks,
            "brownout_mark": self._brownout_mark,
            "injected": dict(self.injected_by_kind),
            "recovered": dict(self.recovered_by_kind),
            "sig_prev": dict(self._sig_prev),
        }

    def restore(self, row: dict) -> None:
        self.rng.bit_generator.state = row["rng"]
        self.agent_down_until = np.copy(row["agent_down_until"])
        self.skew_until = np.copy(row["skew_until"])
        self.predictor_down_until = row["predictor_down_until"]
        self.serving_burst_until = row["serving_burst_until"]
        self._burst_started = row["burst_started"]
        self._matcher_armed = row["matcher_armed"]
        self._wal_pending = row["wal_pending"]
        self._wal_consumed = row["wal_consumed"]
        self._wal_retries = row["wal_retries"]
        self._wal_reported_faults = row["wal_reported_faults"]
        self._wal_reported_retries = row["wal_reported_retries"]
        self._pred_fallback_rounds = row["pred_fallback_rounds"]
        self._pred_mark = row["pred_mark"]
        self._matcher_fallbacks = row["matcher_fallbacks"]
        self._brownout_mark = row["brownout_mark"]
        self.injected_by_kind = dict(row["injected"])
        self.recovered_by_kind = dict(row["recovered"])
        self._sig_prev = dict(row["sig_prev"])
