"""The chaos verification harness: run a chaos scenario, prove survival.

``run_chaos_verification`` executes one chaos-enabled scenario three ways
and checks the survivability invariants the chaos plane promises:

1. a **baseline** run with chaos stripped (``chaos=None``) — the SLO
   yardstick;
2. a **durable chaos** run (WAL + snapshots) — checked for zero event
   loss (WAL count and replay digest match the bus), complete
   fault↔recovery pairing, bounded-retry accounting, and online SLO
   attainment within ``slo_budget`` of the baseline;
3. a **crash** run — the same durable run killed mid-campaign via a
   simulated SIGKILL (``store.abandon()``, a torn WAL tail, and the
   newest snapshot garbled in a hash-consistent way), then resumed.
   The resumed report must be byte-identical to the uninterrupted
   run's, and resume must have exercised skip-to-next-good.

Every check lands in a ``repro.chaos.verify/v1`` verdict document; the
``python -m repro chaos`` CLI prints it and exits nonzero when any
invariant fails.  The harness is deterministic end to end — no
wall-clock reads, no unseeded randomness.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

VERIFY_SCHEMA = "repro.chaos.verify/v1"

# Longest chaos episode (predictor outage, 900 s) plus slack: injection
# stops this long before the horizon so every episode closes and every
# fault pairs with a recovery before finalize.
_QUIET_TAIL_S = 1200.0

_GARBAGE_PICKLE = b"\x80\x05 this is not a snapshot pickle"
_TORN_LINE = '{"seq": 99999999, "t": 1.0, "kin'


class _SimulatedKill(BaseException):
    """Raised from a tick callback to model SIGKILL mid-campaign; derives
    from BaseException so production ``except Exception`` paths cannot
    swallow it (neither would a real SIGKILL)."""


def _invariant(name: str, ok: bool, detail: str) -> dict:
    return {"name": name, "ok": bool(ok), "detail": detail}


def _resolve(scenario, *, seed, engine, devices, hours):
    from repro.cluster.scenario import scenario_by_name
    sc = (scenario_by_name(scenario) if isinstance(scenario, str)
          else scenario)
    sc = sc.with_overrides(seed=seed, engine=engine, n_devices=devices,
                           hours=hours)
    if sc.chaos is None:
        raise ValueError(f"scenario {sc.name!r} has no chaos config — "
                         "nothing to verify (try chaos-storm)")
    horizon = sc.horizon_seconds()
    end_s = min(sc.chaos.end_s, max(0.0, horizon - _QUIET_TAIL_S))
    return dataclasses.replace(
        sc, chaos=dataclasses.replace(sc.chaos, end_s=end_s))


def _zero_event_loss(store, ev: dict) -> tuple[bool, str]:
    n_store = store.count()
    n_bus = ev["n_events"]
    if n_store != n_bus:
        return False, f"WAL holds {n_store} events, bus emitted {n_bus}"
    if ev["sink_dropped"]:
        return False, f"bus dropped {ev['sink_dropped']} sink events"
    digest = store.replay_digest(n_store).hexdigest()
    if digest != ev["digest"]:
        return False, "WAL replay digest != bus digest"
    return True, f"{n_store} events, replay digest matches"


def _crash_partway(run, predictor=None) -> int:
    """Drive a fresh DurableRun exactly like ``execute()``'s fresh branch,
    but die (simulated SIGKILL) partway through the third snapshot
    interval — after two snapshots exist, before the run finishes."""
    from repro.cluster.control import ControlPlane
    every, n_ticks = run._every_ticks(), run._n_ticks()
    crash_tick = min(n_ticks - 1, 2 * every + every // 2)
    run.store.truncate(0)
    run.cp = ControlPlane(run.scenario, predictor=predictor, obs=run.obs)
    run.store.fault_injector = getattr(run.cp, "chaos", None)
    run.cp.bus.attach_sink(run.store.append)
    inner = run._tick_callback()

    def cb(ticks_done: int, t: float) -> None:
        inner(ticks_done, t)
        if ticks_done >= crash_tick:
            raise _SimulatedKill()

    try:
        run.cp.run(tick_callback=cb)
    except _SimulatedKill:
        pass
    return crash_tick


def _tear_wal_tail(rundir: str, backend: str) -> str:
    """Leave the WAL the way a SIGKILL would: the jsonl backend gets a
    torn half-line appended to its live segment; the sqlite backend's
    uncommitted suffix is already gone (``abandon()`` rolled it back)."""
    if backend != "jsonl":
        return "sqlite: uncommitted suffix rolled back by abandon()"
    segs = sorted(glob.glob(
        os.path.join(rundir, "events", "segment-*.jsonl")))
    if not segs:
        return "no segment to tear"
    with open(segs[-1], "a") as f:
        f.write(_TORN_LINE)
    return f"torn half-line appended to {os.path.basename(segs[-1])}"


def _garble_newest_snapshot(rundir: str) -> str | None:
    """Overwrite the newest snapshot with garbage bytes and re-sign the
    manifest so the hash still verifies — the snapshot is only discovered
    to be corrupt at unpickle time, exercising skip-to-next-good (not the
    cheaper hash-mismatch path).  Returns the garbled relpath, or None if
    fewer than two snapshots exist (nothing older to fall back to)."""
    from repro.durability.manifest import (file_sha256, sign_manifest,
                                           write_manifest)
    snaps = sorted(glob.glob(
        os.path.join(rundir, "snapshots", "snap-*.pkl")))
    if len(snaps) < 2:
        return None
    target = snaps[-1]
    with open(target, "wb") as f:
        f.write(_GARBAGE_PICKLE)
    manifest_path = os.path.join(rundir, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    rel = os.path.relpath(target, rundir)
    sha, size = file_sha256(target)
    manifest["artifacts"][rel] = {"sha256": sha, "bytes": size}
    body = {k: v for k, v in manifest.items() if k != "signature"}
    manifest["signature"] = sign_manifest(body)
    write_manifest(manifest_path, manifest)
    return rel


def run_chaos_verification(scenario="chaos-storm", *, workdir: str,
                           seed: int | None = None,
                           engine: str | None = None,
                           devices: int | None = None,
                           hours: float | None = None,
                           backend: str = "jsonl",
                           slo_budget: float = 0.25,
                           crash: bool = True,
                           snapshot_every_s: float = 900.0,
                           predictor=None) -> dict:
    """Run the chaos campaign and verify the survivability invariants.
    Returns the ``repro.chaos.verify/v1`` verdict document.

    ``slo_budget`` bounds how far attainment may fall below the no-chaos
    baseline.  It proves *bounded* degradation, not zero impact: the
    storm's overload burst multiplies demand 2.5x for its whole window
    and every brownout-shed request counts as an SLO miss, so the
    correct ladder response (shed rather than collapse) itself costs
    attainment roughly in proportion to the excess demand.  The default
    absorbs the full-size chaos-storm burst; tighten it for scenarios
    without ``serving_burst``."""
    from repro.cluster.control import run_scenario
    from repro.durability.runner import DurableRun, resume_run, run_durable

    sc = _resolve(scenario, seed=seed, engine=engine, devices=devices,
                  hours=hours)
    inv: list[dict] = []

    # ---- baseline: same scenario, chaos stripped ------------------------
    base_rep = run_scenario(dataclasses.replace(sc, chaos=None),
                            predictor=predictor)

    # ---- durable chaos run ---------------------------------------------
    rundir_a = os.path.join(workdir, "chaos-durable")
    run_a = run_durable(sc, rundir_a, backend=backend,
                        snapshot_every_s=snapshot_every_s,
                        predictor=predictor)
    rep_a = run_a.report
    res = rep_a["resilience"]

    inv.append(_invariant(
        "faults-injected", res["injected"] > 0,
        f"{res['injected']} faults injected: {res['injected_by_kind']}"))
    inv.append(_invariant(
        "fault-recovery-pairing",
        res["unmatched"] == 0 and res["open_end"] == 0,
        f"unmatched={res['unmatched']} ({res['unmatched_by_kind']}), "
        f"open at end={res['open_end']}"))
    ok, detail = _zero_event_loss(run_a.store, rep_a["events"])
    inv.append(_invariant("zero-event-loss", ok, detail))
    lad = res["ladder"]
    inv.append(_invariant(
        "store-retry-ladder",
        lad["store_faults"] == 0
        or lad["store_retries"] >= lad["store_faults"],
        f"{lad['store_faults']} injected WAL faults, "
        f"{lad['store_retries']} bounded retries"))
    base_att = chaos_att = None
    if rep_a["serving"] is not None and base_rep["serving"] is not None:
        base_att = base_rep["serving"]["total"]["slo_attainment"]
        chaos_att = rep_a["serving"]["total"]["slo_attainment"]
        inv.append(_invariant(
            "slo-degradation-budget", chaos_att >= base_att - slo_budget,
            f"attainment {chaos_att:.4f} under chaos vs {base_att:.4f} "
            f"baseline (budget {slo_budget:.4f})"))
    run_a.store.close()

    # ---- crash + resume -------------------------------------------------
    if crash:
        rundir_b = os.path.join(workdir, "chaos-crash")
        run_b = DurableRun.create(sc, rundir_b, backend=backend,
                                  snapshot_every_s=snapshot_every_s)
        crash_tick = _crash_partway(run_b, predictor=predictor)
        run_b.store.abandon()
        tear = _tear_wal_tail(rundir_b, backend)
        garbled = _garble_newest_snapshot(rundir_b)
        run_b2 = resume_run(rundir_b, predictor=predictor)
        identical = (json.dumps(run_b2.report, sort_keys=True)
                     == json.dumps(rep_a, sort_keys=True))
        inv.append(_invariant(
            "recovery-byte-identity", identical,
            f"killed at tick {crash_tick} ({tear}); resumed from tick "
            f"{run_b2.resumed_from_tick}; report "
            + ("byte-identical to the uninterrupted run"
               if identical else "DIVERGED from the uninterrupted run")))
        if garbled is not None:
            inv.append(_invariant(
                "snapshot-skip-to-next-good",
                len(run_b2.snapshot_skips) >= 1,
                f"garbled {garbled} (hash-consistent); skips recorded: "
                f"{run_b2.snapshot_skips}"))
        run_b2.store.close()

    return {
        "schema": VERIFY_SCHEMA,
        "scenario": sc.name,
        "seed": sc.seed,
        "engine": sc.engine,
        "backend": backend,
        "ok": all(i["ok"] for i in inv),
        "invariants": inv,
        "resilience": res,
        "slo": {"baseline_attainment": base_att,
                "chaos_attainment": chaos_att,
                "budget": slo_budget},
    }
