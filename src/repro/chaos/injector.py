"""The `FaultInjector` protocol — the seam every chaos-aware module consults.

Chaos is injected through *explicit seams*, never monkey-patching: each
subsystem that can fail holds an optional ``fault_injector`` attribute
(default ``None``) and consults it at well-defined points.  When the
attribute is ``None`` — every non-chaos run — the consult is skipped
entirely and the trajectory stays byte-identical to a build without the
chaos plane.  When set, the injector decides *whether* a fault fires and
the module's graceful-degradation ladder decides *how* to survive it.

Seams (consulted by → method):

==============================  =======================================
``cluster.agents``              ``agent_outage(t)``, ``heartbeat_skew(t)``
``durability.store``            ``store_fault(op)``, ``note_io_recovered``
``core.simulator._schedule``    ``predictor_down(t)``,
                                ``note_predictor_fallback(t)``,
                                ``matcher_exhausted(t)``,
                                ``note_matcher_fallback(t, free, jobs)``
``serving_plane.plane``         ``serving_burst_mult(t)``,
                                ``brownout_frac(t)``
==============================  =======================================

:class:`FaultInjector` is the no-op base (usable directly as a "chaos
plane that never fires"); :class:`repro.chaos.campaign.ChaosCampaign` is
the seeded production implementation; :class:`ScriptedInjector` is a
deterministic hand-scripted stub for unit-testing individual seams.
"""
from __future__ import annotations

import numpy as np


class FaultInjector:
    """No-op injector: every method returns "no fault".

    Subclasses override only the seams they perturb.  Return conventions
    are chosen so the neutral value short-circuits cheaply: ``None`` means
    "don't even build the mask", ``False``/``1.0``/``0.0`` mean "no
    fault this consult".
    """

    # ---- cluster.agents -------------------------------------------------
    def agent_outage(self, t: float):
        """Bool mask over devices whose node agent is crashed at ``t``
        (crashed agents miss their heartbeat), or ``None`` for no outages."""
        return None

    def heartbeat_skew(self, t: float):
        """Per-device clock skew (seconds) subtracted from heartbeat
        timestamps at ``t``, or ``None`` for no skew."""
        return None

    # ---- durability.store -----------------------------------------------
    def store_fault(self, op: str) -> bool:
        """True to fail this IO attempt (``op`` in append/flush/fsync).
        Consulted *before* the real operation, so an injected fault never
        leaves a partial write behind."""
        return False

    def note_io_recovered(self, op: str, attempts: int) -> None:
        """The store's bounded retry ladder absorbed a transient fault."""

    # ---- core.simulator scheduling round --------------------------------
    def predictor_down(self, t: float) -> bool:
        """True while the trained speed predictor is unavailable."""
        return False

    def note_predictor_fallback(self, t: float) -> None:
        """A scheduling round ran on the static share table instead."""

    def matcher_exhausted(self, t: float) -> bool:
        """True when the KM matching time budget is exhausted this round."""
        return False

    def note_matcher_fallback(self, t: float, n_free: int,
                              n_jobs: int) -> None:
        """A scheduling round fell back to greedy-FIFO placement."""

    # ---- serving_plane --------------------------------------------------
    def serving_burst_mult(self, t: float) -> float:
        """Demand multiplier applied to lane arrivals at ``t`` (1.0 = none).
        Applied *after* the arrival draw so the RNG stream is untouched."""
        return 1.0

    def brownout_frac(self, t: float) -> float:
        """Fraction of the queue to brownout-shed at ``t`` (0.0 = none)."""
        return 0.0


class ScriptedInjector(FaultInjector):
    """Hand-scripted injector for unit tests — no RNG, no episodes.

    Attributes are plain knobs the test sets; calls are recorded so the
    test can assert the ladder engaged (``recovered``, ``pred_rounds``,
    ``matcher_rounds``).
    """

    def __init__(self, *, store_faults: int = 0,
                 predictor_down: bool = False,
                 matcher_exhausted: bool = False,
                 burst_mult: float = 1.0, brownout: float = 0.0,
                 down_mask=None, skew_s: float = 0.0):
        self.store_faults = int(store_faults)   # remaining IO faults to fire
        self._pred_down = bool(predictor_down)
        self._matcher = bool(matcher_exhausted)
        self.burst_mult = float(burst_mult)
        self.brownout = float(brownout)
        self.down_mask = (None if down_mask is None
                          else np.asarray(down_mask, dtype=bool))
        self.skew_s = float(skew_s)
        self.recovered: list[tuple[str, int]] = []
        self.pred_rounds = 0
        self.matcher_rounds = 0

    def agent_outage(self, t):
        return self.down_mask

    def heartbeat_skew(self, t):
        return self.skew_s if self.skew_s else None

    def store_fault(self, op):
        if self.store_faults > 0:
            self.store_faults -= 1
            return True
        return False

    def note_io_recovered(self, op, attempts):
        self.recovered.append((op, int(attempts)))

    def predictor_down(self, t):
        return self._pred_down

    def note_predictor_fallback(self, t):
        self.pred_rounds += 1

    def matcher_exhausted(self, t):
        return self._matcher

    def note_matcher_fallback(self, t, n_free, n_jobs):
        self.matcher_rounds += 1

    def serving_burst_mult(self, t):
        return self.burst_mult

    def brownout_frac(self, t):
        return self.brownout
