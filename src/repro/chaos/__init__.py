"""Chaos plane: deterministic control-plane fault injection + the
graceful-degradation ladder that provably survives it.

Public surface:

- :class:`ChaosConfig` / :class:`ChaosCampaign` — seeded campaign wired
  into :class:`repro.cluster.control.ControlPlane` via ``Scenario.chaos``.
- :class:`FaultInjector` / :class:`ScriptedInjector` — the seam protocol
  and a hand-scripted stub for unit tests.
- ``run_chaos_verification`` (in :mod:`repro.chaos.harness`, imported
  lazily to keep this package import-light) — the invariant harness
  behind ``python -m repro chaos``.
"""
from repro.chaos.campaign import (CHAOS_KINDS, CHAOS_SCHEMA, ChaosCampaign,
                                  ChaosConfig)
from repro.chaos.injector import FaultInjector, ScriptedInjector

__all__ = [
    "CHAOS_KINDS",
    "CHAOS_SCHEMA",
    "ChaosCampaign",
    "ChaosConfig",
    "FaultInjector",
    "ScriptedInjector",
]
