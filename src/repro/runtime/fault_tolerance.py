"""Cluster-runtime fault tolerance: heartbeats, failure detection, elastic
membership, straggler mitigation.

At MuxFlow scale (20 000+ GPUs / 1 000+ TPU hosts) node failure is routine:
offline jobs checkpoint-and-restart (checkpoint/), device health feeds the
SysMonitor (straggler == Unhealthy: its offline job is evicted off the
critical path), and membership changes simply rebuild the next scheduling
round's bipartite graph (core/scheduler.py) — elasticity by rescheduling.

The dead/stale predicate itself is :func:`repro.cluster.agents.stale_mask`
— one shared implementation, so this per-node detector and the control
plane's vectorized staleness masking can never disagree about when a node
counts as failed.
"""
from __future__ import annotations

import dataclasses
import time

from repro.cluster.agents import stale_mask


@dataclasses.dataclass
class NodeState:
    node_id: int
    last_heartbeat: float
    healthy: bool = True
    slow_ticks: int = 0            # consecutive straggler observations
    step_time_ema: float | None = None


class HeartbeatMonitor:
    """Failure detector: a node missing `timeout_s` of heartbeats is dead;
    a node whose step time exceeds `straggler_factor` × cluster median for
    `straggler_patience` consecutive reports is a straggler."""

    def __init__(self, n_nodes: int, *, timeout_s: float = 30.0,
                 straggler_factor: float = 1.5, straggler_patience: int = 3,
                 now: float | None = None):
        t = time.monotonic() if now is None else now
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.nodes = {i: NodeState(i, t) for i in range(n_nodes)}

    def heartbeat(self, node_id: int, *, step_time: float | None = None,
                  now: float | None = None) -> None:
        t = time.monotonic() if now is None else now
        n = self.nodes.setdefault(node_id, NodeState(node_id, t))
        n.last_heartbeat = t
        if step_time is not None:
            n.step_time_ema = (step_time if n.step_time_ema is None
                               else 0.7 * n.step_time_ema + 0.3 * step_time)

    def check(self, now: float | None = None) -> dict:
        """Returns {"dead": [...], "stragglers": [...], "alive": [...]}."""
        t = time.monotonic() if now is None else now
        dead, alive = [], []
        for n in self.nodes.values():
            (dead if stale_mask(t, n.last_heartbeat, self.timeout_s)
             else alive).append(n)
        times = sorted(n.step_time_ema for n in alive if n.step_time_ema)
        median = times[len(times) // 2] if times else None
        stragglers = []
        for n in alive:
            if (median and n.step_time_ema
                    and n.step_time_ema > self.straggler_factor * median):
                n.slow_ticks += 1
                if n.slow_ticks >= self.straggler_patience:
                    stragglers.append(n.node_id)
            else:
                n.slow_ticks = 0
        return {"dead": [n.node_id for n in dead],
                "stragglers": stragglers,
                "alive": [n.node_id for n in alive]}

    def remove(self, node_id: int) -> None:
        self.nodes.pop(node_id, None)

    def join(self, node_id: int, now: float | None = None) -> None:
        t = time.monotonic() if now is None else now
        self.nodes[node_id] = NodeState(node_id, t)


@dataclasses.dataclass
class ElasticPlan:
    """Outcome of a membership change: which mesh to rebuild and from which
    checkpoint step to resume."""
    world: list
    resume_step: int
    reason: str


class ElasticCoordinator:
    """Couples the failure detector with checkpoint/restart: on membership
    change, emit a plan (new world, resume step).  The caller re-creates the
    mesh from the surviving hosts and restores with resharding — checkpoint
    restore is mesh-shape agnostic (see checkpoint/checkpointing.py)."""

    def __init__(self, monitor: HeartbeatMonitor, get_ckpt_step):
        self.monitor = monitor
        self.get_ckpt_step = get_ckpt_step
        self._last_world: tuple | None = None

    def poll(self, now: float | None = None) -> ElasticPlan | None:
        status = self.monitor.check(now=now)
        world = tuple(sorted(status["alive"]))
        if self._last_world is None:
            self._last_world = world
            return None
        if world != self._last_world:
            reason = ("node_failure" if len(world) < len(self._last_world)
                      else "node_join")
            self._last_world = world
            return ElasticPlan(world=list(world),
                               resume_step=self.get_ckpt_step(),
                               reason=reason)
        return None
