"""Gradient compression for the offline DP/FSDP path.

Two standard schemes with error feedback:
  * int8 quantization (per-tensor absmax scaling) — 4× over fp32;
  * top-k sparsification (magnitude) with error-feedback residual.

These trade collective bytes for a little compute — exactly the lever when a
cell's roofline is collective-dominated (EXPERIMENTS.md §Perf quantifies it
on the dry-run).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def int8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_encode(x: jax.Array, k_frac: float = 0.05):
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    return sel, idx, x.shape


def topk_decode(vals, idx, shape) -> jax.Array:
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    return out.at[idx].set(vals).reshape(shape)


@dataclasses.dataclass
class CompressorState:
    residual: dict     # error-feedback per leaf


class GradCompressor:
    """Error-feedback compressor over a grad pytree.  mode: 'int8' | 'topk'."""

    def __init__(self, mode: str = "int8", k_frac: float = 0.05):
        assert mode in ("int8", "topk")
        self.mode = mode
        self.k_frac = k_frac

    def init(self, grads) -> CompressorState:
        return CompressorState(residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))

    def compress_decompress(self, grads, state: CompressorState):
        """Round-trip (what the wire would carry) with error feedback.
        Returns (decoded grads, new state, bytes_on_wire, bytes_raw)."""
        wire = raw = 0
        new_res = {}
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = jax.tree_util.tree_flatten(state.residual)[0]
        out = []
        for g, r in zip(flat_g, flat_r):
            gf = g.astype(jnp.float32) + r
            raw += g.size * 4
            if self.mode == "int8":
                q, scale = int8_encode(gf)
                dec = int8_decode(q, scale)
                wire += q.size + 4
            else:
                vals, idx, shape = topk_encode(gf, self.k_frac)
                dec = topk_decode(vals, idx, shape)
                wire += vals.size * 4 + idx.size * 4
            out.append(dec.astype(g.dtype))
            new_res[id(g)] = gf - dec
        new_state = CompressorState(residual=treedef.unflatten(
            [new_res[id(g)] for g in flat_g]))
        return treedef.unflatten(out), new_state, wire, raw
