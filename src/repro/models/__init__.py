from .model import ModelConfig, forward, init_cache, init_params  # noqa: F401
from .steps import (cross_entropy, greedy_generate, loss_fn,  # noqa: F401
                    make_decode_step, make_eval_step, make_prefill,
                    make_train_step)
