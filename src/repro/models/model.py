"""Model zoo core: config, init, and the pattern-scanned forward pass.

A model is a *pattern* of block descriptors `(mixer, ffn)` repeated
`num_layers / len(pattern)` times (jamba: 8-layer super-block × 9; dense LMs:
1-layer pattern × L).  Parameters and caches carry a leading `repeats` dim and
the forward pass is a single `lax.scan` over repeats — keeping the HLO small
enough to compile 40 dry-run cells on a CPU host with 512 fake devices.

Modes:
  * train   — full-seq forward, logits for every position (loss in steps.py)
  * prefill — full-seq forward, builds the decode cache, last-token logits
  * decode  — one token against the cache (the online-serving hot path)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.context import constrain

from . import layers as L
from . import moe as M
from . import ssm as S

Params = dict[str, Any]

MIXERS = ("attn", "attn_cross", "mamba", "mlstm")
FFNS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|encdec|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple = (("attn", "dense"),)
    # attention
    attn_kind: str = "gqa"            # gqa | mla
    window: int | None = None         # sliding-window size (None = full)
    rope_theta: float = 10000.0
    softcap: float | None = None
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    # ffn
    ffn_act: str = "silu"
    # moe
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_renormalize: bool = True
    moe_impl: str = "grouped"         # grouped (production) | dense (oracle)
    moe_capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    # ssm / mlstm
    ssm_d_inner: int = 0
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_dt_rank: int = 0
    ssm_chunk: int = 256
    mlstm_proj_factor: int = 2
    # encoder (enc-dec archs)
    enc_layers: int = 0
    # modality frontend stubs
    frontend: str = "none"            # none | audio | patch
    num_patches: int = 0              # vlm: image patches prepended to text
    # numerics / impl
    dtype: Any = jnp.bfloat16
    attn_impl: str = "reference"      # reference | pallas
    attn_force_chunked: bool = False  # stream KV chunks even at short seqs
    fused_loss: bool = False          # stream the vocab dim in the loss
    remat: bool = True
    vocab_pad_multiple: int = 256

    @property
    def repeats(self) -> int:
        assert self.num_layers % len(self.pattern) == 0, \
            f"{self.num_layers} layers vs pattern of {len(self.pattern)}"
        return self.num_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def decode_window(self) -> int | None:
        """KV capacity bound for sliding-window archs (ring cache)."""
        return self.window

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        p = 2 * self.padded_vocab * self.d_model   # embed + head
        per_pattern = 0
        for mixer, f in self.pattern:
            per_pattern += self.d_model            # norm1
            if mixer in ("attn", "attn_cross"):
                if self.attn_kind == "mla":
                    H, dh, r, dr = self.num_heads, self.head_dim, self.kv_lora_rank, self.rope_head_dim
                    per_pattern += self.d_model * H * (dh + dr) + self.d_model * (r + dr) \
                        + r * 2 * H * dh + H * dh * self.d_model
                else:
                    H, Hk, dh = self.num_heads, self.num_kv_heads, self.head_dim
                    per_pattern += self.d_model * dh * (H + 2 * Hk) + H * dh * self.d_model
                if mixer == "attn_cross":
                    H, Hk, dh = self.num_heads, self.num_kv_heads, self.head_dim
                    per_pattern += self.d_model * dh * (H + 2 * Hk) + H * dh * self.d_model + self.d_model
            elif mixer == "mamba":
                di, N, dtr, dc = self.ssm_d_inner, self.ssm_state_dim, self.ssm_dt_rank, self.ssm_conv_dim
                per_pattern += self.d_model * 2 * di + dc * di + di * (dtr + 2 * N) \
                    + dtr * di + di * N + di + di * self.d_model + 2 * di  # conv_b, dt_bias
            elif mixer == "mlstm":
                dp = self.mlstm_proj_factor * self.d_model
                per_pattern += self.d_model * 2 * dp + self.ssm_conv_dim * dp + 3 * dp * dp \
                    + 2 * dp * self.num_heads + dp + dp * self.d_model \
                    + dp + 2 * self.num_heads  # conv_b, b_i, b_f
            if f == "dense":
                per_pattern += self.d_model + 3 * self.d_model * self.d_ff
            elif f == "moe":
                per_pattern += self.d_model + self.d_model * self.num_experts \
                    + self.num_experts * 3 * self.d_model * self.moe_d_ff \
                    + (3 * self.d_model * self.moe_d_ff * self.num_shared_experts)
        p += per_pattern * self.repeats
        if self.enc_layers:
            enc = self.enc_layers * (2 * self.d_model
                                     + self.d_model * self.head_dim * (self.num_heads + 2 * self.num_kv_heads)
                                     + self.num_heads * self.head_dim * self.d_model
                                     + 3 * self.d_model * self.d_ff)
            p += enc + self.d_model  # + enc_final_norm
        p += self.d_model                          # final norm
        return p

    def active_param_count(self) -> int:
        """Per-token-active params (MoE: only top-k + shared experts)."""
        if not any(f == "moe" for _, f in self.pattern):
            return self.param_count()
        full = self.param_count()
        moe_positions = sum(1 for _, f in self.pattern if f == "moe")
        dead = (self.num_experts - self.top_k) * 3 * self.d_model * self.moe_d_ff
        return full - dead * moe_positions * self.repeats


# ===========================================================================
# Init
# ===========================================================================

def _block_init(key, cfg: ModelConfig, desc) -> Params:
    mixer, f = desc
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": L.rmsnorm_init(cfg.d_model, cfg.dtype)}
    if mixer == "attn" or mixer == "attn_cross":
        if cfg.attn_kind == "mla":
            p["attn"] = L.mla_init(ks[0], cfg, cfg.dtype)
        else:
            p["attn"] = L.gqa_init(ks[0], cfg, cfg.dtype)
        if mixer == "attn_cross":
            p["norm_cross"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
            p["cross"] = L.gqa_init(ks[1], cfg, cfg.dtype)
    elif mixer == "mamba":
        p["mixer"] = S.mamba_init(ks[0], cfg, cfg.dtype)
    elif mixer == "mlstm":
        p["mixer"] = S.mlstm_init(ks[0], cfg, cfg.dtype)
    else:
        raise ValueError(mixer)
    if f == "dense":
        p["norm2"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
        p["ffn"] = L.ffn_init(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype)
    elif f == "moe":
        p["norm2"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
        p["ffn"] = M.moe_init(ks[2], cfg, cfg.dtype)
    elif f != "none":
        raise ValueError(f)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    R = cfg.repeats
    blocks = []
    for i, desc in enumerate(cfg.pattern):
        bkeys = jax.random.split(jax.random.fold_in(keys[0], i), R)
        blocks.append(jax.vmap(partial(_block_init, cfg=cfg, desc=desc))(bkeys))
    p: Params = {
        "embed": L.embed_init(keys[1], (cfg.padded_vocab, cfg.d_model), cfg.dtype),
        "blocks": tuple(blocks),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "lm_head": L.dense_init(keys[2], (cfg.d_model, cfg.padded_vocab), cfg.dtype),
    }
    if cfg.enc_layers:
        ekeys = jax.random.split(keys[3], cfg.enc_layers)
        p["enc_blocks"] = jax.vmap(
            partial(_block_init, cfg=cfg, desc=("attn", "dense")))(ekeys)
        p["enc_final_norm"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
    return p


# ===========================================================================
# Caches
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, kv_capacity: int,
               src_len: int = 0) -> tuple:
    """Decode cache: tuple over pattern positions, each leaf leading-dim R.

    kv_capacity: sequence capacity of attention KV caches (for SWA archs this
    is min(window, kv_capacity): the ring bound).
    """
    R = cfg.repeats
    Hk, dh = cfg.num_kv_heads, cfg.head_dim
    caches = []
    for mixer, _ in cfg.pattern:
        if mixer in ("attn", "attn_cross"):
            cap = kv_capacity if cfg.window is None else min(cfg.window, kv_capacity)
            if cfg.attn_kind == "mla":
                c = {"ckv": jnp.zeros((R, batch, cap, cfg.kv_lora_rank), cfg.dtype),
                     "kr": jnp.zeros((R, batch, cap, 1, cfg.rope_head_dim), cfg.dtype)}
            else:
                c = {"k": jnp.zeros((R, batch, cap, Hk, dh), cfg.dtype),
                     "v": jnp.zeros((R, batch, cap, Hk, dh), cfg.dtype)}
            if mixer == "attn_cross":
                c["xk"] = jnp.zeros((R, batch, src_len, Hk, dh), cfg.dtype)
                c["xv"] = jnp.zeros((R, batch, src_len, Hk, dh), cfg.dtype)
        elif mixer == "mamba":
            st = S.mamba_state_init(batch, cfg)
            c = {k: jnp.zeros((R,) + v.shape, v.dtype) for k, v in st.items()}
        elif mixer == "mlstm":
            st = S.mlstm_state_init(batch, cfg)
            c = {"C": jnp.zeros((R,) + st["carry"][0].shape, jnp.float32),
                 "n": jnp.zeros((R,) + st["carry"][1].shape, jnp.float32),
                 "m": jnp.full((R,) + st["carry"][2].shape, -60.0, jnp.float32),
                 "conv": jnp.zeros((R,) + st["conv"].shape, st["conv"].dtype)}
        else:
            raise ValueError(mixer)
        caches.append(c)
    return tuple(caches)


# ===========================================================================
# Block application
# ===========================================================================

def _cache_write(cache, new, pos):
    """Write `new` (B,1,...) at sequence position `pos` (scalar or (B,)) —
    per-batch positions enable continuous batching (ragged slots)."""
    if jnp.ndim(pos) == 0:
        starts = (0, pos) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new, starts)
    def one(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(one)(cache, new, pos)


def _apply_attn(bp, x, cfg: ModelConfig, positions, cache, mode, enc_out=None,
                cross=False):
    """Self-attention sub-block.  Returns (out, new_cache_entries)."""
    B, Sq, _ = x.shape
    new_cache = {}
    if cfg.attn_kind == "mla":
        if mode == "decode":
            pos = positions[..., 0] if positions.ndim > 1 else positions[0]
            ckv_new, kr_new = L.mla_latent(bp["attn"], x, cfg, positions)
            ckv = _cache_write(cache["ckv"], ckv_new, pos)
            kr = _cache_write(cache["kr"], kr_new, pos)
            new_cache = {"ckv": ckv, "kr": kr}
            out = L.mla_attend(bp["attn"], x, ckv, kr, cfg, positions,
                               kv_len=pos + 1, causal=False)
        else:
            ckv, kr = L.mla_latent(bp["attn"], x, cfg, positions)
            out = L.mla_attend(bp["attn"], x, ckv, kr, cfg, positions, causal=True)
            if mode == "prefill":
                new_cache = {"ckv": ckv, "kr": kr}
        return out, new_cache

    q, k, v = L.gqa_project_qkv(bp["attn"], x, cfg, positions)
    if mode == "decode":
        pos = positions[..., 0] if positions.ndim > 1 else positions[0]
        if cfg.window is not None and cache["k"].shape[1] == cfg.window:
            slot = pos % cfg.window
            kc = _cache_write(cache["k"], k, slot)
            vc = _cache_write(cache["v"], v, slot)
            o = L.attention_ring_cache(q, kc, vc, pos=pos, window=cfg.window)
        else:
            kc = _cache_write(cache["k"], k, pos)
            vc = _cache_write(cache["v"], v, pos)
            o = L.attention(q, kc, vc, causal=False, q_offset=pos,
                            kv_len=pos + 1, softcap=cfg.softcap)
        new_cache = {"k": kc, "v": vc}
    else:
        o = L.attention(q, k, v, causal=not cross, window=cfg.window,
                        softcap=cfg.softcap,
                        force_chunked=cfg.attn_force_chunked)
        if mode == "prefill":
            if cfg.window is not None:
                W = cfg.window
                if k.shape[1] > W:          # keep last W entries, ring-aligned
                    kl, vl = k[:, -W:], v[:, -W:]
                    shift = (k.shape[1]) % W
                    kc = jnp.roll(kl, shift, axis=1)
                    vc = jnp.roll(vl, shift, axis=1)
                else:
                    kc, vc = k, v
                new_cache = {"k": kc, "v": vc}
            else:
                new_cache = {"k": k, "v": v}
    out = o.reshape(B, Sq, cfg.num_heads * cfg.head_dim) @ bp["attn"]["w_o"]
    return out, new_cache


def _apply_cross_attn(bp, x, enc_out, cfg, cache, mode):
    """Cross-attention: queries from x, keys/values from encoder output."""
    B, Sq, _ = x.shape
    H, Hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ bp["cross"]["w_q"]).reshape(B, Sq, H, dh)
    if mode == "decode":
        k, v = cache["xk"], cache["xv"]
        new = {"xk": k, "xv": v}
    else:
        Skv = enc_out.shape[1]
        k = (enc_out @ bp["cross"]["w_k"]).reshape(B, Skv, Hk, dh)
        v = (enc_out @ bp["cross"]["w_v"]).reshape(B, Skv, Hk, dh)
        new = {"xk": k, "xv": v} if mode == "prefill" else {}
    o = L.attention(q, k, v, causal=False)
    return o.reshape(B, Sq, H * dh) @ bp["cross"]["w_o"], new


def _apply_block(bp, x, cfg: ModelConfig, desc, positions, cache, mode,
                 enc_out=None):
    """Returns (x, new_cache, aux_loss)."""
    mixer, f = desc
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    h = L.rmsnorm(bp["norm1"], x)
    if mixer in ("attn", "attn_cross"):
        o, nc = _apply_attn(bp, h, cfg, positions, cache, mode)
        new_cache.update(nc)
        x = x + o
        if mixer == "attn_cross":
            h = L.rmsnorm(bp["norm_cross"], x)
            o, nc = _apply_cross_attn(bp, h, enc_out, cfg, cache, mode)
            new_cache.update(nc)
            x = x + o
    elif mixer == "mamba":
        if mode == "decode":
            st = {"h": cache["h"], "conv": cache["conv"]}
            o, st = S.mamba_decode_step(bp["mixer"], h, st, cfg)
            new_cache = dict(st)
        else:
            o, h_last = S.mamba_mixer(bp["mixer"], h, cfg)
            if mode == "prefill":
                # conv state holds the last dc-1 *inner* pre-conv activations
                x_in = h @ bp["mixer"]["in_proj"][:, :cfg.ssm_d_inner]
                new_cache = {"h": h_last,
                             "conv": x_in[:, -(cfg.ssm_conv_dim - 1):, :]}
        x = x + o
    elif mixer == "mlstm":
        if mode == "decode":
            st = {"carry": (cache["C"], cache["n"], cache["m"]), "conv": cache["conv"]}
            o, st = S.mlstm_decode_step(bp["mixer"], h, st, cfg)
            new_cache = {"C": st["carry"][0], "n": st["carry"][1],
                         "m": st["carry"][2], "conv": st["conv"]}
        else:
            o, carry = S.mlstm_mixer(bp["mixer"], h, cfg)
            if mode == "prefill":
                dp = cfg.mlstm_proj_factor * cfg.d_model
                x_in = h @ bp["mixer"]["up_proj"][:, :dp]
                new_cache = {"C": carry[0], "n": carry[1], "m": carry[2],
                             "conv": x_in[:, -(cfg.ssm_conv_dim - 1):, :]}
        x = x + o
    if f == "dense":
        h = L.rmsnorm(bp["norm2"], x)
        x = x + L.ffn(bp["ffn"], h, cfg.ffn_act)
    elif f == "moe":
        h = L.rmsnorm(bp["norm2"], x)
        o, a = M.moe_ffn(bp["ffn"], h, cfg)
        x = x + o
        aux = aux + a
    return x, new_cache, aux


# ===========================================================================
# Full forward
# ===========================================================================

def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Assemble the input embedding sequence from tokens and frontend stubs."""
    parts = []
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"].astype(cfg.dtype))
    toks = batch["tokens"]
    parts.append(jnp.take(params["embed"], toks, axis=0))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return constrain(x * math.sqrt(cfg.d_model), "dp", None, None)


def _encoder_forward(params, cfg: ModelConfig, src_embeds):
    """Bidirectional encoder over stub frame embeddings (audio frontend)."""
    x = src_embeds.astype(cfg.dtype) * math.sqrt(cfg.d_model)
    S_len = x.shape[1]
    positions = jnp.arange(S_len)

    def body(x, bp):
        h = L.rmsnorm(bp["norm1"], x)
        q, k, v = L.gqa_project_qkv(bp["attn"], h, cfg, positions)
        o = L.attention(q, k, v, causal=False)
        o = o.reshape(x.shape[0], S_len, cfg.num_heads * cfg.head_dim) @ bp["attn"]["w_o"]
        x = x + o
        h = L.rmsnorm(bp["norm2"], x)
        x = x + L.ffn(bp["ffn"], h, cfg.ffn_act)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_final_norm"], x)


def forward(params, cfg: ModelConfig, batch: dict, *, mode: str = "train",
            cache: tuple | None = None, pos=None):
    """Unified forward.

    train:   batch={tokens,(src_embeds|patch_embeds)} -> (logits, aux)
    prefill: same batch -> (last_logits, cache, aux)
    decode:  batch={tokens (B,1)}, cache, pos -> (logits, cache)
    """
    enc_out = None
    if cfg.enc_layers and mode != "decode":
        enc_out = _encoder_forward(params, cfg, batch["src_embeds"])

    if mode == "decode":
        x = jnp.take(params["embed"], batch["tokens"], axis=0) * math.sqrt(cfg.d_model)
        pos_arr = jnp.asarray(pos)
        positions = pos_arr[:, None] if pos_arr.ndim == 1 else pos_arr[None]
    else:
        x = _embed_inputs(params, cfg, batch)
        positions = jnp.arange(x.shape[1])

    P = len(cfg.pattern)

    def superblock(carry, xs):
        x, aux = carry
        blocks = xs[0]
        caches = xs[1] if cache is not None else (None,) * P
        new_caches = []
        for i, desc in enumerate(cfg.pattern):
            x, nc, a = _apply_block(blocks[i], x, cfg, desc, positions,
                                    caches[i], mode, enc_out=enc_out)
            x = constrain(x, "dp", None, None)
            new_caches.append(nc)
            aux = aux + a
        return (x, aux), tuple(new_caches)

    body = superblock
    if cfg.remat and mode in ("train", "train_hidden"):
        body = jax.checkpoint(superblock)

    xs = (params["blocks"],) if cache is None else (params["blocks"], cache)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    x = L.rmsnorm(params["final_norm"], x)

    if mode == "train":
        logits = x @ params["lm_head"]
        return logits, aux
    if mode == "train_hidden":
        return x, aux
    if mode == "prefill":
        last = x[:, -1:]
        logits = last @ params["lm_head"]
        return logits[:, 0], new_cache, aux
    logits = x[:, 0] @ params["lm_head"]
    return logits, new_cache
