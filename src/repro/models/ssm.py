"""State-space / recurrent mixers: Mamba (selective SSM) and xLSTM's mLSTM.

Both are implemented in *chunkwise-parallel* form for train/prefill
(sub-quadratic: O(S·cs) work materializing only chunk-local quadratics) plus an
O(1)-state decode step.  `repro.kernels.ssm_scan` provides the Pallas version
of the Mamba chunk kernel; these jnp forms are the reference/distribution path.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.context import constrain

from .layers import dense_init

Params = dict[str, Any]


# ===========================================================================
# Mamba (Mamba-1, diagonal A)
# ===========================================================================

def mamba_init(key, cfg, dtype) -> Params:
    d, di, N, dc = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state_dim, cfg.ssm_conv_dim
    dtr = cfg.ssm_dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (dc, di), dtype, scale=1.0 / math.sqrt(dc)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * N), dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _mamba_inputs(params, x, cfg):
    """Shared pre-scan computation.  Returns (dt, B_ssm, C_ssm, z, x_conv).
    The ×N-expanded tensors (dA, dBx: (.., di, N)) are NEVER materialized for
    the full sequence — only per chunk inside the scan body (memory: a full-
    seq (B,S,di,N) fp32 expansion is ~petabyte-scale for jamba train_4k)."""
    B, S, _ = x.shape
    di, N, dtr = cfg.ssm_d_inner, cfg.ssm_state_dim, cfg.ssm_dt_rank
    xz = constrain(x @ params["in_proj"], "dp", None, "tp")
    x_in, z = jnp.split(xz, 2, axis=-1)                    # (B,S,di) each
    x_conv = causal_conv1d(x_in, params["conv_w"], params["conv_b"])
    x_conv = jax.nn.silu(x_conv)
    dbc = x_conv @ params["x_proj"]                        # (B,S,dtr+2N)
    dt_lr = dbc[..., :dtr]
    B_ssm = dbc[..., dtr:dtr + N].astype(jnp.float32)      # (B,S,N)
    C_ssm = dbc[..., dtr + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_lr @ params["dt_proj"] + params["dt_bias"])  # (B,S,di)
    dt = constrain(dt.astype(jnp.float32), "dp", None, "tp")
    return dt, B_ssm, C_ssm, z, x_conv


def _mamba_expand(params, dt_c, B_c, xc_c):
    """Per-chunk discretization: dA, dBx (B,L,di,N) — chunk-local only."""
    A = -jnp.exp(params["A_log"])                          # (di,N)
    dA = jnp.exp(dt_c[..., None] * A)
    dBx = (dt_c * xc_c.astype(jnp.float32))[..., None] * B_c[..., None, :]
    return dA, dBx


def causal_conv1d(x, w, b):
    """Depthwise causal conv over the sequence dim.  x: (B,S,di), w: (dc,di)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(dc))
    return out + b


def _scan_chunk(h0, dA, dBx):
    """First-order recurrence over one chunk via associative scan.
    h0: (B,di,N); dA,dBx: (B,L,di,N).  Returns (h_all (B,L,di,N), h_last)."""
    def combine(a, b):
        (A1, b1), (A2, b2) = a, b
        return A1 * A2, b1 * A2 + b2
    Acum, bcum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = bcum + Acum * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_mixer(params: Params, x: jax.Array, cfg, h0=None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba mixer (chunked).  Returns (y, h_last)."""
    B, S, _ = x.shape
    di, N = cfg.ssm_d_inner, cfg.ssm_state_dim
    cs = min(cfg.ssm_chunk, S)
    if S % cs:
        cs = math.gcd(S, cs)  # fallback for odd prefill lengths
    dt, B_ssm, C_ssm, z, x_conv = _mamba_inputs(params, x, cfg)
    nck = S // cs
    if h0 is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)

    def body(h, inp):
        dt_c, B_c, C_c, xc_c = inp
        dA_c, dBx_c = _mamba_expand(params, dt_c, B_c, xc_c)
        h_all, h_last = _scan_chunk(h, constrain(dA_c, "dp", None, "tp", None),
                                    constrain(dBx_c, "dp", None, "tp", None))
        y_c = jnp.einsum("blds,bls->bld", h_all, C_c)
        y_c = y_c + params["D"] * xc_c.astype(jnp.float32)
        return h_last, constrain(y_c, "dp", None, "tp")

    # remat each chunk: the (B,cs,di,N) state expansion is recomputed in the
    # backward instead of stacked across chunks (70TB-scale for jamba).
    body = jax.checkpoint(body)
    reshape = lambda a: a.reshape(B, nck, cs, *a.shape[2:]).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(
        body, h0, (reshape(dt), reshape(B_ssm), reshape(C_ssm), reshape(x_conv)))
    y = ys.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], h_last


def mamba_mixer_ref(params: Params, x: jax.Array, cfg) -> jax.Array:
    """Sequential oracle (lax.scan over every step) — for tests."""
    B, S, _ = x.shape
    dt, B_ssm, C_ssm, z, x_conv = _mamba_inputs(params, x, cfg)
    dA, dBx = _mamba_expand(params, dt, B_ssm, x_conv)
    h0 = jnp.zeros((B, cfg.ssm_d_inner, cfg.ssm_state_dim), jnp.float32)

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t
        return h, jnp.einsum("bds,bs->bd", h, C_t)

    _, ys = jax.lax.scan(step, h0, (dA.swapaxes(0, 1), dBx.swapaxes(0, 1),
                                    C_ssm.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + params["D"] * x_conv.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"]


def mamba_decode_step(params: Params, x: jax.Array, state: dict, cfg):
    """One-token decode.  x: (B,1,d).  state: {"h": (B,di,N), "conv": (B,dc-1,di)}.
    Returns (y (B,1,d), new_state)."""
    B = x.shape[0]
    di, N, dtr, dc = (cfg.ssm_d_inner, cfg.ssm_state_dim, cfg.ssm_dt_rank,
                      cfg.ssm_conv_dim)
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                    # (B,1,di)
    conv_buf = jnp.concatenate([state["conv"], x_in], axis=1)  # (B,dc,di)
    x_conv = jnp.einsum("bcd,cd->bd", conv_buf, params["conv_w"]) + params["conv_b"]
    x_conv = jax.nn.silu(x_conv)[:, None]                  # (B,1,di)
    dbc = x_conv @ params["x_proj"]
    dt_lr = dbc[..., :dtr]
    B_ssm = dbc[..., dtr:dtr + N].astype(jnp.float32)[:, 0]
    C_ssm = dbc[..., dtr + N:].astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus(dt_lr @ params["dt_proj"] + params["dt_bias"])
    dt = dt.astype(jnp.float32)[:, 0]                      # (B,di)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A)                        # (B,di,N)
    dBx = (dt * x_conv.astype(jnp.float32)[:, 0])[..., None] * B_ssm[:, None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, C_ssm) + params["D"] * x_conv.astype(jnp.float32)[:, 0]
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, {"h": h, "conv": conv_buf[:, 1:]}


def mamba_state_init(B, cfg):
    return {
        "h": jnp.zeros((B, cfg.ssm_d_inner, cfg.ssm_state_dim), jnp.float32),
        "conv": jnp.zeros((B, cfg.ssm_conv_dim - 1, cfg.ssm_d_inner), cfg.dtype),
    }


# ===========================================================================
# mLSTM (xLSTM, matrix memory with exponential gating)
# ===========================================================================

def mlstm_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    pf = cfg.mlstm_proj_factor
    dp = pf * d
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * dp), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_dim, dp), dtype, scale=0.5),
        "conv_b": jnp.zeros((dp,), dtype),
        "w_q": dense_init(ks[2], (dp, dp), dtype),
        "w_k": dense_init(ks[3], (dp, dp), dtype),
        "w_v": dense_init(ks[4], (dp, dp), dtype),
        "w_i": dense_init(ks[5], (dp, H), jnp.float32, scale=0.02),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(ks[6], (dp, H), jnp.float32, scale=0.02),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # open forget gates at init
        "gn_scale": jnp.ones((dp,), dtype),
        "down_proj": dense_init(ks[7], (dp, d), dtype),
    }


def _mlstm_qkvif(params, x_in, cfg):
    """x_in: (B,S,dp) (post up-proj mlstm branch).  Returns q,k,v (B,H,S,dh)
    fp32 and gates i,f (B,H,S) fp32 (raw pre-activations)."""
    B, S, dp = x_in.shape
    H = cfg.num_heads
    dh = dp // H
    x_conv = jax.nn.silu(causal_conv1d(x_in, params["conv_w"], params["conv_b"]))
    to_heads = lambda a: constrain(
        a.reshape(B, S, H, dh).transpose(0, 2, 1, 3).astype(jnp.float32),
        "dp", None, None, "tp")
    q = to_heads(x_conv @ params["w_q"])
    k = to_heads(x_conv @ params["w_k"]) / math.sqrt(dh)
    v = to_heads(x_in @ params["w_v"])
    i_raw = (x_conv.astype(jnp.float32) @ params["w_i"] + params["b_i"])
    f_raw = (x_conv.astype(jnp.float32) @ params["w_f"] + params["b_f"])
    return q, k, v, i_raw.transpose(0, 2, 1), f_raw.transpose(0, 2, 1)


def _mlstm_chunk(q, k, v, i_raw, f_raw, carry):
    """One chunk of stabilized mLSTM.  All (B,H,L,·) fp32.
    carry = (C (B,H,dh,dh), n (B,H,dh), m (B,H))."""
    C_p, n_p, m_p = carry
    B, H, L, dh = q.shape
    logf = jax.nn.log_sigmoid(f_raw)                      # (B,H,L)
    F = jnp.cumsum(logf, axis=-1)                         # cumulative within chunk
    # pairwise decay D[t,s] = F_t - F_s + i_s   (valid for s<=t)
    Dm = F[..., :, None] - F[..., None, :] + i_raw[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(tri, Dm, -jnp.inf)
    m_intra = Dm.max(axis=-1)                             # (B,H,L)
    m_t = jnp.maximum(F + m_p[..., None], m_intra)
    m_t = jnp.maximum(m_t, -60.0)                         # floor to avoid inf ratios
    scores = jnp.exp(Dm - m_t[..., None])                 # (B,H,L,L)
    qk = jnp.einsum("bhtd,bhsd->bhts", q, k)
    num = jnp.einsum("bhts,bhsv->bhtv", scores * qk, v)
    den = (scores * qk).sum(-1)
    inter_w = jnp.exp(F + m_p[..., None] - m_t)           # (B,H,L)
    num = num + inter_w[..., None] * jnp.einsum("bhtd,bhdv->bhtv", q, C_p)
    den = den + inter_w * jnp.einsum("bhtd,bhd->bht", q, n_p)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    # ---- carry update to end of chunk
    last = L - 1
    m_new = jnp.maximum(F[..., last:] + m_p[..., None], m_intra[..., last:])[..., 0]
    m_new = jnp.maximum(m_new, -60.0)
    wS = jnp.exp(F[..., last, None] - F + i_raw - m_new[..., None])  # (B,H,L)
    C_new = (jnp.exp(F[..., last] + m_p - m_new)[..., None, None] * C_p
             + jnp.einsum("bhs,bhsd,bhsv->bhdv", wS, k, v))
    n_new = (jnp.exp(F[..., last] + m_p - m_new)[..., None] * n_p
             + jnp.einsum("bhs,bhsd->bhd", wS, k))
    return h, (C_new, n_new, m_new)


def mlstm_mixer(params: Params, x: jax.Array, cfg, carry=None):
    """Full mLSTM block body.  x: (B,S,d) -> (y (B,S,d), carry)."""
    B, S, d = x.shape
    dp = cfg.mlstm_proj_factor * d
    H = cfg.num_heads
    dh = dp // H
    xz = x @ params["up_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_raw, f_raw = _mlstm_qkvif(params, x_in, cfg)
    cs = min(cfg.ssm_chunk, S)
    if S % cs:
        cs = math.gcd(S, cs)
    nck = S // cs
    if carry is None:
        carry = mlstm_carry_init(B, H, dh)

    resh = lambda a: a.reshape(B, H, nck, cs, *a.shape[3:]).transpose(2, 0, 1, 3, *range(4, a.ndim + 1))
    def body(c, inp):
        qc, kc, vc, ic, fc = inp
        h, c = _mlstm_chunk(qc, kc, vc, ic, fc, c)
        return c, h
    body = jax.checkpoint(body)  # recompute (L,L) gate matrices in the bwd
    carry, hs = jax.lax.scan(body, carry, (resh(q), resh(k), resh(v), resh(i_raw), resh(f_raw)))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)  # (B,H,S,dh)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, dp)
    # per-head group norm
    hg = h.reshape(B, S, H, dh)
    mu = hg.mean(-1, keepdims=True)
    var = hg.var(-1, keepdims=True)
    hg = (hg - mu) * jax.lax.rsqrt(var + 1e-6)
    h = (hg.reshape(B, S, dp) * params["gn_scale"]).astype(x.dtype)
    h = h * jax.nn.silu(z)
    return h @ params["down_proj"], carry


def mlstm_carry_init(B, H, dh):
    return (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H), -60.0, jnp.float32))


def mlstm_mixer_ref(params: Params, x: jax.Array, cfg) -> jax.Array:
    """Sequential per-step oracle."""
    B, S, d = x.shape
    dp = cfg.mlstm_proj_factor * d
    H = cfg.num_heads
    dh = dp // H
    xz = x @ params["up_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_raw, f_raw = _mlstm_qkvif(params, x_in, cfg)
    carry = mlstm_carry_init(B, H, dh)

    def step(c, inp):
        qt, kt, vt, it, ft = inp                           # (B,H,dh) / (B,H)
        h, c = _mlstm_cell_step(qt, kt, vt, it, ft, c)
        return c, h

    qs, ks_, vs = (a.transpose(2, 0, 1, 3) for a in (q, k, v))
    is_, fs = (a.transpose(2, 0, 1) for a in (i_raw, f_raw))
    _, hs = jax.lax.scan(step, carry, (qs, ks_, vs, is_, fs))
    h = hs.transpose(1, 2, 0, 3).transpose(0, 2, 1, 3).reshape(B, S, dp)
    hg = h.reshape(B, S, H, dh)
    mu = hg.mean(-1, keepdims=True)
    var = hg.var(-1, keepdims=True)
    hg = (hg - mu) * jax.lax.rsqrt(var + 1e-6)
    h = (hg.reshape(B, S, dp) * params["gn_scale"]).astype(x.dtype)
    h = h * jax.nn.silu(z)
    return h @ params["down_proj"]


def _mlstm_cell_step(qt, kt, vt, it, ft, carry):
    """Single-step stabilized mLSTM cell.  qt,kt,vt: (B,H,dh); it,ft: (B,H)."""
    C_p, n_p, m_p = carry
    logf = jax.nn.log_sigmoid(ft)
    m_t = jnp.maximum(logf + m_p, it)
    m_t = jnp.maximum(m_t, -60.0)
    fw = jnp.exp(logf + m_p - m_t)[..., None]
    iw = jnp.exp(it - m_t)[..., None]
    C_t = fw[..., None] * C_p + iw[..., None] * kt[..., :, None] * vt[..., None, :]
    n_t = fw * n_p + iw * kt
    num = jnp.einsum("bhd,bhdv->bhv", qt, C_t)
    den = jnp.einsum("bhd,bhd->bh", qt, n_t)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    return h, (C_t, n_t, m_t)


def mlstm_decode_step(params: Params, x: jax.Array, state: dict, cfg):
    """One-token decode.  x: (B,1,d).  state: {"carry": (C,n,m), "conv": (B,dc-1,dp)}."""
    B = x.shape[0]
    d = cfg.d_model
    dp = cfg.mlstm_proj_factor * d
    H = cfg.num_heads
    dh = dp // H
    xz = x @ params["up_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                    # (B,1,dp)
    conv_buf = jnp.concatenate([state["conv"], x_in], axis=1)
    x_conv = jnp.einsum("bcd,cd->bd", conv_buf, params["conv_w"]) + params["conv_b"]
    x_conv = jax.nn.silu(x_conv)                           # (B,dp)
    qt = (x_conv @ params["w_q"]).reshape(B, H, dh).astype(jnp.float32)
    kt = (x_conv @ params["w_k"]).reshape(B, H, dh).astype(jnp.float32) / math.sqrt(dh)
    vt = (x_in[:, 0] @ params["w_v"]).reshape(B, H, dh).astype(jnp.float32)
    it = (x_conv.astype(jnp.float32) @ params["w_i"] + params["b_i"])
    ft = (x_conv.astype(jnp.float32) @ params["w_f"] + params["b_f"])
    h, carry = _mlstm_cell_step(qt, kt, vt, it, ft, state["carry"])
    mu = h.mean(-1, keepdims=True)
    var = h.var(-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + 1e-6)
    h = (h.reshape(B, dp) * params["gn_scale"]).astype(x.dtype)
    h = (h * jax.nn.silu(z[:, 0]))[:, None]
    return h @ params["down_proj"], {"carry": carry, "conv": conv_buf[:, 1:]}


def mlstm_state_init(B, cfg):
    dp = cfg.mlstm_proj_factor * cfg.d_model
    H = cfg.num_heads
    return {"carry": mlstm_carry_init(B, H, dp // H),
            "conv": jnp.zeros((B, cfg.ssm_conv_dim - 1, dp), cfg.dtype)}
