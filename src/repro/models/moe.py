"""Mixture-of-Experts FFN with top-k routing and optional shared experts.

Two execution paths:
  * `moe_dense_dispatch` — baseline: every expert runs on every token and the
    result is combined with the (sparse) routing weights.  FLOP-inflated but
    trivially shardable; this is the paper-faithful baseline the roofline
    analysis starts from.
  * `moe_grouped_dispatch` — capacity-based gather/scatter dispatch: tokens are
    routed to per-expert buffers of capacity C = ceil(k*T/E)*cf, experts run
    only on their buffers.  This is the optimized path (§Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# jax.shard_map (with check_vma) landed after 0.4.x; fall back to the
# experimental module and its check_rep spelling of the same kwarg
if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:  # pragma: no cover - exercised on jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

from repro.sharding.context import constrain

from .layers import dense_init, _ACTS


def moe_init(key, cfg, dtype):
    d, dff = cfg.d_model, cfg.moe_d_ff
    E, S = cfg.num_experts, cfg.num_shared_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, dff), dtype),
        "w_up": dense_init(ks[2], (E, d, dff), dtype),
        "w_down": dense_init(ks[3], (E, dff, d), dtype),
    }
    if S > 0:
        from .layers import ffn_init
        p["shared"] = ffn_init(ks[4], d, dff * S, dtype)
    return p


def router_probs(params, x, cfg):
    """Top-k routing probabilities.  x: (B,S,d) -> (weights (B,S,k), idx (B,S,k),
    aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ params["router"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)               # (B,S,k)
    if cfg.moe_renormalize:
        weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss
    E = cfg.num_experts
    me = jnp.mean(probs, axis=(0, 1))                            # mean prob per expert
    ce = jnp.mean(
        (jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(-2) > 0).astype(jnp.float32),
        axis=(0, 1),
    )
    aux = E * jnp.sum(me * ce)
    return weights, idx, aux


def _expert_ffn(wp, x, act):
    g = _ACTS[act](jnp.einsum("ted,edf->tef", x, wp["w_gate"]))
    u = jnp.einsum("ted,edf->tef", x, wp["w_up"])
    return jnp.einsum("tef,efd->ted", g * u, wp["w_down"])


def moe_dense_dispatch(params, x, cfg):
    """Baseline: run all E experts on all tokens; combine by routing weights."""
    B, S, d = x.shape
    weights, idx, aux = router_probs(params, x, cfg)
    xt = x.reshape(B * S, 1, d)
    xe = jnp.broadcast_to(xt, (B * S, cfg.num_experts, d))
    ye = _expert_ffn(params, xe, cfg.ffn_act)                    # (T,E,d)
    comb = jnp.zeros((B * S, cfg.num_experts), x.dtype)
    comb = comb.at[jnp.arange(B * S)[:, None], idx.reshape(B * S, -1)].add(
        weights.reshape(B * S, -1).astype(x.dtype))
    y = jnp.einsum("ted,te->td", ye, comb).reshape(B, S, d)
    if "shared" in params:
        from .layers import ffn
        y = y + ffn(params["shared"], x, cfg.ffn_act)
    return y, aux


def moe_grouped_dispatch(params, x, cfg, capacity_factor: float = 1.25):
    """Capacity-based grouped dispatch (production path, expert-parallel).

    Each batch row is a dispatch *group* (stays on its data shard).  Within a
    group, slots are sorted by expert id to compute in-expert positions in
    O(M log M) instead of the O(M·E) cumsum, scattered into per-expert
    capacity buffers, run through the expert FFN (experts sharded over the
    model axis = EP), and gathered back.  Slots beyond capacity are dropped
    (GShard/Switch semantics).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    M = S * K
    weights, idx, aux = router_probs(params, x, cfg)             # (B,S,K)
    cap = int(max(1, round(-(-S * K // E) * capacity_factor)))
    cap = min(cap, M)

    flat_idx = idx.reshape(B, M)                                 # expert of slot
    tok_of_slot = jnp.repeat(jnp.arange(S), K)                   # (M,)

    def group_positions(e_ids):
        order = jnp.argsort(e_ids, stable=True)
        ranks = jnp.zeros((M,), jnp.int32).at[order].set(jnp.arange(M, dtype=jnp.int32))
        sorted_e = e_ids[order]
        start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        return ranks - start[e_ids]

    pos = jax.vmap(group_positions)(flat_idx)                    # (B,M)
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)

    def scatter_group(xg, e_ids, p, kp):
        vals = jnp.where(kp[:, None], xg[tok_of_slot], 0)
        return jnp.zeros((E, cap, d), x.dtype).at[e_ids, p].add(vals)

    buf = jax.vmap(scatter_group)(x, flat_idx, safe_pos, keep)   # (B,E,cap,d)
    buf = constrain(buf, "dp", "tp", None, None)
    yb = _expert_ffn_grouped(params, buf, cfg.ffn_act)           # (B,E,cap,d)
    yb = constrain(yb, "dp", "tp", None, None)

    def gather_group(ybg, e_ids, p):
        return ybg[e_ids, p]                                     # (M,d)

    g = jax.vmap(gather_group)(yb, flat_idx, safe_pos)           # (B,M,d)
    g = jnp.where(keep[..., None], g, 0).reshape(B, S, K, d)
    y = jnp.einsum("bskd,bsk->bsd", g, weights.astype(x.dtype))
    if "shared" in params:
        from .layers import ffn
        y = y + ffn(params["shared"], x, cfg.ffn_act)
    return y.astype(x.dtype), aux


def _expert_ffn_grouped(wp, buf, act):
    g = _ACTS[act](constrain(jnp.einsum("becd,edf->becf", buf, wp["w_gate"]),
                             "dp", "tp", None, None))
    u = constrain(jnp.einsum("becd,edf->becf", buf, wp["w_up"]),
                  "dp", "tp", None, None)
    return jnp.einsum("becf,efd->becd", g * u, wp["w_down"])


def moe_a2a_dispatch(params, x, cfg, capacity_factor: float = 1.25):
    """Expert-parallel dispatch with explicit all-to-alls (shard_map).

    The §Perf optimization over `grouped`: GSPMD lowers the grouped gather
    /scatter across expert shards into partial-sum all-reduces of the full
    (tokens, d) slot tensor; here each token's slots move to their expert's
    shard and back with two all-to-alls over the model axis, so only routed
    capacity travels at (n−1)/n per direction.

    Falls back to `moe_grouped_dispatch` when no mesh with a model axis is
    installed (unit tests, single-device runs).
    """
    from repro.sharding.context import current_mesh
    mesh = current_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or cfg.num_experts % mesh.shape["model"] != 0):
        return moe_grouped_dispatch(params, x, cfg, capacity_factor)
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import dp_axes

    tp = mesh.shape["model"]
    dp = dp_axes(mesh)
    dp_spec = dp[0] if len(dp) == 1 else dp
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    E_loc = E // tp
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    B_loc = B // dp_size if (dp_size > 1 and B % dp_size == 0) else B
    batch_spec = dp_spec if (dp_size > 1 and B % dp_size == 0) else None
    if (B_loc * S) % tp != 0:
        return moe_grouped_dispatch(params, x, cfg, capacity_factor)
    M = B_loc * S * K // tp          # slots per device (token-parallel)
    cap = int(max(1, round(-(-M // E) * capacity_factor)))
    cap = min(cap, M)

    def local_moe(router_w, w_gate, w_up, w_down, shared, x_loc):
        b, s, _ = x_loc.shape
        # x_loc is replicated across the model axis: each model-rank routes
        # only its 1/tp slice of the tokens (token-parallel dispatch), so the
        # expert FLOPs stay at 1/(dp*tp) of the global work per device.
        rank = jax.lax.axis_index("model")
        T = b * s
        T_loc = T // tp
        xt_full = x_loc.reshape(T, d)
        xt = jax.lax.dynamic_slice_in_dim(xt_full, rank * T_loc, T_loc, 0)
        logits = xt.astype(jnp.float32) @ router_w
        probs = jax.nn.softmax(logits, axis=-1)
        weights, idx = jax.lax.top_k(probs, K)                # (T, K)
        if cfg.moe_renormalize:
            weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean((jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1) > 0)
                      .astype(jnp.float32), axis=0)
        aux = E * jnp.sum(me * ce)
        m = T_loc * K
        e_ids = idx.reshape(m)
        tok = jnp.repeat(jnp.arange(T_loc), K)
        order = jnp.argsort(e_ids, stable=True)
        ranks = jnp.zeros((m,), jnp.int32).at[order].set(
            jnp.arange(m, dtype=jnp.int32))
        start = jnp.searchsorted(e_ids[order], jnp.arange(E), side="left")
        pos = ranks - start[e_ids]
        keep = pos < cap
        safe = jnp.where(keep, pos, cap - 1)
        buf = jnp.zeros((E, cap, d), x_loc.dtype).at[e_ids, safe].add(
            jnp.where(keep[:, None], xt[tok], 0))
        # ---- a2a out: send expert-block i to model-shard i; receive every
        # shard's rows for MY local experts: (tp_src, E_loc, cap, d)
        buf = buf.reshape(tp, E_loc, cap, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                 tiled=True)
        buf = buf.transpose(1, 0, 2, 3).reshape(E_loc, tp * cap, d)
        g = _ACTS[cfg.ffn_act](jnp.einsum("ecd,edf->ecf", buf, w_gate))
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        yb = jnp.einsum("ecf,efd->ecd", g * u, w_down)
        # ---- a2a back: return each shard's token rows to their owner
        yb = yb.reshape(E_loc, tp, cap, d).transpose(1, 0, 2, 3)
        yb = jax.lax.all_to_all(yb, "model", split_axis=0, concat_axis=0,
                                tiled=True)      # (tp_expert_owner, E_loc, cap, d)
        yb = yb.reshape(E, cap, d)
        got = yb[e_ids, safe]
        got = jnp.where(keep[:, None], got, 0).reshape(T_loc, K, d)
        y = jnp.einsum("tkd,tk->td", got, weights.astype(x_loc.dtype))
        if shared is not None:
            # shared experts also run token-parallel over the model axis
            sg = _ACTS[cfg.ffn_act](xt @ shared["w_gate"])
            y = y + (sg * (xt @ shared["w_up"])) @ shared["w_down"]
        # reassemble the full token dim across the model axis
        y = jax.lax.all_gather(y, "model", axis=0, tiled=True)
        y = y.reshape(b, s, d)
        return y.astype(x_loc.dtype), aux[None]

    shared = params.get("shared")
    shared_spec = (jax.tree.map(lambda _: P(), shared)
                   if shared is not None else None)
    fn = _shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), shared_spec,
                  P(batch_spec, None, None)),
        out_specs=(P(batch_spec, None, None), P(dp_spec if dp else None)),
        **{_CHECK_KW: False})
    y, aux = fn(params["router"], params["w_gate"], params["w_up"],
                params["w_down"], shared, x)
    return y, jnp.mean(aux)


def moe_ffn(params, x, cfg):
    """Dispatch-mode switch: cfg.moe_impl in {'dense','grouped','a2a'}."""
    impl = getattr(cfg, "moe_impl", "dense")
    if impl == "a2a":
        return moe_a2a_dispatch(params, x, cfg,
                                capacity_factor=cfg.moe_capacity_factor)
    if impl == "grouped":
        return moe_grouped_dispatch(params, x, cfg,
                                    capacity_factor=cfg.moe_capacity_factor)
    return moe_dense_dispatch(params, x, cfg)
