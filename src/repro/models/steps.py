"""Step functions: loss, train_step, prefill, decode — the jit/pjit units.

These are what the launcher lowers for the dry-run and what the MuxFlow
multiplexer executes (decode = online workload, train = offline workload).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.context import constrain

from .model import ModelConfig, forward, init_cache, init_params  # noqa: F401


def cross_entropy(logits: jax.Array, targets: jax.Array, vocab_size: int,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross-entropy.  logits: (B,S,Vpad); targets: (B,S).
    Padded-vocab columns are excluded from the partition function."""
    Vpad = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if Vpad > vocab_size:
        pad_bias = jnp.where(jnp.arange(Vpad) < vocab_size, 0.0, -1e9)
        lf = lf + pad_bias
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.clip(mask.sum(), 1.0)
    return nll.mean()


def chunked_cross_entropy(x, lm_head, targets, vocab_size, mask,
                          chunk: int = 8192):
    """Fused loss: never materializes (B,S,Vpad) logits.  Scans lm_head in
    vocab chunks with a streaming log-sum-exp; each chunk's logits are
    recomputed in the backward (jax.checkpoint).  x: (B,S,d) post-norm
    hiddens; lm_head: (d, Vpad)."""
    d, Vpad = lm_head.shape
    if Vpad % chunk:
        chunk = math.gcd(Vpad, chunk) or Vpad
    nck = Vpad // chunk
    ws = lm_head.reshape(d, nck, chunk).transpose(1, 0, 2)   # (nck, d, chunk)
    B, S, _ = x.shape

    def body(carry, wi):
        m, s, gold = carry
        w, i = wi
        logits_c = (x @ w).astype(jnp.float32)               # (B,S,chunk)
        col = i * chunk + jnp.arange(chunk)
        logits_c = jnp.where(col[None, None, :] < vocab_size, logits_c, -1e9)
        m_new = jnp.maximum(m, logits_c.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(
            logits_c - m_new[..., None]).sum(-1)
        local = targets - i * chunk
        in_c = (local >= 0) & (local < chunk)
        g = jnp.take_along_axis(logits_c, jnp.clip(local, 0, chunk - 1)[..., None],
                                axis=-1)[..., 0]
        gold = gold + jnp.where(in_c, g, 0.0)
        return (m_new, s, gold), None

    init = (jnp.full((B, S), -1e30, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.zeros((B, S), jnp.float32))
    (m, s, gold), _ = jax.lax.scan(
        jax.checkpoint(body), init, (ws, jnp.arange(nck)))
    nll = (m + jnp.log(jnp.maximum(s, 1e-30))) - gold
    nll = nll * mask
    return nll.sum() / jnp.clip(mask.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Next-token LM loss (+ MoE aux).  For VLM inputs the image-patch
    positions are excluded from the loss.  cfg.fused_loss streams the vocab
    dim instead of materializing (B,S,Vpad) logits."""
    toks = batch["tokens"]
    n_p = (batch["patch_embeds"].shape[1]
           if cfg.frontend == "patch" and "patch_embeds" in batch else 0)
    targets = jnp.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
    mask = jnp.ones(toks.shape, jnp.float32).at[:, -1].set(0.0)
    if getattr(cfg, "fused_loss", False):
        hidden, aux = forward(params, cfg, batch, mode="train_hidden")
        hidden = constrain(hidden, "dp", None, None)
        if n_p:
            hidden = hidden[:, n_p:]
        ce = chunked_cross_entropy(hidden, params["lm_head"], targets,
                                   cfg.vocab_size, mask)
    else:
        logits, aux = forward(params, cfg, batch, mode="train")
        logits = constrain(logits, "dp", None, "tp")
        if n_p:
            logits = logits[:, n_p:]
        ce = cross_entropy(logits, targets, cfg.vocab_size, mask)
    return ce + cfg.moe_aux_weight * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, optimizer, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 enables gradient accumulation: the global batch is split
    along dim 0 and scanned, with fp32 grad accumulation (grads inherit the
    FSDP parameter sharding, so the accumulator is ZeRO-sharded).  This is how
    very large models (jamba-398B) fit their activations on a pod.
    """

    def grad_fn(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(p, cfg, batch),
                                  has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, loss_a, ce_a, aux_a = carry
                (loss, (ce, aux)), grads = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
                return (acc, loss_a + loss, ce_a + ce, aux_a + aux), None

            zero = jnp.zeros((), jnp.float32)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                body, (acc0, zero, zero, zero), micro)
            scale = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * scale, grads)
            loss, ce, aux = loss * scale, ce * scale, aux * scale
        params, opt_state, gnorm = optimizer.update(params, grads, opt_state)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, (ce, aux) = loss_fn(params, cfg, batch)
        return {"loss": loss, "ce": ce}
    return eval_step


def make_prefill(cfg: ModelConfig):
    """prefill(params, batch) -> (next_token_logits (B,Vpad), cache)."""

    def prefill(params, batch):
        logits, cache, _aux = forward(params, cfg, batch, mode="prefill")
        return logits, cache

    return prefill


def make_decode_step(cfg: ModelConfig):
    """decode_step(params, cache, tokens (B,1), pos) -> (logits (B,Vpad), cache).

    This is the online-serving unit MuxFlow protects: one token for the whole
    batch against the standing cache."""

    def decode_step(params, cache, tokens, pos):
        logits, cache = forward(params, cfg, {"tokens": tokens}, mode="decode",
                                cache=cache, pos=pos)
        return logits, cache

    return decode_step


def greedy_generate(cfg: ModelConfig, params, batch, steps: int):
    """Tiny sampling loop for examples/tests: prefill then greedy decode."""
    prefill = make_prefill(cfg)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, batch)
    # re-init a roomier cache for generation
    B = batch["tokens"].shape[0]
    S0 = batch["tokens"].shape[1] + (batch.get("patch_embeds").shape[1]
                                     if cfg.frontend == "patch" and "patch_embeds" in batch else 0)
    cap = S0 + steps
    full_cache = init_cache(cfg, B, cap, src_len=batch.get("src_embeds", jnp.zeros((1, 0, 1))).shape[1])
    full_cache = _copy_prefix_cache(cfg, cache, full_cache)
    toks = [jnp.argmax(logits[:, :cfg.vocab_size], -1)]
    cache = full_cache
    for i in range(steps):
        logits, cache = decode(params, cache, toks[-1][:, None], S0 + i)
        toks.append(jnp.argmax(logits[:, :cfg.vocab_size], -1))
    return jnp.stack(toks, axis=1)


def _copy_prefix_cache(cfg, src, dst):
    """Copy a prefill cache (length S0) into a larger decode cache."""
    out = []
    for ci, (mixer, _) in enumerate(cfg.pattern):
        d = dict(dst[ci])
        for k, v in src[ci].items():
            if k in ("k", "v", "ckv", "kr", "xk", "xv") and v.ndim >= 3:
                d[k] = jax.lax.dynamic_update_slice(
                    dst[ci][k], v.astype(dst[ci][k].dtype), (0,) * v.ndim)
            else:
                d[k] = v
        out.append(d)
    return tuple(out)
