"""Core model layers: norms, RoPE, attention (GQA / MLA / sliding-window), GLU FFN.

Pure-functional: every layer is `fn(params, x, ...)` over nested-dict params.
All matmuls run in the configured compute dtype (bf16 by default); softmax and
normalization statistics are computed in fp32 for stability.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.context import constrain

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (production default)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, dh/2)
    sin = jnp.sin(ang)[..., None, :]                  # (..., S, 1, dh/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (reference dense path; Pallas kernels live in repro.kernels)
# ---------------------------------------------------------------------------

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def repeat_kv(k: jax.Array, G: int) -> jax.Array:
    """(B,S,Hk,dh) -> (B,S,Hk*G,dh).  Keeps heads a single flat dim so the
    score tensor (B,H,Sq,Skv) shards over the model axis under GSPMD."""
    return jnp.repeat(k, G, axis=2) if G > 1 else k


# Above this many score elements per head-batch, attention() streams over
# KV chunks (flash-style online softmax) instead of materializing (Sq, Skv).
_MATERIALIZE_LIMIT = 4096 * 4096
_CHUNK_Q = 2048
_CHUNK_K = 2048


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    softcap: float | None = None,
    force_chunked: bool = False,
) -> jax.Array:
    """Reference attention with GQA, causal/sliding-window masking.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hk, dh) with H % Hk == 0.
    q_offset: absolute position of q[.., 0] (decode: current position).
    kv_len: number of valid kv entries (decode with pre-allocated cache).
    Returns (B, Sq, H, dh) in q.dtype.

    Long sequences (prefill_32k etc.) dispatch to the chunked online-softmax
    form — the jnp analogue of the Pallas flash kernel.
    """
    Sq, Skv = q.shape[1], k.shape[1]
    if ((force_chunked or Sq * Skv > _MATERIALIZE_LIMIT) and Sq > 1
            and Sq % _CHUNK_Q == 0 and Skv % _CHUNK_K == 0):
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, kv_len=kv_len,
                                 softcap=softcap)
    if Sq <= 8 and q.shape[2] != k.shape[2]:
        # decode: grouped-query scores without materializing repeated KV
        # (the KV cache may be seq-sharded over the model axis; scores align)
        return _attention_gqa_decode(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset, kv_len=kv_len,
                                     softcap=softcap)
    B, Sq, H, dh = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // Hk
    k = constrain(repeat_kv(k, G), "dp", None, "tp", None)
    v = constrain(repeat_kv(v, G), "dp", None, "tp", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q * (dh ** -0.5), k,
                        preferred_element_type=jnp.float32)  # (B,H,Sq,Skv)
    scores = constrain(scores, "dp", "tp", None, None)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap

    q_off = jnp.asarray(q_offset)
    q_pos = q_off.reshape(-1, 1) + jnp.arange(Sq)[None]   # (1|B, Sq)
    k_pos = jnp.arange(Skv)                               # (Skv,)
    mask = jnp.ones((q_pos.shape[0], Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos[None, None, :] <= q_pos[..., None]
    if window is not None:
        mask &= k_pos[None, None, :] > q_pos[..., None] - window
    mask = jnp.broadcast_to(mask, (B, Sq, Skv)) if mask.shape[0] == 1 \
        else mask
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        kvl = jnp.broadcast_to(kvl.reshape(-1, 1, 1), (B, 1, 1))
        mask = mask & (k_pos[None, None, :] < kvl)
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = constrain(out, "dp", None, "tp", None)
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


def _attention_gqa_decode(q, k, v, *, causal, window, q_offset, kv_len,
                          softcap) -> jax.Array:
    """Decode-shape attention keeping KV heads grouped: q (B,Sq,H,dh) vs
    k/v (B,Skv,Hk,dh); scores (B,Hk,G,Sq,Skv)."""
    B, Sq, H, dh = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, dh) * (dh ** -0.5)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    q_off = jnp.asarray(q_offset)
    q_pos = q_off.reshape(-1, 1) + jnp.arange(Sq)[None]   # (1|B, Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((q_pos.shape[0], Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos[None, None, :] <= q_pos[..., None]
    if window is not None:
        mask &= k_pos[None, None, :] > q_pos[..., None] - window
    if kv_len is not None:
        kvl = jnp.asarray(kv_len).reshape(-1, 1, 1)
        mask = mask & (k_pos[None, None, :] < kvl)
    mask = jnp.broadcast_to(mask, (B, Sq, Skv)) if mask.shape[0] == 1 \
        else mask
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    softcap: float | None = None,
    chunk_q: int = _CHUNK_Q,
    chunk_k: int = _CHUNK_K,
) -> jax.Array:
    """Flash-style attention: scan over KV chunks with online softmax.
    Never materializes more than (B,H,chunk_q,chunk_k) scores.  Semantically
    identical to `attention` (tested); compiles to nested while loops whose
    trip counts the roofline analyzer accounts for."""
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    dv = v.shape[-1]
    G = H // k.shape[2]
    k = constrain(repeat_kv(k, G), "dp", None, "tp", None)
    v = constrain(repeat_kv(v, G), "dp", None, "tp", None)
    nq, nk = Sq // chunk_q, Skv // chunk_k
    qs = (q * (dh ** -0.5)).reshape(B, nq, chunk_q, H, dh).swapaxes(0, 1)
    ks = k.reshape(B, nk, chunk_k, H, dh).swapaxes(0, 1)
    vs = v.reshape(B, nk, chunk_k, H, dv).swapaxes(0, 1)
    q_pos0 = jnp.asarray(q_offset)

    def q_chunk_body(_, qi_blk):
        qi, q_blk = qi_blk                              # q_blk: (B,cq,H,dh)
        qp = q_pos0 + qi * chunk_q + jnp.arange(chunk_q)

        def kv_body(carry, ki_blk):
            m, l, acc = carry
            ki, k_blk, v_blk = ki_blk
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32)
            s = constrain(s, "dp", "tp", None, None)
            if softcap is not None:
                s = jnp.tanh(s / softcap) * softcap
            kp = ki * chunk_k + jnp.arange(chunk_k)
            mask = jnp.ones((chunk_q, chunk_k), bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= kp[None, :] > qp[:, None] - window
            if kv_len is not None:
                mask &= kp[None, :] < jnp.asarray(kv_len)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, H, chunk_q), -jnp.inf, jnp.float32),
                jnp.zeros((B, H, chunk_q), jnp.float32),
                jnp.zeros((B, H, chunk_q, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_body), init, (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # (B,H,cq,dv)
        return None, out.swapaxes(1, 2)                 # (B,cq,H,dv)

    _, outs = jax.lax.scan(q_chunk_body, None, (jnp.arange(nq), qs))
    out = outs.swapaxes(0, 1).reshape(B, Sq, H, dv)
    return constrain(out, "dp", None, "tp", None).astype(q.dtype)


def attention_ring_cache(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    pos: jax.Array,
    window: int,
) -> jax.Array:
    """Decode attention against a rolling (ring) KV cache of size `window`.

    q: (B,1,H,dh); caches: (B,window,Hk,dh) written at slot pos % window.
    Entry at ring slot s holds absolute position p(s) such that p ≡ s (mod W)
    and p <= pos. Valid iff p(s) > pos - window and p(s) >= 0.
    """
    B, _, H, dh = q.shape
    W, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    k_cache = constrain(repeat_kv(k_cache, G), "dp", None, "tp", None)
    v_cache = constrain(repeat_kv(v_cache, G), "dp", None, "tp", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q * (dh ** -0.5), k_cache,
                        preferred_element_type=jnp.float32)  # (B,H,1,W)
    scores = constrain(scores, "dp", "tp", None, None)
    slots = jnp.arange(W)
    posa = jnp.asarray(pos).reshape(-1, 1)            # (1|B, 1)
    cur = posa % W
    # absolute position stored in each slot (newest write is at `cur`)
    p = posa - ((cur - slots[None, :]) % W)           # (1|B, W)
    valid = (p >= 0) & (p > posa - window)
    valid = jnp.broadcast_to(valid, (B, W))
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GLU feed-forward
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def ffn_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def ffn(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    g = _ACTS[act](constrain(x @ params["w_gate"], "dp", None, "tp"))
    u = constrain(x @ params["w_up"], "dp", None, "tp")
    return (g * u) @ params["w_down"]


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + core + out-proj)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype) -> Params:
    d, H, Hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_q": dense_init(k1, (d, H * dh), dtype),
        "w_k": dense_init(k2, (d, Hk * dh), dtype),
        "w_v": dense_init(k3, (d, Hk * dh), dtype),
        "w_o": dense_init(k4, (H * dh, d), dtype),
    }


def gqa_project_qkv(params: Params, x: jax.Array, cfg, positions: jax.Array):
    B, S, _ = x.shape
    H, Hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = constrain((x @ params["w_q"]).reshape(B, S, H, dh), "dp", None, "tp", None)
    k = constrain((x @ params["w_k"]).reshape(B, S, Hk, dh), "dp", None, "tp", None)
    v = constrain((x @ params["w_v"]).reshape(B, S, Hk, dh), "dp", None, "tp", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2) block
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype) -> Params:
    d, H, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        # queries are full-rank in V2-Lite (q_lora_rank = 0)
        "w_q": dense_init(ks[0], (d, H * (dh + dr)), dtype),
        "w_dkv": dense_init(ks[1], (d, r), dtype),       # down-proj -> latent
        "w_kr": dense_init(ks[2], (d, dr), dtype),       # shared rope key
        "w_uk": dense_init(ks[3], (r, H * dh), dtype),   # up-proj keys
        "w_uv": dense_init(ks[4], (r, H * dh), dtype),   # up-proj values
        "w_o": dense_init(ks[5], (H * dh, d), dtype),
    }


def mla_latent(params: Params, x: jax.Array, cfg, positions: jax.Array):
    """Compute the compressed KV latent + rope-key for x: returns
    (c_kv: (B,S,r), k_rope: (B,S,1,dr)) — this is exactly what the MLA
    decode cache stores (memory = r + dr per token, not 2*H*dh)."""
    B, S, _ = x.shape
    c_kv = x @ params["w_dkv"]                         # (B,S,r)
    k_rope = (x @ params["w_kr"]).reshape(B, S, 1, cfg.rope_head_dim)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_attend(params: Params, x: jax.Array, c_kv: jax.Array, k_rope: jax.Array,
               cfg, positions: jax.Array, *, kv_len=None, causal=True):
    """MLA attention of queries from x against (possibly cached) latents."""
    B, Sq, _ = x.shape
    Skv = c_kv.shape[1]
    H, dh, dr = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    q = (x @ params["w_q"]).reshape(B, Sq, H, dh + dr)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, Skv, H, dh)
    v = (c_kv @ params["w_uv"]).reshape(B, Skv, H, dh)
    # concat nope+rope per head; rope key is shared (MQA-style) across heads
    k_rope_b = jnp.broadcast_to(k_rope, (B, Skv, 1, dr))
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope_b, (B, Skv, H, dr))], axis=-1)
    q_off = positions[0] if positions.ndim == 1 else 0
    # attention() scales by 1/sqrt(q.shape[-1]) = 1/sqrt(dh+dr): the MLA scale.
    out = attention(qf, kf, v, causal=causal, q_offset=q_off, kv_len=kv_len,
                    force_chunked=getattr(cfg, "attn_force_chunked", False))
    return out.reshape(B, Sq, H * dh) @ params["w_o"]
