"""repro.policies — first-class, pluggable GPU-sharing policies.

The :class:`SharingPolicy` API plus a string-keyed registry
(:func:`register` / :func:`resolve` / :func:`available`).  Importing this
package registers the paper's policies (``online-only`` a.k.a.
``dedicated``, the ``muxflow`` family, ``time-sharing``,
``pb-time-sharing``) and the related-work baselines (``tally-priority``,
``static-partition``).

Adding your own policy (see README "Sharing policies" for the worked
example)::

    from repro.policies import SharingPolicy, register

    class MyPolicy(SharingPolicy):
        name = "my-policy"
        def shared_performance(self, on, off, shares):
            ...

    register(MyPolicy())
    # now: run_policy("my-policy", ...), --policy my-policy, scenarios, ...
"""
from repro.policies.base import (SharingPolicy, available, policy_name,
                                 register, resolve, unregister)
from repro.policies.builtin import (DedicatedPolicy, MuxFlowPolicy,
                                    PriorityTimeSharingPolicy,
                                    TimeSharingPolicy)
from repro.policies.extra import StaticPartitionPolicy, TallyPriorityPolicy
# registered last: the measured policy lives in repro.profiling (it wraps
# the speed-matrix artifact) and only touches repro.policies.base, so the
# import graph stays acyclic in both import orders
from repro.profiling.calibrate import register_measured_policy

MEASURED_MUXFLOW = register_measured_policy()

__all__ = [
    "SharingPolicy", "available", "policy_name", "register", "resolve",
    "unregister", "DedicatedPolicy", "MuxFlowPolicy",
    "PriorityTimeSharingPolicy", "TimeSharingPolicy",
    "StaticPartitionPolicy", "TallyPriorityPolicy", "MEASURED_MUXFLOW",
]
