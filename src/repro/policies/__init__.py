"""repro.policies — first-class, pluggable GPU-sharing policies.

The :class:`SharingPolicy` API plus a string-keyed registry
(:func:`register` / :func:`resolve` / :func:`available`).  Importing this
package registers the paper's policies (``online-only`` a.k.a.
``dedicated``, the ``muxflow`` family, ``time-sharing``,
``pb-time-sharing``) and the related-work baselines (``tally-priority``,
``static-partition``).

Adding your own policy (see README "Sharing policies" for the worked
example)::

    from repro.policies import SharingPolicy, register

    class MyPolicy(SharingPolicy):
        name = "my-policy"
        def shared_performance(self, on, off, shares):
            ...

    register(MyPolicy())
    # now: run_policy("my-policy", ...), --policy my-policy, scenarios, ...
"""
from repro.policies.base import (SharingPolicy, available, policy_name,
                                 register, resolve, unregister)
from repro.policies.builtin import (DedicatedPolicy, MuxFlowPolicy,
                                    PriorityTimeSharingPolicy,
                                    TimeSharingPolicy)
from repro.policies.extra import StaticPartitionPolicy, TallyPriorityPolicy

__all__ = [
    "SharingPolicy", "available", "policy_name", "register", "resolve",
    "unregister", "DedicatedPolicy", "MuxFlowPolicy",
    "PriorityTimeSharingPolicy", "TimeSharingPolicy",
    "StaticPartitionPolicy", "TallyPriorityPolicy",
]
