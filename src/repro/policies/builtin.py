"""The paper's policies as registered :class:`SharingPolicy` implementations.

These reproduce the engine's original string-dispatched behavior exactly —
the fixed-seed parity suite pins each one to the per-device reference
engine, so every formula here mirrors the pre-refactor arithmetic
operation-for-operation.
"""
from __future__ import annotations

import numpy as np

from repro.core.dynamic_sm import dynamic_sm_array, fixed_sm
from repro.core.interference import shared_performance_arrays
from repro.core.scheduler import SchedulerConfig
from repro.policies.base import SharingPolicy, register


class DedicatedPolicy(SharingPolicy):
    """Dedicated GPUs (the paper's Online-only baseline): no sharing at all.

    Offline jobs are never scheduled; every device runs its online workload
    alone at exactly base performance.
    """

    name = "online-only"
    description = ("Dedicated GPUs: offline jobs never run, online serves "
                   "at base performance (the paper's pre-MuxFlow state).")
    wants_scheduling = False

    def sm_shares(self, on, idx):
        return np.zeros(idx.shape, np.float64)

    def shared_performance(self, on, off, shares):
        n = on["gpu_util"].shape[0]
        return np.ones(n), np.zeros(n)


class MuxFlowPolicy(SharingPolicy):
    """MuxFlow space-sharing (§4–§5), parameterized into its ablations.

    The full policy uses dynamic SM allocation (§4.3) and matching-based
    scheduling (§5); turning either off yields the paper's MuxFlow-S
    (fixed 40 % SM share), MuxFlow-M (greedy FIFO instead of KM matching),
    and MuxFlow-S-M variants.  Shared performance is the calibrated
    space-sharing interference model (Fig. 4).
    """

    needs_predictor = True

    def __init__(self, name: str = "muxflow", *, use_dynamic_sm: bool = True,
                 use_matching: bool = True):
        self.name = name
        self.use_dynamic_sm = use_dynamic_sm
        self.use_matching = use_matching
        parts = []
        if not use_dynamic_sm:
            parts.append("fixed 40% SM share (-S)")
        if not use_matching:
            parts.append("greedy FIFO placement (-M)")
        self.description = ("MuxFlow space-sharing: dynamic SM + KM matching."
                            if not parts else
                            "MuxFlow ablation: " + ", ".join(parts) + ".")

    def scheduler_config(self, shard_size: int = 256) -> SchedulerConfig:
        return SchedulerConfig(use_dynamic_sm=self.use_dynamic_sm,
                               use_matching=self.use_matching,
                               shard_size=shard_size)

    def sm_shares(self, on, idx):
        if self.use_dynamic_sm:
            return dynamic_sm_array(on["sm_activity"][idx])
        return np.full(idx.shape, fixed_sm(), np.float64)

    def shared_performance(self, on, off, shares):
        return shared_performance_arrays(on, off, shares)


class TimeSharingPolicy(SharingPolicy):
    """Gandiva-style fair time-sharing: online and offline alternate slices.

    The offline workload holds the GPU roughly half the time, so the online
    workload stalls whenever it arrives during an offline slice — slowdown
    grows with online utilization (up to ~50 % in the paper, Fig. 11).
    """

    name = "time-sharing"
    description = ("Gandiva-style fair time slices: ~0.45x offline "
                   "throughput but online slows with load (up to ~50%).")
    off_duty = 0.5                 # offline's share of wall time

    def shared_performance(self, on, off, shares):
        slow = 1.0 + 0.9 * self.off_duty * np.minimum(1.0,
                                                      on["gpu_util"] * 2.2)
        n = on["gpu_util"].shape[0]
        return slow, np.full(n, self.off_duty * 0.9)


class PriorityTimeSharingPolicy(SharingPolicy):
    """AntMan/PAI-style priority-based time-sharing.

    Online has strict time priority; offline kernels fill only idle *time*,
    so online pays a small fixed context overhead and offline throughput
    tracks online idleness.
    """

    name = "pb-time-sharing"
    description = ("AntMan/PAI-style priority time-sharing: offline fills "
                   "idle time only; small fixed online overhead.")

    def shared_performance(self, on, off, shares):
        n = on["gpu_util"].shape[0]
        idle = np.maximum(0.0, 1.0 - on["gpu_util"])
        return np.full(n, 1.05), idle * 0.8


DEDICATED = register(DedicatedPolicy(), aliases=("dedicated",))
MUXFLOW = register(MuxFlowPolicy())
MUXFLOW_S = register(MuxFlowPolicy("muxflow-s", use_dynamic_sm=False,
                                   use_matching=True))
MUXFLOW_M = register(MuxFlowPolicy("muxflow-m", use_dynamic_sm=True,
                                   use_matching=False))
MUXFLOW_S_M = register(MuxFlowPolicy("muxflow-s-m", use_dynamic_sm=False,
                                     use_matching=False))
TIME_SHARING = register(TimeSharingPolicy())
PB_TIME_SHARING = register(PriorityTimeSharingPolicy())
