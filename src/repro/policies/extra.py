"""New sharing policies from related work, built purely on the policy API.

Neither of these touches the simulator engine — they exist to prove the
:class:`~repro.policies.base.SharingPolicy` API carries its weight: a new
baseline is one registered class, a scenario entry, and a benchmark cell.

* ``tally-priority`` — Tally-style priority task-slicing (PAPERS.md:
  "Tally: Non-Intrusive Performance Isolation for Concurrent DL
  Workloads").  Best-effort kernels are sliced and admitted only in
  priority-gated slack windows, so online interference is near zero by
  construction, at the cost of offline throughput.
* ``static-partition`` — ParvaGPU-style static spatial partitioning
  (PAPERS.md: "ParvaGPU: Efficient Spatial GPU Sharing").  A fixed
  MIG-like SM split hard-isolates the pair: offline gets a constant,
  predictable slice; online suffers only when its instantaneous demand
  spills past its own partition.
"""
from __future__ import annotations

import numpy as np

from repro.core.interference import instantaneous_sm_demand
from repro.policies.base import SharingPolicy, register


def _inst_demand(on: dict[str, np.ndarray]) -> np.ndarray:
    """Online instantaneous SM demand (the interference model's own
    duty-cycle correction)."""
    return instantaneous_sm_demand(on["sm_activity"], on["gpu_util"])


class TallyPriorityPolicy(SharingPolicy):
    """Priority task-slicing: offline work admitted in slack slices only.

    The scheduler slices best-effort kernels into short launch quanta and
    gates each quantum on the online workload's instantaneous occupancy, so
    the online workload almost never waits behind offline work — slowdown
    stays within the slicing instrumentation overhead.  Offline throughput
    is whatever fits in the gated slices: idle time plus the spatial slack
    left during online kernels, discounted by slicing efficiency.
    """

    name = "tally-priority"
    description = ("Tally-style priority task-slicing: near-zero online "
                   "slowdown, offline rides priority-gated slack slices.")
    slice_share = 0.25             # SM quota a slice may occupy (placement)
    overhead = 0.02                # worst-case slowdown from slicing
    idle_eff = 0.70                # slice efficiency in fully idle time
    slack_eff = 0.30               # slice efficiency inside spatial slack

    def sm_shares(self, on, idx):
        return np.full(idx.shape, self.slice_share, np.float64)

    def shared_performance(self, on, off, shares):
        util = on["gpu_util"]
        # instrumentation + gating checks scale with how often online runs
        slow = 1.0 + self.overhead * util
        idle = np.maximum(0.0, 1.0 - util)
        slack = np.maximum(0.0, 1.0 - _inst_demand(on))
        tput = self.idle_eff * idle + self.slack_eff * util * slack
        return slow, np.clip(tput, 0.0, 1.0)


class StaticPartitionPolicy(SharingPolicy):
    """Fixed MIG-like SM split: hard spatial isolation, zero elasticity.

    The device is carved once: ``partition`` of the SMs go to the offline
    tenant, the rest to online.  Isolation means offline throughput is a
    constant fraction of demand (no cross-tenant contention), but the online
    workload is capped at its own partition — when its instantaneous demand
    spills past that cap it queues on its own slice and slows down.
    """

    name = "static-partition"
    description = ("ParvaGPU-style static MIG-like SM split: predictable "
                   "offline slice, online capped at its partition.")
    partition = 0.5                # offline's fixed SM fraction
    isolation_eff = 0.95           # partition/reconfiguration overhead

    def sm_shares(self, on, idx):
        return np.full(idx.shape, self.partition, np.float64)

    def shared_performance(self, on, off, shares):
        on_cap = 1.0 - self.partition
        # online queues on its own slice when demand exceeds the partition
        spill = np.maximum(0.0, _inst_demand(on) - on_cap) / max(on_cap, 1e-6)
        slow = 1.0 + 0.8 * spill * on["gpu_util"]
        used = np.minimum(self.partition, off["sm_activity"])
        tput = self.isolation_eff * used / np.maximum(off["sm_activity"],
                                                      1e-6)
        return slow, np.clip(tput, 0.0, 1.0)


TALLY_PRIORITY = register(TallyPriorityPolicy())
STATIC_PARTITION = register(StaticPartitionPolicy())
