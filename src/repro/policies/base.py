"""The SharingPolicy API and its string-keyed registry.

MuxFlow's evaluation (§7) is a comparison of *GPU-sharing policies* —
dedicated devices, Gandiva-style time-sharing, AntMan/PAI-style
priority-based time-sharing, MuxFlow and its -S/-M ablations.  This module
makes a policy a first-class object instead of a magic string dispatched
inside the simulator engine: each policy says whether it needs the speed
predictor, whether it schedules at all, how matched placement should be
configured, what SM share greedy placement hands out, and how a
sharing pair performs (the engine's per-tick ground truth), all in
vectorized array form.

The engine (:class:`repro.core.simulator.ClusterSim`), the control plane
(:mod:`repro.cluster`), the scenario registry, the CLI, and the figure
benchmarks all resolve policies through :func:`resolve`; adding a policy is
``register(MyPolicy())`` — no engine edits.
"""
from __future__ import annotations

import numpy as np

from repro.core.scheduler import SchedulerConfig


class SharingPolicy:
    """One GPU-sharing policy: scheduling behavior + shared-performance model.

    Subclasses set the class attributes and implement
    :meth:`shared_performance`; everything else has a sensible default.
    Policies are stateless — one instance serves every simulator run — and
    every array method is vectorized over the fleet.

    Attributes:
        name: registry key; also what :class:`SimResults.policy` reports.
        description: one-liner for ``--list-policies`` and docs.
        needs_predictor: True if scheduling requires the §5 speed predictor
            (the engine refuses to run without one).
        wants_scheduling: False for dedicated policies that never place
            offline work (the engine skips scheduling rounds entirely).
    """

    name: str = "unnamed"
    description: str = ""
    needs_predictor: bool = False
    wants_scheduling: bool = True

    # ------------------------------------------------------------ scheduling
    def scheduler_config(self, shard_size: int = 256) -> SchedulerConfig | None:
        """Configuration for the matching scheduler (§5, Algorithm 1).

        Return a :class:`SchedulerConfig` to place jobs through the
        predictor + KM-matching path (only Healthy, memory-feasible devices),
        or None to use greedy FIFO packing onto any alive free device (the
        time-sharing baselines' placement).
        """
        return None

    def sm_shares(self, on: dict[str, np.ndarray],
                  idx: np.ndarray) -> np.ndarray:
        """Offline SM shares handed out at greedy (non-matching) placement.

        ``on`` holds fleet-wide online profile arrays (see
        :func:`repro.core.interference.online_profile_arrays`); ``idx`` are
        the device indices about to receive a job.  Returns one share in
        [0, 1] per entry of ``idx``.  On the matching path the
        :class:`SchedulerConfig` governs shares instead.
        """
        return np.full(idx.shape, 0.5, np.float64)

    def build_predictor(self, gpu_types, *, samples: int = 2000,
                        epochs: int = 120, seed: int = 0):
        """Train the §5 speed predictor this policy schedules with.

        Only consulted when ``needs_predictor`` is True and the caller (the
        control plane, a benchmark) did not supply a predictor.  The default
        trains on the synthetic interference model; measured policies
        (``muxflow-measured``) override this to train on profiled pairs, so
        the predictor's training distribution always matches the policy's
        ground truth.
        """
        from repro.core.predictor import build_speed_predictor
        return build_speed_predictor(gpu_types=tuple(gpu_types), n=samples,
                                     epochs=epochs, seed=seed)

    # ----------------------------------------------------------- performance
    def shared_performance(self, on: dict[str, np.ndarray],
                           off: dict[str, np.ndarray],
                           shares: np.ndarray,
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Per-device (online slowdown, offline normalized throughput).

        ``on``/``off`` are ``[key] -> (n_devices,) array`` mappings of
        online/offline profile fields (``gpu_util``, ``sm_activity``,
        ``sm_occupancy``, ``mem_bw``, ``exec_time_ms``, ``mem_bytes_frac``).
        The engine hands ``off`` in lazily — untouched keys cost nothing —
        and its entries for devices without a job are stale (the engine
        masks afterwards); ``shares`` is the per-device offline SM share.
        Must return two ``(n_devices,)`` arrays with slowdown >= 1.0 and
        throughput in [0, 1] everywhere.
        """
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<{type(self).__name__} {self.name!r}>"


# --------------------------------------------------------------------- registry
_REGISTRY: dict[str, SharingPolicy] = {}


def register(policy: SharingPolicy, *,
             aliases: tuple[str, ...] = ()) -> SharingPolicy:
    """Register ``policy`` under its name (plus ``aliases``); returns it.

    Re-registering a name bound to a *different* policy object raises — the
    registry is the single source of truth for what a name means.  The check
    runs over every key before any is inserted, so a rejected registration
    leaves the registry untouched.
    """
    if not policy.name or policy.name == SharingPolicy.name:
        raise ValueError(
            f"policy {type(policy).__name__} must set a unique `name` class "
            f"attribute before registration (got {policy.name!r})")
    keys = (policy.name, *aliases)
    for key in keys:
        bound = _REGISTRY.get(key)
        if bound is not None and bound is not policy:
            raise ValueError(f"sharing policy name {key!r} already registered "
                             f"to {bound!r}")
    for key in keys:
        _REGISTRY[key] = policy
    return policy


def unregister(name: str) -> None:
    """Remove the policy bound to ``name`` — together with every other key
    (canonical name and aliases) bound to the same object, so
    :func:`available` never advertises a name :func:`resolve` would reject."""
    pol = _REGISTRY.pop(name, None)
    if pol is not None:
        for key in [k for k, v in _REGISTRY.items() if v is pol]:
            del _REGISTRY[key]


def available() -> tuple[str, ...]:
    """Sorted canonical policy names (aliases excluded)."""
    return tuple(sorted({p.name for p in _REGISTRY.values()}))


def resolve(spec: str | SharingPolicy) -> SharingPolicy:
    """A policy instance from a registry name or an instance (passthrough).

    Unknown names raise ``ValueError`` listing every registered policy, so a
    typo'd ``--policy`` flag or config value fails loudly and helpfully.
    """
    if isinstance(spec, SharingPolicy):
        return spec
    try:
        return _REGISTRY[spec]
    except KeyError:
        raise ValueError(
            f"unknown sharing policy {spec!r}; available: "
            f"{', '.join(available())}") from None


def policy_name(spec: str | SharingPolicy) -> str:
    """Canonical name for a policy spec (resolves aliases and instances)."""
    return resolve(spec).name
