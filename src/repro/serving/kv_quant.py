"""int8 KV-cache quantization (serving memory/bandwidth lever).

Decode is bandwidth-bound on the KV cache (EXPERIMENTS.md §Roofline); int8
storage with per-(token, head) scales halves the traffic vs bf16 and
quarters it vs fp32 (KIVI/KVQuant-style, per-token post-RoPE).  Provided as
a standalone utility + quantized decode attention, validated against the
fp32 oracle in tests (attention output error < 1e-2 at int8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_quantize(kv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """kv: (B, S, H, d) -> (int8 values, fp16 scales (B, S, H, 1)).
    Symmetric per-(token, head) absmax scaling."""
    absmax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = (absmax / 127.0 + 1e-12)
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def quantized_cache_bytes(B: int, S: int, H: int, d: int) -> int:
    """int8 values + fp16 scales."""
    return B * S * H * d * 1 + B * S * H * 2


def decode_attention_quantized(q: jax.Array, k_q, k_scale, v_q, v_scale,
                               kv_len) -> jax.Array:
    """Decode attention over an int8-quantized cache.

    q: (B, 1, H, d) fp; k_q/v_q: (B, S, Hk, d) int8 with (B, S, Hk, 1)
    scales.  Dequantizes block-free (the Pallas kernel would dequantize
    per-tile in VMEM; this is the jnp reference path)."""
    B, _, H, d = q.shape
    Skv, Hk = k_q.shape[1], k_q.shape[2]
    G = H // Hk
    k = kv_dequantize(k_q, k_scale)
    v = kv_dequantize(v_q, v_scale)
    qg = q.reshape(B, 1, Hk, G, d).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)
    mask = jnp.arange(Skv)[None, :] < jnp.broadcast_to(
        jnp.asarray(kv_len).reshape(-1, 1), (B, 1))
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, 1, H, d).astype(q.dtype)
