"""Continuous-batching serving engine — the online workload's front-end.

The production shape of the paper's online container: a slot-based decode
engine (vLLM-style continuous batching, fixed-shape for TPU):

  * a fixed pool of B decode slots over one pre-allocated KV cache,
  * every engine step runs ONE fixed-shape `decode_step` over all slots with
    *per-slot positions* (the model's decode path supports ragged positions),
  * new requests are admitted into free slots and their prompts are
    piggy-backed: while a slot is still prefilling, its input token is the
    next prompt token and its logits are discarded; once the prompt is
    consumed the slot switches to generation,
  * finished sequences retire and free their slot immediately.

Fixed shapes mean exactly one compiled program regardless of traffic — which
is what makes MuxFlow's duty-cycle throttling well-behaved on TPU (no
recompilation storms when the multiplexer squeezes offline steps between
engine steps).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache
from repro.models.model import ModelConfig, forward


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int
    arrival: float = 0.0
    output: list = dataclasses.field(default_factory=list)
    done_at: float | None = None


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 8
    kv_capacity: int = 256
    eos_id: int | None = None
    greedy: bool = True


class ServingEngine:
    """Slot-based continuous batching over the model zoo's decode step."""

    def __init__(self, cfg: ModelConfig, params,
                 ecfg: EngineConfig = EngineConfig()):
        assert cfg.frontend == "none" and not cfg.enc_layers, \
            "engine currently serves plain decoder LMs"
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        B = ecfg.num_slots
        self.cache = init_cache(cfg, B, ecfg.kv_capacity)
        self.slot_req: list[ServeRequest | None] = [None] * B
        self.slot_pos = np.zeros(B, np.int32)       # position being written
        self.slot_prompt_left = np.zeros(B, np.int32)
        self.slot_tok = np.zeros((B, 1), np.int32)
        self.waiting: list[ServeRequest] = []
        self.finished: list[ServeRequest] = []
        self.steps = 0
        self._decode = jax.jit(
            lambda p, c, t, pos: forward(p, cfg, {"tokens": t},
                                         mode="decode", cache=c, pos=pos))

    # -- admission ----------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        assert len(req.prompt) >= 1
        assert len(req.prompt) + req.max_new_tokens < self.ecfg.kv_capacity
        self.waiting.append(req)

    def _admit(self) -> None:
        for slot in range(self.ecfg.num_slots):
            if self.slot_req[slot] is not None or not self.waiting:
                continue
            req = self.waiting.pop(0)
            self.slot_req[slot] = req
            self.slot_pos[slot] = 0
            self.slot_prompt_left[slot] = len(req.prompt)
            self.slot_tok[slot, 0] = req.prompt[0]

    # -- stepping -----------------------------------------------------------
    def step(self, now: float = 0.0) -> int:
        """Admit + one fixed-shape decode step.  Returns #active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.slot_tok),
            jnp.asarray(self.slot_pos))
        self.steps += 1
        logits = np.asarray(logits[:, :self.cfg.vocab_size])
        for slot in active:
            req = self.slot_req[slot]
            self.slot_pos[slot] += 1
            if self.slot_prompt_left[slot] > 1:
                # still prefilling: feed the next prompt token, drop logits
                self.slot_prompt_left[slot] -= 1
                idx = len(req.prompt) - int(self.slot_prompt_left[slot])
                self.slot_tok[slot, 0] = req.prompt[idx]
                continue
            self.slot_prompt_left[slot] = 0
            nxt = int(np.argmax(logits[slot]))
            req.output.append(nxt)
            self.slot_tok[slot, 0] = nxt
            done = (len(req.output) >= req.max_new_tokens
                    or (self.ecfg.eos_id is not None
                        and nxt == self.ecfg.eos_id)
                    or self.slot_pos[slot] >= self.ecfg.kv_capacity - 1)
            if done:
                req.done_at = now
                self.finished.append(req)
                self.slot_req[slot] = None
                self.slot_pos[slot] = 0
        return len(active)

    def drain(self, max_steps: int = 100_000) -> None:
        while self.waiting or any(r is not None for r in self.slot_req):
            self.step()
            max_steps -= 1
            if max_steps <= 0:
                raise RuntimeError("engine did not drain")

    @property
    def active_slots(self) -> int:
        return sum(r is not None for r in self.slot_req)
