"""Cluster-scale example: the full MuxFlow control plane on a simulated
GPU cluster — matching-based scheduling, SysMonitor eviction, mixed error
handling, checkpoint/restart — against the paper's baselines, then a full
control-plane scenario (heterogeneous fleet, fault campaign, node agents,
autoscaling) through `repro.cluster`.

  PYTHONPATH=src python examples/cluster_sim.py
"""
from repro.api import (build_speed_predictor, resolve, run_policy_scenario,
                       run_scenario)


def main() -> None:
    print("training speed predictor...")
    pred = build_speed_predictor(gpu_types=("T4", "A10"), n=1200, epochs=50)
    cfg = dict(n_devices=200, horizon_s=8 * 3600.0, tick_s=60.0, trace="C",
               seed=0)
    print("simulating 8h on 200 GPUs, trace C...\n")
    header = (f"{'policy':18s} {'online slow':>11s} {'p99 ms':>8s} "
              f"{'avg JCT':>9s} {'done':>9s} {'oversold':>8s} "
              f"{'util':>5s} {'evict%':>6s} {'err prop':>8s}")
    print(header)
    print("-" * len(header))
    for pol in ("online-only", "muxflow", "muxflow-s", "muxflow-m",
                "muxflow-s-m", "pb-time-sharing", "time-sharing",
                "tally-priority", "static-partition", "muxflow-measured"):
        p = resolve(pol)
        use = None
        if p.needs_predictor:
            # the measured policy trains its own predictor on profiled pairs
            # (SharingPolicy.build_predictor); everything else shares the
            # synthetic one built above
            use = (p.build_predictor(("T4", "A10"), samples=600, epochs=20)
                   if pol == "muxflow-measured" else pred)
        r = run_policy_scenario(pol, use, **cfg)
        print(f"{pol:18s} {r.avg_slowdown:>10.3f}x {r.p99_latency_ms:>8.1f} "
              f"{r.avg_jct_s/60:>7.1f}mn {r.n_finished:>4d}/{r.n_jobs:<4d} "
              f"{r.oversold_gpu:>8.3f} {r.gpu_util:>5.2f} "
              f"{100*r.eviction_frac:>5.1f}% {r.errors_propagated:>3d}/{r.errors_injected:<3d}")
    print("\nMuxFlow: highest oversold GPU at <20% online slowdown, "
          "zero error propagation (graceful exit).")

    print("\nfull control-plane campaign: diurnal-mixed on 200 devices, 4h")
    rep = run_scenario("diurnal-mixed", n_devices=200, hours=4.0, seed=0)
    s, j, f, a = rep["sim"], rep["jobs"], rep["faults"], rep["agents"]
    print(f"  jobs     : {j['completed']}/{j['n_jobs']} done, "
          f"{j['total_preemptions']} preemptions, "
          f"avg queue wait {j['avg_queue_wait_s']:.0f}s, "
          f"lost work {j['total_lost_work_s']:.0f}s")
    print(f"  faults   : {f['injected']} injected, {f['propagated']} "
          f"propagated (rate {f['propagation_rate']:.3f})")
    print(f"  agents   : {a['reports_dropped']} heartbeats dropped, "
          f"{a['stale_episodes']} stale episodes")
    print(f"  autoscale: {rep['autoscaler']['n_decisions']} decisions")
    print(f"  events   : {rep['events']['n_events']} "
          f"(digest {rep['events']['digest'][:12]}...)")
    print(f"  pools    : " + ", ".join(
        f"{p['pool']}={p['n']}" for p in rep["pools"]))


if __name__ == "__main__":
    main()
