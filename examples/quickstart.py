"""Quickstart: the MuxFlow pipeline in one minute.

1. build two workload classes from the model zoo (an online decoder and an
   offline trainer),
2. profile them, train the speed predictor,
3. run Algorithm 1 (dynamic SM + KM matching) to pair offline jobs with
   online-serving devices,
4. print the chosen sharing plan.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import (OFFLINE_MODEL_PROFILES, OfflineJob, OnlineSlot,
                       build_speed_predictor, dynamic_sm, online_profile,
                       schedule)


def main() -> None:
    print("== training the speed predictor (4-layer MLP, momentum SGD) ==")
    predictor = build_speed_predictor(gpu_types=("T4",), n=800, epochs=40)

    rng = np.random.default_rng(0)
    services = ["recommend", "translate", "vision"]
    slots = []
    for i in range(6):
        qps = float(rng.uniform(15, 180))
        prof = online_profile(services[i % 3], qps)
        slots.append(OnlineSlot(i, "T4", prof))
        print(f"  device {i}: {prof.name:10s} qps={qps:5.0f} "
              f"sm_activity={prof.sm_activity:.2f} -> dynamic SM share for "
              f"offline = {dynamic_sm(prof.sm_activity):.1f}")

    jobs = [OfflineJob(j, OFFLINE_MODEL_PROFILES[m], 3600.0)
            for j, m in enumerate(rng.choice(list(OFFLINE_MODEL_PROFILES), 4))]
    print("\n== Algorithm 1: KM matching over predicted normalized throughput ==")
    plan = schedule(slots, jobs, predictor)
    for a in plan:
        job = jobs[[j.job_id for j in jobs].index(a.job_id)]
        print(f"  GPU {a.device_id} <- offline '{job.profile.name}' "
              f"@ SM {a.sm_share:.0%}, predicted tput {a.predicted_tput:.2f}")
    total = sum(a.predicted_tput for a in plan)
    print(f"\n  plan total normalized throughput: {total:.2f}")


if __name__ == "__main__":
    main()
