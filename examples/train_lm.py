"""Offline-workload example: train a small LM for a few hundred steps with
checkpointing, then kill-and-resume to demonstrate the evict/restart path the
MuxFlow scheduler relies on.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro.checkpoint.checkpointing import latest_step
from repro.launch.train import run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="xlstm-350m")
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="muxflow_ckpt_")
    try:
        half = args.steps // 2
        print(f"== phase 1: train to step {half} (then simulate eviction) ==")
        out1 = run(args.arch, smoke=True, steps=half, batch=8, seq=64,
                   lr=3e-3, ckpt_dir=ckpt, ckpt_every=25)
        print(f"   evicted at step {half}, checkpoint at "
              f"step {latest_step(ckpt)}")
        print("== phase 2: restart from checkpoint, finish the job ==")
        out2 = run(args.arch, smoke=True, steps=args.steps, batch=8, seq=64,
                   lr=3e-3, ckpt_dir=ckpt, ckpt_every=25, resume=True)
        print(f"\nloss: start {out1['losses'][0]:.3f} -> "
              f"pre-evict {out1['final_loss']:.3f} -> "
              f"final {out2['final_loss']:.3f}")
        assert out2["final_loss"] < out1["losses"][0]
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
