"""End-to-end driver: serve a small model with batched requests while an
offline training job space-shares the same device under MuxFlow protection.

Real JAX compute on this host: the online workload is `decode_step` of a
reduced h2o-danube (batched requests, Poisson arrivals); the offline workload
is `train_step` of a reduced granite-MoE.  The multiplexer's PID holds the
online latency inside the SLO while harvesting idle quanta for training —
the xCUDA/dynamic-SM mechanism at step granularity.

The §4.2 signal path is demonstrated end-to-end: a GracefulExit harness with
real checkpoint/release callbacks is installed on the multiplexer, and a
timer sends this process an actual SIGINT mid-run — the handler freezes
kernel launches (no more offline microsteps), checkpoints the training
state, and releases resources while the online workload keeps serving.
Ctrl-C exercises the same path by hand.

  PYTHONPATH=src python examples/serve_multiplex.py
"""
import os
import signal
import threading
import time

import jax

from repro.api import ArrivalProcess
from repro.configs import get_config
from repro.core.errors import GracefulExit
from repro.core.multiplexer import Multiplexer, MuxConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import init_cache, init_params, make_decode_step, make_train_step
from repro.optim.optimizer import AdamW, AdamWConfig


def main() -> None:
    key = jax.random.PRNGKey(0)
    # ---- online: danube decode over a standing KV cache
    on_cfg = get_config("h2o-danube-1.8b", smoke=True)
    on_params = init_params(key, on_cfg)
    decode = jax.jit(make_decode_step(on_cfg))
    BATCH, CAP = 8, 128
    cache = init_cache(on_cfg, BATCH, CAP)
    toks = jax.numpy.zeros((BATCH, 1), jax.numpy.int32)
    logits, cache = decode(on_params, cache, toks, 0)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for i in range(1, 9):
        logits, cache = decode(on_params, cache, toks, i)
    jax.block_until_ready(logits)
    base_step = (time.perf_counter() - t0) / 8
    print(f"online decode step (batch {BATCH}): {base_step*1e3:.2f} ms")

    # ---- offline: granite-MoE training
    off_cfg = get_config("granite-moe-1b-a400m", smoke=True)
    opt = AdamW(AdamWConfig(lr=3e-3, total_steps=100_000))
    state = {"p": init_params(jax.random.PRNGKey(1), off_cfg)}
    state["o"] = opt.init(state["p"])
    train = jax.jit(make_train_step(off_cfg, opt), donate_argnums=(0, 1))
    pipe = TokenPipeline(DataConfig(off_cfg.vocab_size, 64, 8))
    state["p"], state["o"], m = train(state["p"], state["o"], pipe.batch_at(0))
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    state["p"], state["o"], m = train(state["p"], state["o"], pipe.batch_at(1))
    jax.block_until_ready(m["loss"])
    off_step = time.perf_counter() - t0
    losses = [float(m["loss"])]
    step_i = [2]
    print(f"offline train microstep: {off_step*1e3:.2f} ms")

    pos = [9]

    def online_fn(bs: int) -> float:
        t = time.perf_counter()
        out, _ = decode(on_params, cache, toks, pos[0] % (CAP - 1))
        jax.block_until_ready(out)
        pos[0] += 1
        return time.perf_counter() - t

    def offline_fn() -> float:
        t = time.perf_counter()
        state["p"], state["o"], m = train(state["p"], state["o"],
                                          pipe.batch_at(step_i[0]))
        jax.block_until_ready(m["loss"])
        losses.append(float(m["loss"]))
        step_i[0] += 1
        return time.perf_counter() - t

    n_req = 150
    # arrival rate sized so the device is ~half-loaded by online traffic;
    # the latency budget absorbs at most one offline microstep of queueing
    # (the paper: latency demands >100ms, a ~10ms share-slowdown is fine).
    # Same seeded ArrivalProcess the sim and profiler consume — one
    # definition of "requests arrive" across the repo.
    process = ArrivalProcess.poisson(
        mean_gap=max(base_step * 2.0, off_step * 1.2), seed=0)
    arrivals = process.first_n(n_req).tolist()
    horizon = arrivals[-1] + 0.5
    budget = base_step * 2 + off_step * 2.5
    print(f"\nserving {n_req} request batches over ~{horizon:.1f}s; "
          f"latency budget {budget*1e3:.0f}ms; offline fills the slack...")
    mux = Multiplexer(online_fn, offline_fn, base_step, off_step,
                      MuxConfig(slo_slowdown=1.25, latency_budget_s=budget))

    # ---- §4.2 graceful exit, wired end-to-end: freeze -> checkpoint ->
    # release, driven by a *real* signal delivered mid-run
    ckpt: dict = {}
    released: list[float] = []

    def on_checkpoint() -> None:
        ckpt["step"] = step_i[0]
        ckpt["loss"] = losses[-1]
        ckpt["params"] = state["p"]          # persisted snapshot stand-in

    def on_release() -> None:
        released.append(time.perf_counter())  # CUDA-context release analogue

    mux.graceful = GracefulExit(throttle=mux.throttle,
                                on_checkpoint=on_checkpoint,
                                on_release=on_release)
    # deliver SIGINT partway through serving (Ctrl-C does the same by hand)
    killer = threading.Timer(horizon * 0.5,
                             lambda: os.kill(os.getpid(), signal.SIGINT))
    killer.daemon = True
    killer.start()
    s = mux.run(arrivals, horizon)
    killer.cancel()
    print(f"\nonline : served={s.served} p50={s.p50_ms:.2f}ms "
          f"p99={s.p99_ms:.2f}ms (base {s.base_ms:.2f}ms)")
    print(f"offline: {s.offline_steps} train steps "
          f"(loss {losses[0]:.3f} -> {losses[-1]:.3f}), "
          f"duty={s.offline_duty:.2f}, oversold={s.oversold:.2f}")
    print(f"safety : evicted={s.evicted}, slo_violations={s.slo_violations}")
    gex = mux.graceful
    if gex.triggered is not None:
        print(f"graceful exit: caught {gex.triggered.value} -> froze kernel "
              f"launches (frozen={mux.throttle.frozen}), checkpointed at "
              f"step {ckpt.get('step')} (loss {ckpt.get('loss', 0.0):.3f}), "
              f"released context ({len(released)} release callback)")
        print("online kept serving after the signal: errors propagated = 0")
    else:
        print("graceful exit: signal did not arrive before the horizon "
              "(run was too short); Ctrl-C exercises the same path")


if __name__ == "__main__":
    main()
