"""On-device multiplexer: SLO protection, quota, graceful exit, eviction."""
import numpy as np
import pytest

from repro.core.multiplexer import Multiplexer, MuxConfig
from repro.core.protection import QuotaExceeded


def make_mux(slo=1.2, couple=0.35, base=0.010, off=0.020, **kw):
    mux_holder = {}

    def online_fn(bs):
        duty = mux_holder["m"].throttle.duty
        return base * (1.0 + couple * duty)

    m = Multiplexer(online_fn, lambda: off, base, off, MuxConfig(slo_slowdown=slo, **kw))
    mux_holder["m"] = m
    return m


def arrivals(qps, n, seed=0):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / qps, n)).tolist()


def test_slo_respected_under_load():
    m = make_mux(slo=1.2, couple=0.5)
    s = m.run(arrivals(40, 600), 20.0)
    assert s.served == 600
    # average online step slowdown stays near the SLO bound
    assert s.p50_ms <= 1.35 * s.base_ms * 2   # incl. queueing slack
    assert s.offline_steps > 0
    assert 0.0 < s.offline_duty < 1.0


def test_more_load_less_offline():
    lo = make_mux().run(arrivals(10, 100), 12.0)
    hi = make_mux().run(arrivals(90, 1080), 12.0)
    assert lo.oversold > hi.oversold


def test_quota_rejects_oversized_offline():
    with pytest.raises(QuotaExceeded):
        Multiplexer(lambda b: 0.01, lambda: 0.02, 0.01, 0.02,
                    MuxConfig(device_bytes=1000, quota_frac=0.4),
                    offline_state_bytes=500)


def test_offline_only_runs_when_idle_budget_allows():
    # zero arrivals: offline free-runs at the PID's initial duty
    m = make_mux()
    s = m.run([], 5.0, max_offline_steps=10)
    assert s.offline_steps == 10
    assert s.served == 0


def test_eviction_on_persistent_violation():
    # online step always 5x base: PID can't save it -> SysMonitor-style evict
    m = Multiplexer(lambda b: 0.05, lambda: 0.02, 0.01, 0.02,
                    MuxConfig(slo_slowdown=1.2, evict_after_violations=10))
    s = m.run(arrivals(50, 300), 10.0)
    assert s.evicted
