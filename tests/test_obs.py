"""Observability plane tests: registry contracts, canonical exporters,
end-to-end byte-identity (same seed, across processes' worth of runs, and
across tick engines), report neutrality when obs is enabled, and the
phase profiler's exclusion arithmetic."""
import hashlib
import json

import pytest

from repro.cluster.control import run_scenario
from repro.cluster.run import check_schema
from repro.obs import (METRICS_SCHEMA, OBS_SCHEMA, TRACE_SCHEMA, JsonlWriter,
                       MetricsRegistry, ObsConfig, PhaseProfiler,
                       canonical_json, lint_prometheus, prometheus_text)
from repro.obs.export import rfloat

TINY = dict(n_devices=24, hours=0.5, seed=0)


def _obs(tmp_path, tag="", **kw):
    return ObsConfig(metrics_out=str(tmp_path / f"metrics{tag}.jsonl"),
                     trace_out=str(tmp_path / f"trace{tag}.jsonl"),
                     prom_out=str(tmp_path / f"metrics{tag}.prom"), **kw)


# ---------------------------------------------------------------- registry
def test_registry_counter_gauge_histogram_basics():
    r = MetricsRegistry()
    c = r.counter("jobs_total", "jobs", labels=("pool",))
    c.labels(pool="a100").inc()
    c.labels(pool="a100").inc(2.0)
    c.labels(pool="t4").inc()
    assert c.labels(pool="a100").value == 3.0
    g = r.gauge("depth")
    g.set(7.5)
    assert g._solo().value == 7.5
    h = r.histogram("lat", buckets=(1.0, 2.0))
    for v in (0.5, 1.5, 99.0):
        h.observe(v)
    solo = h._solo()
    assert solo.count == 3 and solo.bucket_counts == [1, 1]
    assert solo.sum == pytest.approx(101.0)
    assert r.n_series == 4


def test_registry_rejects_bad_names_and_kind_drift():
    r = MetricsRegistry()
    with pytest.raises(ValueError):
        r.counter("bad name")
    with pytest.raises(ValueError):
        r.counter("ok", labels=("bad-label",))
    r.counter("x_total")
    with pytest.raises(ValueError):
        r.gauge("x_total")                       # kind drift
    with pytest.raises(ValueError):
        r.counter("x_total", labels=("pool",))   # label drift
    assert r.counter("x_total") is r.counter("x_total")  # re-register OK


def test_counter_rejects_negative_and_labels_must_match():
    r = MetricsRegistry()
    c = r.counter("n_total", labels=("pool",))
    with pytest.raises(ValueError):
        c.labels(pool="x").inc(-1.0)
    with pytest.raises(ValueError):
        c.labels(wrong="x")
    with pytest.raises(ValueError):
        r.histogram("h", buckets=(2.0, 1.0))     # unsorted buckets


# ------------------------------------------------------------- canonical JSON
def test_canonical_json_sorted_rounded_and_rejects_nonfinite():
    line = canonical_json({"b": 1.0 / 3.0, "a": 1, "c": [True, -0.0]})
    assert line == '{"a":1,"b":0.333333333,"c":[true,0.0]}'
    with pytest.raises(ValueError):
        canonical_json({"x": float("nan")})
    with pytest.raises(ValueError):
        canonical_json({"x": float("inf")})


def test_rfloat_matches_canon_and_flat_writes_match_slow_path(tmp_path):
    # the write_flat fast path must produce the same bytes as write()
    row = {"t": 1.23456789012345, "n": 3, "s": "x", "none": None,
           "neg": -0.0, "data": {"a": 2.0 / 3.0}}
    pre = {k: (rfloat(v) if not isinstance(v, dict)
               else {kk: rfloat(vv) for kk, vv in v.items()})
           for k, v in row.items()}
    w1, w2 = JsonlWriter(str(tmp_path / "a")), JsonlWriter(str(tmp_path / "b"))
    w1.write(row)
    w2.write_flat(pre)
    w1.close(), w2.close()
    assert (tmp_path / "a").read_bytes() == (tmp_path / "b").read_bytes()
    assert w1.digest() == w2.digest()


def test_jsonl_writer_digest_matches_file_bytes(tmp_path):
    p = tmp_path / "rows.jsonl"
    w = JsonlWriter(str(p))
    for i in range(5):
        w.write({"i": i, "v": i * 0.1})
    w.close()
    assert w.rows == 5
    assert w.digest() == hashlib.sha256(p.read_bytes()).hexdigest()
    sink = JsonlWriter(None)                     # digest-only sink
    sink.write({"i": 0, "v": 0.0})
    assert sink.rows == 1 and len(sink.digest()) == 64


# ------------------------------------------------------------- prometheus
def test_prometheus_text_renders_and_lints_clean():
    r = MetricsRegistry()
    r.counter("jobs_total", "jobs run", labels=("pool",)).labels(
        pool="a100").inc(3)
    r.gauge("util", "gpu util").set(0.5)
    h = r.histogram("slow", "slowdown", buckets=(1.0, 2.0))
    for v in (0.5, 1.5, 9.0):
        h.observe(v)
    text = prometheus_text(r)
    assert '# TYPE jobs_total counter' in text
    assert 'jobs_total{pool="a100"} 3.0' in text
    assert 'slow_bucket{le="+Inf"} 3' in text
    assert lint_prometheus(text) == []


def test_prometheus_lint_catches_breakage():
    assert lint_prometheus("no_type_metric 1.0\n")
    assert lint_prometheus("# TYPE x gauge\nx nope\n")
    assert lint_prometheus("# TYPE x wrongkind\n")
    broken_hist = ("# TYPE h histogram\n"
                   'h_bucket{le="1.0"} 5\nh_bucket{le="2.0"} 3\n'
                   'h_bucket{le="+Inf"} 5\nh_sum 1.0\nh_count 5\n')
    assert any("non-monotonic" in p for p in lint_prometheus(broken_hist))
    no_inf = "# TYPE h histogram\nh_sum 1.0\nh_count 5\n"
    assert any("+Inf" in p for p in lint_prometheus(no_inf))


# ------------------------------------------------------------- end to end
@pytest.fixture(scope="module")
def obs_run(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("obs")
    rep = run_scenario("smoke", obs=_obs(tmp), **TINY)
    return tmp, rep


def test_same_seed_byte_identical_exports(obs_run, tmp_path):
    tmp1, rep1 = obs_run
    rep2 = run_scenario("smoke", obs=_obs(tmp_path), **TINY)
    for name in ("metrics.jsonl", "trace.jsonl", "metrics.prom"):
        assert (tmp1 / name).read_bytes() == (tmp_path / name).read_bytes()
    assert json.dumps(rep1, sort_keys=True) == json.dumps(rep2,
                                                          sort_keys=True)


def test_exports_byte_identical_across_engines(tmp_path):
    run_scenario("smoke", obs=_obs(tmp_path, "_np"), engine="numpy", **TINY)
    run_scenario("smoke", obs=_obs(tmp_path, "_xla"), engine="xla", **TINY)
    for name in ("metrics", "trace"):
        a = (tmp_path / f"{name}_np.jsonl").read_bytes()
        b = (tmp_path / f"{name}_xla.jsonl").read_bytes()
        assert a == b, f"{name} diverged across engines"
    assert ((tmp_path / "metrics_np.prom").read_bytes()
            == (tmp_path / "metrics_xla.prom").read_bytes())


def test_obs_summary_digests_match_files_and_schema_v3(obs_run):
    tmp, rep = obs_run
    assert check_schema(rep) == []
    obs = rep["obs"]
    assert obs["schema"] == OBS_SCHEMA
    assert obs["metrics"]["schema"] == METRICS_SCHEMA
    assert obs["trace"]["schema"] == TRACE_SCHEMA
    for section, name in (("metrics", "metrics.jsonl"),
                          ("trace", "trace.jsonl")):
        digest = hashlib.sha256((tmp / name).read_bytes()).hexdigest()
        assert obs[section]["digest"] == digest
    prom_digest = hashlib.sha256(
        (tmp / "metrics.prom").read_bytes()).hexdigest()
    assert obs["metrics"]["prom_digest"] == prom_digest
    assert lint_prometheus((tmp / "metrics.prom").read_text()) == []


def test_obs_is_neutral_to_the_report(obs_run):
    _, rep_on = obs_run
    rep_off = run_scenario("smoke", **TINY)
    on = {k: v for k, v in rep_on.items() if k != "obs"}
    off = {k: v for k, v in rep_off.items() if k != "obs"}
    assert json.dumps(on, sort_keys=True) == json.dumps(off, sort_keys=True)
    assert rep_off["obs"] is None


def test_metrics_rows_content(obs_run):
    tmp, rep = obs_run
    rows = [json.loads(line) for line in
            (tmp / "metrics.jsonl").read_text().splitlines()]
    header, samples = rows[0], rows[1:]
    assert header["kind"] == "header"
    assert header["schema"] == METRICS_SCHEMA
    assert header["n_devices"] == TINY["n_devices"]
    assert samples and all(r["kind"] == "sample" for r in samples)
    fracs = [r for r in samples if r["name"].endswith("_frac")]
    assert fracs and all(0.0 <= r["value"] <= 1.0 for r in fracs)
    hist = [r for r in samples if r["name"] == "tick_online_slowdown"]
    assert hist and all(r["count"] == sum(r["buckets"]) or
                        r["count"] >= sum(r["buckets"]) for r in hist)
    assert rep["obs"]["metrics"]["windows"] >= 1
    # counters are run-cumulative: the last window's total is the run total
    # (every placement segment emits one job_start)
    started = [r for r in samples if r["name"] == "jobs_started_total"]
    assert started[-1]["value"] == rep["jobs"]["total_placements"]


def test_trace_rows_content(obs_run):
    tmp, rep = obs_run
    rows = [json.loads(line) for line in
            (tmp / "trace.jsonl").read_text().splitlines()]
    assert rows[0] == {"kind": "header", "schema": TRACE_SCHEMA}
    spans = [r for r in rows if r["kind"] == "job_span"]
    for s in spans:
        assert s["end"] in ("finish", "evict", "open")
        if s["queue_wait_s"] is not None:
            assert s["queue_wait_s"] >= 0.0
        if s["end"] == "finish":
            assert s["t_end"] >= s["t_start"]
    kinds = rep["obs"]["trace"]["kinds"]
    assert sum(kinds.values()) + 1 == rep["obs"]["trace"]["rows"]  # + header


def test_metrics_every_changes_window_count(tmp_path):
    obs_fast = ObsConfig(metrics_out=str(tmp_path / "fast.jsonl"),
                         metrics_every_s=60.0)
    obs_slow = ObsConfig(metrics_out=str(tmp_path / "slow.jsonl"),
                         metrics_every_s=1800.0)
    r_fast = run_scenario("smoke", obs=obs_fast, **TINY)
    r_slow = run_scenario("smoke", obs=obs_slow, **TINY)
    assert (r_fast["obs"]["metrics"]["windows"]
            > r_slow["obs"]["metrics"]["windows"])


# --------------------------------------------------------------- profiler
def test_phase_profiler_excludes_nested_phase():
    clock = iter(range(100))
    prof = PhaseProfiler(clock=lambda: float(next(clock)))
    with prof.phase("account", exclude=("serving",)):   # enters at 0
        with prof.phase("serving"):                     # 1 .. 2  (1s)
            pass
    # account exits at 3: saw 3s wall minus the 1s of nested serving = 2s
    s = prof.summary()
    assert s["phases"]["serving"]["wall_s"] == pytest.approx(1.0)
    assert s["phases"]["account"]["wall_s"] == pytest.approx(2.0)
    assert s["phases"]["account"]["calls"] == 1
    assert s["total_s"] == pytest.approx(3.0)
    assert "account" in prof.format_table()


def test_profile_phases_never_lands_in_report(tmp_path, capsys):
    obs = ObsConfig(metrics_out=str(tmp_path / "m.jsonl"),
                    profile_phases=True)
    rep = run_scenario("smoke", obs=obs, **TINY)
    assert rep["obs"]["profile_phases"] is True
    blob = json.dumps(rep)
    assert "wall_s" not in blob     # phase walls quarantined from artifacts
    rep_off = run_scenario("smoke", **TINY)
    on = {k: v for k, v in rep.items() if k != "obs"}
    off = {k: v for k, v in rep_off.items() if k != "obs"}
    assert json.dumps(on, sort_keys=True) == json.dumps(off, sort_keys=True)
