"""SysMonitor state machine: transitions, eviction, exponential re-admission."""
from _hyp import given, settings, st

from repro.core.protection import DeviceTelemetry
from repro.core.sysmonitor import GPUState, SysMonitor


def tele(t, util=0.3, sm=0.2, clock=1500.0, mem=0.4, temp=60.0):
    return DeviceTelemetry(ts=t, gpu_util=util, sm_activity=sm, sm_clock=clock,
                           mem_used_frac=mem, temp_c=temp)


def warmed(now=10.0):
    m = SysMonitor(now=0.0)
    m.update(tele(now), now)
    assert m.state == GPUState.HEALTHY
    return m


def test_init_to_healthy():
    m = SysMonitor(now=0.0)
    s, ev = m.update(tele(1.0), 1.0)
    assert s == GPUState.INIT
    s, ev = m.update(tele(6.0), 6.0)
    assert s == GPUState.HEALTHY and "schedulable" in ev


def test_unhealthy_and_back():
    m = warmed()
    s, ev = m.update(tele(11, util=0.95), 11)
    assert s == GPUState.UNHEALTHY and "unschedulable" in ev
    assert not m.schedulable
    s, ev = m.update(tele(12), 12)
    assert s == GPUState.HEALTHY and m.schedulable


def test_overlimit_evicts_and_backs_off():
    m = warmed()
    s, ev = m.update(tele(11, mem=0.99), 11)
    assert s == GPUState.OVERLIMIT and "evict" in ev
    # healthy metrics but must wait the re-admission period
    s, _ = m.update(tele(12), 12)
    assert s == GPUState.OVERLIMIT
    s, _ = m.update(tele(12 + 61), 12 + 61)
    assert s == GPUState.UNHEALTHY
    s, _ = m.update(tele(12 + 62), 12 + 62)
    assert s == GPUState.HEALTHY


def test_readmission_grows_exponentially():
    m = warmed()
    t = 11.0
    waits = []
    for _ in range(3):
        m.update(tele(t, mem=0.99), t)
        assert m.state == GPUState.OVERLIMIT
        t += 1
        t0 = t
        while m.state == GPUState.OVERLIMIT and t - t0 < 10_000:
            m.update(tele(t), t)
            t += 5
        waits.append(t - t0)
        m.update(tele(t), t)       # back to healthy
        t += 1
    assert waits[1] > waits[0] and waits[2] > waits[1]


def test_healthy_to_overlimit_direct():
    m = warmed()
    s, ev = m.update(tele(11, clock=800.0), 11)
    assert s == GPUState.OVERLIMIT and "evict" in ev


def test_disabled_is_terminal():
    m = warmed()
    m.disable()
    s, _ = m.update(tele(20), 20)
    assert s == GPUState.DISABLED and not m.schedulable


@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.floats(0, 1),
                          st.floats(700, 1600), st.floats(0, 1)),
                min_size=1, max_size=40))
def test_invariants_random_walk(samples):
    """Whatever the telemetry, (a) schedulable only in HEALTHY, (b) every
    OVERLIMIT entry emits exactly one evict event."""
    m = SysMonitor(now=0.0)
    m.update(tele(10.0), 10.0)
    t = 11.0
    evicts = 0
    entries = 0
    prev = m.state
    for util, sm, clock, mem in samples:
        s, ev = m.update(tele(t, util=util, sm=sm, clock=clock, mem=mem), t)
        evicts += ev.count("evict")
        if s == GPUState.OVERLIMIT and prev != GPUState.OVERLIMIT:
            entries += 1
        assert m.schedulable == (s == GPUState.HEALTHY)
        prev = s
        t += 1.0
    assert evicts == entries


def test_vector_monitor_matches_scalar_fleet():
    """VectorSysMonitor replicates the scalar state machine device-for-device
    over a random telemetry walk (including devices skipping samples, as the
    simulator does for failed hardware)."""
    import numpy as np

    from repro.core.sysmonitor import VectorSysMonitor

    n, steps, dt = 24, 400, 30.0
    rng = np.random.default_rng(42)
    scalars = [SysMonitor(now=0.0) for _ in range(n)]
    vec = VectorSysMonitor(n, now=0.0)
    for k in range(steps):
        now = k * dt
        util = rng.uniform(0.5, 1.0, n)
        sm = rng.uniform(0.4, 1.0, n)
        mem = rng.uniform(0.5, 1.0, n)
        clock = rng.uniform(850.0, 1600.0, n)
        temp = rng.uniform(60.0, 95.0, n)
        active = rng.random(n) > 0.1
        level = vec.classify(util, sm, mem, clock, temp)
        evict_vec = vec.update(level, now, active)
        for i in range(n):
            if not active[i]:
                continue
            m = tele(now, util=util[i], sm=sm[i], clock=clock[i], mem=mem[i],
                     temp=temp[i])
            state, events = scalars[i].update(m, now)
            assert vec.states()[i] == state, (k, i)
            assert bool(evict_vec[i]) == ("evict" in events), (k, i)
        assert all(bool(vec.schedulable[i]) == scalars[i].schedulable
                   for i in range(n))


# ---------------------------------------------------------------------------
# VectorSysMonitor edges: ring-buffer wraparound, disable vs transitions
# ---------------------------------------------------------------------------
import numpy as np

from repro.core.sysmonitor import (S_DISABLED, S_HEALTHY, S_OVERLIMIT,
                                   S_UNHEALTHY, VectorSysMonitor)


def test_wait_periods_at_ring_wraparound():
    m = VectorSysMonitor(1, ring=4)
    dev = np.array([0])
    for t in (0.0, 100.0, 200.0, 300.0, 400.0, 500.0):   # 6 pushes, ring=4
        m.push_overlimit(dev, t)
    # only the retained 4 entries (200..500) count: e = 4-1 -> 60 * 2**3
    assert m.wait_periods(dev, 600.0)[0] == 480.0
    # the two overwritten entries (0, 100) must NOT resurface once the
    # window slides past the retained ones
    assert m.wait_periods(dev, 7500.0)[0] == 240.0       # 300,400,500 left
    assert m.wait_periods(dev, 7800.0)[0] == 60.0        # window empty


def test_wait_periods_honours_readmit_cap():
    m = VectorSysMonitor(1, ring=16)
    dev = np.array([0])
    for _ in range(8):                                    # e=7 -> 7680 s raw
        m.push_overlimit(dev, 1000.0)
    assert m.wait_periods(dev, 1000.0)[0] == m.cfg.readmit_cap_s


def test_disable_vs_schedulable_under_concurrent_transitions():
    m = VectorSysMonitor(4)
    lvl0 = np.zeros(4, np.int8)
    m.update(lvl0, 10.0)                                  # INIT -> HEALTHY
    assert (m.state == S_HEALTHY).all()
    m.disable(np.array([1]))
    assert m.schedulable.tolist() == [True, False, True, True]
    # one tick where every non-disabled device transitions at once
    evict = m.update(np.array([2, 2, 1, 0], np.int8), 20.0)
    assert evict.tolist() == [True, False, False, False]  # disabled: no evict
    assert m.state.tolist() == [S_OVERLIMIT, S_DISABLED, S_UNHEALTHY,
                                S_HEALTHY]
    assert m.schedulable.tolist() == [False, False, False, True]
    # disabled is terminal: healthy levels never resurrect device 1
    m.update(lvl0, 30.0)
    assert m.state[1] == S_DISABLED and not m.schedulable[1]
    assert m.state[2] == S_HEALTHY                        # others recover
