"""xCUDA analogue: GPU-load law (Eq. 1–2), PID stability, quota ledger,
injectable-clock determinism."""
import pytest
from _hyp import given, settings, st

from repro.core.protection import (ClockFactorConfig, GPUMonitor,
                                   KernelThrottle, MemoryQuota, PIDConfig,
                                   PIDController, QuotaExceeded, VirtualClock,
                                   WallClock, clock_factor, gpu_load)


def test_clock_factor_piecewise():
    cfg = ClockFactorConfig(t_sm=1350, c_high=1590, a_l=4.0, a_h=0.5)
    # at threshold: a_C = 1 both sides (Eq. 2 is continuous)
    assert clock_factor(1350.0, cfg) == pytest.approx(1.0)
    # below threshold: boost, slope a_L
    assert clock_factor(675.0, cfg) == pytest.approx(1 + 4.0 * 0.5)
    # above: damp, slope a_H
    assert clock_factor(1590.0, cfg) == pytest.approx(1 - 0.5)
    # a_L >> a_H: the low-clock response dominates
    assert (clock_factor(1250.0, cfg) - 1) > (1 - clock_factor(1450.0, cfg))


@settings(max_examples=50, deadline=None)
@given(st.floats(0, 1), st.floats(700, 1600))
def test_gpu_load_monotone_in_usm(u_sm, c_sm):
    a = clock_factor(c_sm)
    assert gpu_load(u_sm, a) == pytest.approx(u_sm * a)
    assert gpu_load(u_sm, a) >= 0


def test_pid_converges_to_setpoint():
    """Closed loop: measured load = 0.2 + 0.8 * duty.  PID must settle the
    duty so the load tracks the 0.85 setpoint."""
    pid = PIDController(PIDConfig(setpoint=0.85), initial=0.1)
    duty = 0.1
    for _ in range(200):
        load = 0.2 + 0.8 * duty
        duty = pid.update(load, dt=1.0)
    assert 0.2 + 0.8 * duty == pytest.approx(0.85, abs=0.02)


def test_pid_output_bounded():
    pid = PIDController(PIDConfig(setpoint=0.5, out_min=0.0, out_max=1.0))
    for load in [0.0, 2.0, -1.0, 5.0, 0.0, 0.0]:
        out = pid.update(load)
        assert 0.0 <= out <= 1.0


def test_quota_enforced():
    q = MemoryQuota(device_bytes=100, quota_frac=0.4)
    h = q.alloc(30)
    assert q.used == 30
    with pytest.raises(QuotaExceeded):
        q.alloc(11)
    q.free(h)
    assert q.used == 0
    q.alloc(40)   # exactly the quota is fine


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 30), min_size=1, max_size=50))
def test_quota_never_exceeded_property(sizes):
    q = MemoryQuota(device_bytes=1000, quota_frac=0.4)
    handles = []
    for s in sizes:
        if q.would_fit(s):
            handles.append(q.alloc(s))
        else:
            with pytest.raises(QuotaExceeded):
                q.alloc(s)
        assert q.used <= q.quota_bytes
        if len(handles) > 3:
            q.free(handles.pop(0))
            assert q.used >= 0


def test_throttle_duty_credit():
    th = KernelThrottle()
    th.duty = 0.5
    launches = sum(th.should_launch(1.0) for _ in range(100))
    assert 45 <= launches <= 55
    th.freeze()
    assert not th.should_launch(1.0)


def test_throttle_responds_to_clock_drop():
    th = KernelThrottle()
    for _ in range(50):
        th.observe(u_sm=0.5, c_sm=1500.0)
    duty_ok = th.duty
    for _ in range(50):
        th.observe(u_sm=0.5, c_sm=1000.0)   # depressed clock -> load spikes
    assert th.duty < duty_ok


def test_throttle_defaults_to_wall_clock():
    assert isinstance(KernelThrottle().clock, WallClock)


def test_observe_now_virtual_clock_deterministic():
    """The PID/duty loop never reads wall time: with a VirtualClock the whole
    duty trajectory is an exact function of the telemetry sequence."""
    def trajectory():
        clock = VirtualClock()
        th = KernelThrottle(clock=clock)
        duties = []
        for i in range(40):
            clock.advance(0.25)
            c_sm = 1500.0 if i < 20 else 1000.0
            duties.append(th.observe_now(u_sm=0.5, c_sm=c_sm))
        return duties

    a, b = trajectory(), trajectory()
    assert a == b
    # first observation uses dt=1.0; later ones the clock delta (0.25 s)
    assert a[0] != pytest.approx(a[1]) or a[1] != pytest.approx(a[2])


def test_observe_now_coalesces_bursty_samples():
    """Near-simultaneous observations must not feed the PID an explosive
    dt (derivative = error delta / dt): sub-millisecond samples are dropped
    and the duty is unchanged."""
    clock = VirtualClock()
    th = KernelThrottle(PIDController(PIDConfig(kd=0.5)), clock=clock)
    clock.advance(1.0)
    th.observe_now(u_sm=0.5, c_sm=1500.0)
    duty = th.duty
    clock.advance(1e-7)                      # telemetry burst
    assert th.observe_now(u_sm=0.9, c_sm=1000.0) == duty
    assert th.duty == duty
    clock.advance(1.0)                       # normal cadence resumes
    th.observe_now(u_sm=0.9, c_sm=1000.0)
    assert 0.0 <= th.duty <= 1.0 and th.duty != duty


def test_gpu_monitor_sample_stamps_with_injected_clock():
    clock = VirtualClock(start=100.0)
    mon = GPUMonitor(horizon_s=10.0, clock=clock)
    s1 = mon.sample(gpu_util=0.5, sm_activity=0.3, sm_clock=1500.0,
                    mem_used_frac=0.4)
    assert s1.ts == 100.0
    clock.advance(15.0)   # beyond the horizon: first sample must be dropped
    mon.sample(gpu_util=0.6, sm_activity=0.4, sm_clock=1400.0,
               mem_used_frac=0.5)
    assert [s.ts for s in mon.samples] == [115.0]
    assert mon.latest().gpu_util == 0.6
