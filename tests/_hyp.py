"""Optional-`hypothesis` shim for the property-based tests.

`hypothesis` is a dev-only dependency (declared in the ``dev`` extra).  When
it is installed the real API is re-exported unchanged; when it is missing the
property tests are skipped with a clear reason while the plain tests in the
same modules keep running.

Usage (instead of ``from hypothesis import given, settings, strategies as st``):

    from _hyp import HAVE_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without dev deps
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install .[dev])")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Builds inert placeholders so module-level strategy definitions
        (e.g. ``st.sampled_from(...)``) import cleanly."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
