"""Continuous-batching engine: outputs must equal sequential whole-prompt
generation, under ragged admission and slot reuse."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import greedy_generate, init_params
from repro.serving.engine import EngineConfig, ServeRequest, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", smoke=True),
                              dtype=jnp.float32, window=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def ref_generate(cfg, params, prompt, n_new):
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    toks = greedy_generate(cfg, params, batch, steps=max(n_new - 1, 0))
    return [int(t) for t in np.asarray(toks[0])][:n_new]


def test_single_request_matches_sequential(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    eng = ServingEngine(cfg, params, EngineConfig(num_slots=4, kv_capacity=64))
    eng.submit(ServeRequest(0, prompt, max_new_tokens=6))
    eng.drain()
    assert len(eng.finished) == 1
    want = ref_generate(cfg, params, prompt, 6)
    assert eng.finished[0].output == want


def test_ragged_batch_matches_sequential(setup):
    """Multiple requests with different prompt lengths admitted together —
    per-slot positions keep every sequence independent."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size,
                                         int(rng.integers(2, 9))).astype(np.int32),
                         max_new_tokens=int(rng.integers(2, 6)))
            for i in range(6)]
    eng = ServingEngine(cfg, params, EngineConfig(num_slots=3, kv_capacity=64))
    for r in reqs:
        eng.submit(r)
    eng.drain()
    assert len(eng.finished) == 6
    for r in reqs:
        want = ref_generate(cfg, params, r.prompt, r.max_new_tokens)
        assert r.output == want, f"request {r.request_id}"


def test_slot_reuse_and_fixed_shape(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    eng = ServingEngine(cfg, params, EngineConfig(num_slots=2, kv_capacity=64))
    for i in range(5):
        eng.submit(ServeRequest(i, rng.integers(0, cfg.vocab_size, 3)
                                .astype(np.int32), max_new_tokens=3))
    eng.drain()
    assert len(eng.finished) == 5
    # one compiled program: decode was jitted once; steps bounded
    assert eng.steps < 5 * (3 + 3) + 10
