"""Per-arch smoke tests + model-consistency properties.

Every assigned architecture: reduced config instantiates, runs one forward +
one train step on CPU, output shapes as expected, no NaNs.  Consistency:
prefill-then-decode equals full teacher forcing; chunked SSM forms equal
their sequential oracles.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import init_cache, init_params, loss_fn
from repro.models import ssm as S
from repro.models.model import forward
from repro.optim.optimizer import AdamW, AdamWConfig
from repro.models.steps import make_train_step


def make_batch(cfg, key, B, S_len):
    batch = {"tokens": jax.random.randint(key, (B, S_len), 0, cfg.vocab_size)}
    if cfg.frontend == "audio":
        batch["src_embeds"] = jax.random.normal(key, (B, S_len, cfg.d_model), cfg.dtype)
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), cfg.dtype)
        batch["tokens"] = batch["tokens"][:, :S_len - cfg.num_patches]
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n == cfg.param_count(), "analytic param count drifted"
    B, S_len = 2, 32
    batch = make_batch(cfg, key, B, S_len)
    logits, aux = forward(params, cfg, batch, mode="train")
    exp_len = S_len if cfg.frontend != "audio" else S_len
    assert logits.shape == (B, S_len, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # one full train step
    opt = AdamW(AdamWConfig(lr=1e-3, total_steps=10))
    step = make_train_step(cfg, opt)
    params2, _, metrics = jax.jit(step)(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).max()),
                     params, params2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode(logits at pos P) == train forward(logits at pos P)."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=jnp.float32,
                              moe_capacity_factor=64.0)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, total = 2, 24
    batch = make_batch(cfg, key, B, total)
    logits_full, _ = forward(params, cfg, batch, mode="train")
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    lp, cache, _ = forward(params, cfg, pre, mode="prefill")
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_full[:, -2]),
                               atol=3e-5, rtol=3e-5)
    cache_d = init_cache(cfg, B, total, src_len=(total if cfg.enc_layers else 0))
    merged = []
    for ci in range(len(cfg.pattern)):
        dd = dict(cache_d[ci])
        for k, v in cache[ci].items():
            if k in ("k", "v", "ckv", "kr", "xk", "xv") and v.shape[2] != dd[k].shape[2]:
                dd[k] = jax.lax.dynamic_update_slice(dd[k], v, (0,) * v.ndim)
            else:
                dd[k] = v
        merged.append(dd)
    ld, _ = forward(params, cfg, {"tokens": batch["tokens"][:, -1:]},
                    mode="decode", cache=tuple(merged), pos=total - 1)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(logits_full[:, -1]),
                               atol=5e-5, rtol=5e-5)


def test_mamba_chunked_equals_sequential():
    cfg = dataclasses.replace(get_config("jamba-1.5-large-398b", smoke=True),
                              dtype=jnp.float32)
    p = S.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, _ = S.mamba_mixer(p, x, cfg)
    y_ref = S.mamba_mixer_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


def test_mlstm_chunked_equals_sequential():
    cfg = dataclasses.replace(get_config("xlstm-350m", smoke=True),
                              dtype=jnp.float32)
    p = S.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, _ = S.mlstm_mixer(p, x, cfg)
    y_ref = S.mlstm_mixer_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-5, rtol=5e-5)


def test_moe_grouped_equals_dense_without_drops():
    import repro.models.moe as M
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b", smoke=True),
                              dtype=jnp.float32)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    yd, auxd = M.moe_dense_dispatch(p, x, cfg)
    yg, auxg = M.moe_grouped_dispatch(p, x, cfg, capacity_factor=100.0)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg), atol=2e-5)
    assert float(auxd) == pytest.approx(float(auxg))


def test_sliding_window_restricts_attention():
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 16, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 16, 2, 8))
    full = L.attention(q, k, v, causal=True)
    win = L.attention(q, k, v, causal=True, window=4)
    assert not np.allclose(np.asarray(full[:, -1]), np.asarray(win[:, -1]))
    # prefix shorter than the window is unaffected
    np.testing.assert_allclose(np.asarray(full[:, :4]), np.asarray(win[:, :4]),
                               atol=1e-6)


def test_loss_decreases_in_short_training():
    from repro.launch.train import run
    out = run("granite-moe-1b-a400m", smoke=True, steps=25, batch=4, seq=32,
              lr=5e-3)
    assert out["losses"][-1] < out["losses"][0] * 0.8
