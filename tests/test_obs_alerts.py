"""Alerting-plane tests: rule registry contracts, the alert state machine,
burn-rate slow-window gating, threshold monotonicity, and the end-to-end
determinism contract (smoke stays incident-free; fault-storm opens
incidents; incidents.jsonl is byte-identical across same-seed runs and
across tick engines; the report/v5 "incidents" section validates)."""
import json

import pytest

from repro.cluster.control import check_schema, run_scenario
from repro.cluster.scenario import scenario_by_name
from repro.obs import (ALERTS_SCHEMA, AlertEngine, AlertRule, JsonlWriter,
                       ObsConfig, alert_rules_available, default_alert_rules,
                       incidents_open_at, read_incidents,
                       register_alert_rule, resolve_alert_rules)


def _engine(rules, window_s=600.0):
    return AlertEngine(JsonlWriter(None), rules, window_s=window_s)


def _fleet(series, rule, eng):
    for i, v in enumerate(series):
        eng.on_window(600.0 * (i + 1), {"fleet": {rule.signal: v}})


# ---------------------------------------------------------------- registry
def test_rule_validation_rejects_bad_fields():
    with pytest.raises(ValueError):
        AlertRule("x", signal="s", scope="galaxy", threshold=1.0)
    with pytest.raises(ValueError):
        AlertRule("x", signal="s", scope="fleet", threshold=1.0,
                  severity="whisper")
    with pytest.raises(ValueError):
        AlertRule("x", signal="s", scope="fleet", threshold=1.0,
                  kind="vibes")
    with pytest.raises(ValueError):
        AlertRule("x", signal="s", scope="fleet", threshold=1.0,
                  for_windows=0)


def test_registry_rejects_duplicates_and_unknown_names():
    assert "error-rate" in alert_rules_available()
    with pytest.raises(ValueError, match="already registered"):
        register_alert_rule(AlertRule(
            "error-rate", signal="s", scope="fleet", threshold=1.0))
    with pytest.raises(ValueError, match="unknown alert rule"):
        resolve_alert_rules(["no-such-rule"])
    sub = resolve_alert_rules(["online-slowdown", "error-rate"])
    assert [r.name for r in sub] == ["error-rate", "online-slowdown"]


def test_default_catalog_sorted_and_engine_rejects_dup_rules():
    names = [r.name for r in default_alert_rules()]
    assert names == sorted(names)
    r = AlertRule("dup", signal="s", scope="fleet", threshold=1.0)
    with pytest.raises(ValueError, match="duplicate"):
        _engine((r, r))


# ----------------------------------------------------------- state machine
def test_lifecycle_pending_firing_resolved():
    rule = AlertRule("r", signal="s", scope="fleet", threshold=10.0,
                     for_windows=2, clear_windows=2)
    eng = _engine((rule,))
    _fleet([5, 20, 30, 20, 5, 5, 5], rule, eng)
    assert len(eng.incidents) == 1
    inc = eng.incidents[0]
    # pending at the first breach, firing (incident opens) at the second
    assert inc.opened_t == 600.0 * 3
    # two clean windows resolve it
    assert inc.resolved_t == 600.0 * 6
    assert inc.windows == 3 and inc.peak == 30.0
    assert inc.target == "fleet" and eng.open_count() == 0
    # transitions: pending, firing, resolved
    assert eng.transitions == 3 and eng.breach_windows == 3


def test_single_clean_window_does_not_resolve_with_clear_2():
    rule = AlertRule("r", signal="s", scope="fleet", threshold=10.0,
                     clear_windows=2)
    eng = _engine((rule,))
    _fleet([20, 5, 20, 5, 5], rule, eng)
    # the lone clean window between breaches never resolves the incident
    assert len(eng.incidents) == 1
    assert eng.incidents[0].resolved_t == 600.0 * 5


def test_pending_run_shorter_than_for_windows_never_fires():
    rule = AlertRule("r", signal="s", scope="fleet", threshold=10.0,
                     for_windows=3)
    eng = _engine((rule,))
    _fleet([20, 20, 5, 20, 20, 5], rule, eng)
    assert eng.incidents == [] and eng.breach_windows == 4


def test_burn_rate_requires_slow_window_mean():
    rule = AlertRule("r", signal="burn", scope="service", threshold=10.0,
                     kind="burn_rate", slow_windows=3, slow_threshold=5.0)
    eng = _engine((rule,))
    # spike with a cold trailing mean: (0 + 0 + 15)/3 = 5.0, not > 5.0
    for i, v in enumerate([0.0, 0.0, 15.0]):
        eng.on_window(600.0 * (i + 1), {"service": {"svc": {"burn": v}}})
    assert eng.incidents == [] and eng.breach_windows == 0
    # sustained burn pushes the mean over the gate -> fires
    eng.on_window(600.0 * 4, {"service": {"svc": {"burn": 15.0}}})
    assert len(eng.incidents) == 1
    assert eng.incidents[0].target == "svc"


def test_targets_discovered_per_pool_and_sorted():
    rule = AlertRule("r", signal="s", scope="pool", threshold=10.0)
    eng = _engine((rule,))
    eng.on_window(600.0, {"pool": {"b": {"s": 20.0}, "a": {"s": 30.0}}})
    assert [i.target for i in eng.incidents] == ["a", "b"]


def test_incident_open_at_half_open_interval():
    rule = AlertRule("r", signal="s", scope="fleet", threshold=10.0)
    eng = _engine((rule,))
    _fleet([20, 5], rule, eng)
    inc = eng.incidents[0]
    assert inc.open_at(600.0) and inc.open_at(900.0)
    assert not inc.open_at(599.0) and not inc.open_at(1200.0)
    assert incidents_open_at([inc], 700.0) == [inc]


# ------------------------------------------------------------ monotonicity
def test_breach_windows_monotone_in_threshold():
    """Strict `>` breaching: raising the threshold can only shrink the set
    of breaching windows (the incident *count* is not monotone — a higher
    threshold can split one long incident into two — so the property pins
    breach_windows)."""
    series = [0.0, 3.0, 7.0, 7.0, 2.0, 9.0, 9.0, 9.0, 1.0, 5.0, 8.0, 0.0]
    prev = None
    for threshold in (0.0, 2.0, 4.0, 6.0, 8.0, 10.0):
        rule = AlertRule("r", signal="s", scope="fleet",
                         threshold=threshold, for_windows=2)
        eng = _engine((rule,))
        _fleet(series, rule, eng)
        if prev is not None:
            assert eng.breach_windows <= prev
        prev = eng.breach_windows
    assert prev == 0  # threshold above the series -> no breaches at all


# ------------------------------------------------------------- end to end
def _run(tmp_path, tag, scenario, *, engine=None, rules=(), **overrides):
    out = tmp_path / f"incidents{tag}.jsonl"
    report = run_scenario(
        scenario_by_name(scenario), engine=engine,
        obs=ObsConfig(alerts_out=str(out), alert_rules=rules,
                      metrics_every_s=600.0),
        **overrides)
    return report, out.read_bytes()


def test_smoke_seed0_is_incident_free(tmp_path):
    """The quiet CI scenario stays clean: background agent churn and the
    tiny error budget never cross the tuned default thresholds."""
    report, _ = _run(tmp_path, "s", "smoke", seed=0)
    inc = report["incidents"]
    assert inc["total"] == 0 and inc["open_end"] == 0
    assert inc["windows"] > 0


def test_fault_storm_opens_incidents_and_is_byte_identical(tmp_path):
    report1, raw1 = _run(tmp_path, "1", "fault-storm", seed=0, hours=3.0)
    _report2, raw2 = _run(tmp_path, "2", "fault-storm", seed=0, hours=3.0)
    assert raw1 == raw2
    inc = report1["incidents"]
    assert inc["total"] >= 1
    assert inc["by_rule"]  # attributed to at least one named rule
    # the stream digest in the report matches the file bytes
    import hashlib
    assert hashlib.sha256(raw1).hexdigest() == inc["digest"]
    # the persisted timeline reads back (canonical rounding on both sides)
    from repro.obs import canonical_json
    timeline = read_incidents(str(tmp_path / "incidents1.jsonl"))
    assert (canonical_json([i.row() for i in timeline])
            == canonical_json(inc["timeline"]))


def test_incidents_byte_identical_across_engines(tmp_path):
    _, raw_np = _run(tmp_path, "n", "fault-storm", seed=0, hours=2.0,
                     engine="numpy")
    _, raw_xla = _run(tmp_path, "x", "fault-storm", seed=0, hours=2.0,
                      engine="xla")
    assert raw_np == raw_xla


def test_report_v5_schema_with_and_without_alerts(tmp_path):
    report, _ = _run(tmp_path, "v", "smoke", seed=0)
    assert report["schema"].endswith("/v5")
    assert report["incidents"]["schema"] == ALERTS_SCHEMA
    assert check_schema(report) == []
    plain = run_scenario(scenario_by_name("smoke"), seed=0)
    assert plain["incidents"] is None
    assert check_schema(plain) == []


def test_rule_subset_only_evaluates_named_rules(tmp_path):
    report, raw = _run(tmp_path, "sub", "fault-storm", seed=0, hours=3.0,
                       rules=("error-rate",))
    inc = report["incidents"]
    assert inc["rules"] == ["error-rate"]
    assert set(inc["by_rule"]) <= {"error-rate"}
    header = json.loads(raw.splitlines()[0])
    assert header["rules"] == ["error-rate"]


def test_alerting_never_changes_metrics_bytes(tmp_path):
    """Signal extraction rides the accumulators: metrics output is
    byte-identical whether or not the alert engine is attached."""
    sc = scenario_by_name("smoke")
    for tag, alerts in (("off", None), ("on", str(tmp_path / "inc.jsonl"))):
        run_scenario(sc, seed=0, obs=ObsConfig(
            metrics_out=str(tmp_path / f"m{tag}.jsonl"), alerts_out=alerts,
            metrics_every_s=600.0))
    assert ((tmp_path / "moff.jsonl").read_bytes()
            == (tmp_path / "mon.jsonl").read_bytes())


def test_window_delta_gauges_sum_to_cumulative_totals(tmp_path):
    """The per-window delta gauges (satellite fix: counters were
    run-cumulative only) must sum back to the run totals."""
    out = tmp_path / "metrics.jsonl"
    report = run_scenario(
        scenario_by_name("fault-storm"), seed=0, hours=2.0,
        obs=ObsConfig(metrics_out=str(out), metrics_every_s=600.0))
    sums = {}
    finals = {}
    for line in out.read_text().splitlines():
        row = json.loads(line)
        if row.get("kind") != "sample":
            continue
        name = row["name"]
        if name.endswith("_window") and not name.startswith("serving"):
            sums[name] = sums.get(name, 0.0) + row["value"]
        elif name.endswith("_total"):
            finals[name] = row["value"]  # last sample = cumulative end
    for win_name, total_name in (
            ("errors_injected_window", "errors_injected_total"),
            ("jobs_started_window", "jobs_started_total"),
            ("jobs_finished_window", "jobs_finished_total"),
            ("jobs_evicted_window", "jobs_evicted_total"),
            ("online_incidents_window", "online_incidents_total")):
        assert sums.get(win_name, 0.0) == finals.get(total_name, 0.0), \
            win_name
    assert sums["errors_injected_window"] == report["sim"]["errors_injected"]
