"""Serving-plane tests: ArrivalProcess properties, admission control, the
serving report section's byte-determinism, and the unified CLI seams."""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.cluster.control import check_schema, run_scenario
from repro.cluster.scenario import scenario_by_name
from repro.serving_plane import (ARRIVAL_KINDS, ArrivalProcess,
                                 DeadlineAdmission, NoAdmission,
                                 ServingConfig, admission_available,
                                 resolve_admission)
from repro.serving_plane.arrivals import expected_count

# ---------------------------------------------------------------------------
# ArrivalProcess properties
# ---------------------------------------------------------------------------


def test_poisson_times_matches_legacy_harness_stream():
    # the exact inline formula profiling/harness.py historically used —
    # ArrivalProcess.poisson(mean_gap=...) must reproduce it bit-for-bit
    seed, wl_seed, on_cost, horizon, target_util = 3, 17, 7, 5000, 0.5
    rng = np.random.default_rng(np.random.SeedSequence([seed, wl_seed]))
    mean_gap = on_cost / max(target_util, 0.05)
    gaps = rng.exponential(mean_gap, size=max(int(2 * horizon / mean_gap), 8))
    legacy = np.cumsum(gaps)
    legacy = legacy[legacy < horizon]
    proc = ArrivalProcess.poisson(mean_gap=mean_gap, seed=[seed, wl_seed])
    got = proc.times(horizon)
    assert got.shape == legacy.shape
    assert (got == legacy).all()


def test_first_n_matches_legacy_serve_multiplex_stream():
    mean_gap = 0.0321
    legacy = np.cumsum(np.random.default_rng(
        np.random.SeedSequence(0)).exponential(mean_gap, 150))
    got = ArrivalProcess.poisson(mean_gap=mean_gap, seed=0).first_n(150)
    assert (got == legacy).all()


@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_seed_determinism_within_process(kind):
    def build():
        if kind == "poisson":
            return ArrivalProcess.poisson(2.0, seed=5)
        if kind == "diurnal":
            return ArrivalProcess.diurnal(
                lambda t: 2.0 + np.sin(t / 50.0), seed=5)
        if kind == "burst":
            return ArrivalProcess.burst(2.0, mult=3.0, period_s=100.0,
                                        burst_len_s=20.0, seed=5)
        return ArrivalProcess.trace_replay(np.arange(0.0, 100.0, 0.5))

    a, b = build(), build()
    assert (a.times(200.0) == b.times(200.0)).all()
    ca = [a.counts_at(t, 1.0) for t in range(100)]
    cb = [b.counts_at(t, 1.0) for t in range(100)]
    assert ca == cb
    a.reset()
    assert ca == [a.counts_at(t, 1.0) for t in range(100)]


def test_seed_determinism_across_processes():
    # the SeedSequence contract: no builtin hash() anywhere in the stream,
    # so a fresh interpreter reproduces the identical bytes
    code = (
        "import hashlib, numpy as np\n"
        "from repro.serving_plane import ArrivalProcess\n"
        "p = ArrivalProcess.burst(3.0, mult=2.5, period_s=60.0,"
        " burst_len_s=10.0, seed=[1, 2])\n"
        "h = hashlib.sha256(p.times(500.0).tobytes())\n"
        "h.update(bytes(p.counts_at(t, 1.0) % 256 for t in range(200)))\n"
        "print(h.hexdigest())\n")
    outs = {subprocess.run([sys.executable, "-c", code], check=True,
                           capture_output=True, text=True).stdout
            for _ in range(2)}
    assert len(outs) == 1


def test_diurnal_rate_parity_with_qps_bank():
    # from_qps_bank's rate() must be *definitionally* the sim's QPS curve
    from repro.core.traces import OnlineQPS, QPSBank
    rng = np.random.default_rng(0)
    bank = QPSBank([OnlineQPS(rng) for _ in range(12)])
    mask = np.arange(12) % 3 == 0
    proc = ArrivalProcess.from_qps_bank(bank, mask=mask, scale=0.25, seed=1)
    for t in (0.0, 777.0, 43200.0, 86399.0):
        assert proc.rate(t) == 0.25 * float(bank.qps(t)[mask].sum())


@pytest.mark.parametrize("kind", ["poisson", "diurnal", "burst"])
def test_rate_conservation(kind):
    # times() and counts_at() must both realize E[N] = integral of rate
    if kind == "poisson":
        proc = ArrivalProcess.poisson(4.0, seed=9)
    elif kind == "diurnal":
        proc = ArrivalProcess.diurnal(
            lambda t: 4.0 + 2.0 * np.sin(t / 200.0), seed=9)
    else:
        proc = ArrivalProcess.burst(4.0, mult=3.0, period_s=500.0,
                                    burst_len_s=100.0, seed=9)
    horizon = 4000.0
    expect = expected_count(proc, horizon, dt=1.0)
    n_times = proc.times(horizon).size
    proc.reset()
    n_counts = sum(proc.counts_at(float(t), 1.0) for t in range(int(horizon)))
    # ~16k arrivals: 5% tolerance is > 6 sigma, deterministic under the seed
    assert abs(n_times - expect) / expect < 0.05
    assert abs(n_counts - expect) / expect < 0.05


def test_trace_replay_counts_partition_the_trace():
    times = np.sort(np.random.default_rng(3).uniform(0, 100.0, 500))
    proc = ArrivalProcess.trace_replay(times)
    total = sum(proc.counts_at(float(t), 5.0) for t in range(0, 100, 5))
    assert total == 500
    assert (proc.times(50.0) == times[times < 50.0]).all()


def test_arrival_validation():
    with pytest.raises(ValueError):
        ArrivalProcess.poisson()                      # neither rate nor gap
    with pytest.raises(ValueError):
        ArrivalProcess.poisson(2.0, mean_gap=0.5)     # both
    with pytest.raises(ValueError):
        ArrivalProcess.poisson(-1.0)
    with pytest.raises(ValueError):
        ArrivalProcess("weibull")


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


def test_admission_registry():
    assert set(admission_available()) >= {"none", "deadline"}
    assert isinstance(resolve_admission("none", slack=9.0), NoAdmission)
    pol = resolve_admission("deadline", slack=0.5)
    assert isinstance(pol, DeadlineAdmission) and pol.slack == 0.5
    assert resolve_admission(pol) is pol
    with pytest.raises(ValueError):
        resolve_admission("nope")
    with pytest.raises(ValueError):
        DeadlineAdmission(slack=0.0)


def test_deadline_sheds_only_past_deadline():
    pol = DeadlineAdmission(slack=1.0)
    ages = np.array([0.0, 0.1, 0.5, 2.0])
    counts = np.array([10, 10, 10, 10])
    shed = pol.shed(0.0, ages, counts, slo_s=0.6, service_s=0.1,
                    capacity_rps=100.0)
    # deadline = 0.6 - 0.1 = 0.5; only the 2.0s-old cohort is doomed
    assert shed.tolist() == [0, 0, 0, 10]
    none = NoAdmission().shed(0.0, ages, counts, slo_s=0.6, service_s=0.1,
                              capacity_rps=100.0)
    assert none.tolist() == [0, 0, 0, 0]


def _serving_report(load, *, admission="deadline", seed=0):
    sc = scenario_by_name("serving-slo")
    serving = ServingConfig(arrivals="diurnal", load=load,
                            request_size_sigma=0.8, admission=admission)
    return run_scenario(sc, n_devices=24, hours=0.5, seed=seed,
                        serving=serving)


def test_zero_shed_at_low_load_and_monotone_in_load():
    lo = _serving_report(0.05)["serving"]
    hi = _serving_report(1.3)["serving"]
    assert lo["total"]["shed"] == 0
    assert lo["total"]["slo_attainment"] == 1.0
    assert hi["total"]["shed"] > lo["total"]["shed"]
    assert hi["total"]["slo_attainment"] < lo["total"]["slo_attainment"]
    # per-service sections carry the required columns
    for row in hi["services"].values():
        for k in ("p50_ms", "p99_ms", "slo_ms", "slo_attainment",
                  "shed", "arrived", "served"):
            assert k in row
        assert row["p99_ms"] >= row["p50_ms"] > 0


# ---------------------------------------------------------------------------
# Serving report determinism
# ---------------------------------------------------------------------------


def test_serving_report_deterministic_and_engine_invariant():
    kw = dict(n_devices=24, hours=0.5, seed=1)
    a = run_scenario("serving-slo", **kw)
    b = run_scenario("serving-slo", **kw)
    x = run_scenario("serving-slo", engine="xla", **kw)
    ja, jb, jx = (json.dumps(r, sort_keys=True) for r in (a, b, x))
    assert ja == jb            # same seed, same process -> same bytes
    assert ja == jx            # numpy and xla engines -> same bytes
    assert check_schema(a) == []
    serving = a["serving"]
    assert serving["schema"] == "repro.serving/v1"
    assert set(serving["services"]) == {"recommend", "translate", "vision"}
    tot = serving["total"]
    assert tot["arrived"] == (tot["served"] + tot["shed"]
                              + tot["queued_end"])


def test_non_serving_scenarios_report_null_section():
    rep = run_scenario("smoke", n_devices=16, hours=0.5, seed=0)
    assert rep["serving"] is None
    assert check_schema(rep) == []


def test_check_schema_flags_missing_serving_columns():
    rep = run_scenario("serving-slo", n_devices=16, hours=0.5, seed=0)
    del rep["serving"]["services"]["vision"]["p99_ms"]
    assert any("p99_ms" in p for p in check_schema(rep))
    rep["serving"]["schema"] = "bogus"
    assert any("serving.schema" in p for p in check_schema(rep))


# ---------------------------------------------------------------------------
# Unified CLI + legacy delegates
# ---------------------------------------------------------------------------


def _run_cli(args):
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True)


def test_new_cli_and_legacy_delegate_emit_identical_bytes():
    flags = ["--scenario", "smoke", "--devices", "16", "--hours", "0.5",
             "--seed", "0"]
    new = _run_cli(["-m", "repro", "sim", *flags])
    old = _run_cli(["-m", "repro.cluster.run", *flags])
    assert new.returncode == 0 and old.returncode == 0
    assert new.stdout == old.stdout            # byte-identical artifact
    assert "deprecated" in old.stderr          # note on stderr only
    assert "deprecated" not in new.stderr


def test_cli_dispatcher_usage_and_unknown_command():
    assert "commands:" in _run_cli(["-m", "repro", "--help"]).stdout
    bad = _run_cli(["-m", "repro", "frobnicate"])
    assert bad.returncode == 2
    assert "unknown command" in bad.stderr


def test_bench_delegate_reexports_suite_tables():
    import benchmarks.run as br
    from repro.cli import BENCH_JSON_SUITES, BENCH_SUITES
    assert br.SUITES is BENCH_SUITES
    assert br.JSON_SUITES is BENCH_JSON_SUITES


# ---------------------------------------------------------------------------
# Public API surface
# ---------------------------------------------------------------------------


def test_api_surface_exports_resolve():
    import repro.api as api
    for name in api.__all__:
        assert getattr(api, name) is not None, name
    # the curated surface covers the ISSUE-named entry points
    for name in ("build_sim_config", "run_policy_scenario", "SharingPolicy",
                 "register", "resolve", "ArrivalProcess", "SCENARIOS",
                 "scenario_by_name"):
        assert name in api.__all__
