"""repro.profiling subsystem: executed catalog, harness determinism, the
speed-matrix artifact contract, measured calibration, and the predictor
feature-contract property tests (satellite of ISSUE 4)."""
import dataclasses
import json

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.interference import (OFFLINE_MODEL_PROFILES, WorkloadProfile,
                                     online_profile, online_profile_arrays)
from repro.core.predictor import FEATURE_RANGES, N_FEATURES, pair_features
from repro.core.traces import SERVICES
from repro.profiling import (MeasuredInterferenceProvider, SpeedMatrix,
                             build_catalog, build_measured_predictor,
                             build_speed_matrix, catalog_by_role,
                             check_schema, default_matrix, execute,
                             make_measured_dataset, predict_share_curve,
                             workload_profile)
from repro.profiling.run import main as profiling_main


@pytest.fixture(scope="module")
def matrix():
    return default_matrix("smoke")


@pytest.fixture(scope="module")
def measured_predictor(matrix):
    # A100 included: the calibrated scenario's heterogeneous fleet needs it
    return build_measured_predictor(matrix, gpu_types=("T4", "A10", "A100"),
                                    n=150, epochs=5, seed=0)


# ------------------------------------------------------------------ catalog
def test_catalog_roles_and_costs():
    cat = build_catalog()
    onlines, offlines = catalog_by_role(cat)
    assert {w.name for w in onlines} == {"flash-prefill", "decode-serve"}
    assert {w.name for w in offlines} == {"ssm-scan", "lm-train-step"}
    for w in cat.values():
        assert w.cost_s() > 0
        p = w.profile()
        assert 0 < p.sm_activity <= 1 and 0 < p.mem_bw <= 1
        assert 0 <= p.mem_bytes_frac <= 1


def test_execute_runs_real_steps():
    cat = build_catalog()
    rec = execute(cat["ssm-scan"])
    assert rec.steps_executed == cat["ssm-scan"].steps
    assert np.isfinite(rec.checksum) and rec.checksum != 0.0
    assert rec.wall_ms_per_step > 0
    # execution is deterministic: same seed, same checksum
    assert execute(cat["ssm-scan"]).checksum == rec.checksum


# ------------------------------------------------------------------ harness
def test_matrix_bit_reproducible(matrix):
    again = build_speed_matrix("smoke", seed=0)
    assert again.to_json() == matrix.to_json()


def test_matrix_schema_valid(matrix):
    assert check_schema(matrix.data) == []


def test_matrix_covers_full_pair_grid(matrix):
    onlines, offlines = catalog_by_role()
    for on in onlines:
        for off in offlines:
            pair = matrix.pair(on.name, off.name)
            assert pair["shares"] == sorted(pair["shares"])
            assert all(s >= 1.0 for s in pair["online_slowdown"])
            assert all(0.0 <= t <= 1.0 for t in pair["offline_tput"])
            # more SM share never slows the offline partner down
            assert pair["offline_tput"] == sorted(pair["offline_tput"])


def test_matrix_artifact_excludes_wall_time(matrix):
    assert "wall" not in matrix.to_json()


def test_schema_catches_corruption(matrix):
    data = json.loads(matrix.to_json())
    bad = dict(data, schema="nope/v0")
    assert any("schema" in p for p in check_schema(bad))
    bad = json.loads(matrix.to_json())
    bad["pairs"][0]["offline_tput"][0] = 1.7
    assert any("offline_tput" in p for p in check_schema(bad))
    bad = json.loads(matrix.to_json())
    del bad["workloads"][bad["pairs"][0]["online"]]
    assert check_schema(bad)


def test_matrix_save_load_roundtrip(matrix, tmp_path):
    path = tmp_path / "m.json"
    matrix.save(str(path))
    loaded = SpeedMatrix.load(str(path))
    assert loaded.data == json.loads(matrix.to_json())
    assert profiling_main(["--check-schema", str(path)]) == 0


def test_cli_list():
    assert profiling_main(["--list"]) == 0


# ------------------------------------------------------------- calibration
def test_provider_is_drop_in_for_array_provider(matrix):
    """Same call shape as interference.shared_performance_arrays, sane
    output contract for a whole simulated fleet."""
    provider = MeasuredInterferenceProvider(matrix)
    n = 64
    rng = np.random.default_rng(0)
    service_idx = np.arange(n) % len(SERVICES)
    on = online_profile_arrays(service_idx, rng.uniform(5, 150, n),
                               tuple(SERVICES))
    models = tuple(OFFLINE_MODEL_PROFILES)
    prof = [OFFLINE_MODEL_PROFILES[m] for m in models]
    idx = rng.integers(len(models), size=n)
    off = {k: np.array([getattr(p, k) for p in prof])[idx]
           for k in ("gpu_util", "sm_activity", "sm_occupancy", "mem_bw",
                     "exec_time_ms", "mem_bytes_frac")}
    shares = rng.uniform(0, 1, n)
    slow, tput = provider(on, off, shares)
    assert slow.shape == tput.shape == (n,)
    assert (slow >= 1.0).all()
    assert ((tput >= 0.0) & (tput <= 1.0)).all()
    # the alias used at drop-in call sites is the same function
    s2, t2 = provider.shared_performance_arrays(on, off, shares)
    np.testing.assert_array_equal(slow, s2)
    np.testing.assert_array_equal(tput, t2)


def test_provider_exact_on_measured_points(matrix):
    """Feeding a measured pair's own profiles at a measured share returns
    the matrix cell exactly."""
    provider = MeasuredInterferenceProvider(matrix)
    pair = matrix.pair("decode-serve", "lm-train-step")
    on_p = workload_profile(matrix, "decode-serve")
    off_p = workload_profile(matrix, "lm-train-step")
    keys = ("gpu_util", "sm_activity", "sm_occupancy", "mem_bw",
            "exec_time_ms", "mem_bytes_frac")
    on = {k: np.array([getattr(on_p, k)]) for k in keys}
    off = {k: np.array([getattr(off_p, k)]) for k in keys}
    for i, s in enumerate(pair["shares"]):
        slow, tput = provider(on, off, np.array([s]))
        assert slow[0] == pytest.approx(pair["online_slowdown"][i])
        assert tput[0] == pytest.approx(pair["offline_tput"][i])


def test_measured_dataset_shapes_and_ranges(matrix):
    feats, targets = make_measured_dataset(
        matrix, np.random.default_rng(3), n=64)
    assert feats.shape == (64, N_FEATURES)
    assert targets.shape == (64,)
    assert ((targets >= 0) & (targets <= 1)).all()
    lo, hi = FEATURE_RANGES[:, 0], FEATURE_RANGES[:, 1]
    assert (feats >= lo - 1e-6).all() and (feats <= hi + 1e-6).all()


def test_measured_policy_end_to_end(matrix, measured_predictor):
    from repro.core.simulator import run_policy
    from repro.policies import resolve
    pol = resolve("muxflow-measured")
    assert pol is resolve("calibrated-muxflow")
    assert pol.needs_predictor
    res = run_policy("muxflow-measured", predictor=measured_predictor,
                     n_devices=32, horizon_s=1800.0, trace="C", seed=3)
    assert res.policy == "muxflow-measured"
    assert res.avg_slowdown >= 1.0
    assert 0.0 <= res.avg_norm_tput <= 1.0


def test_calibrated_scenario_report(measured_predictor):
    from repro.cluster import run_scenario
    from repro.cluster.run import check_schema as report_schema
    rep = run_scenario("calibrated", predictor=measured_predictor,
                       n_devices=24, hours=0.5, seed=1)
    assert report_schema(rep) == []
    assert rep["sim"]["policy"] == "muxflow-measured"


def test_policy_build_predictor_seam(matrix):
    """SharingPolicy.build_predictor: the measured policy trains on
    measurements; the base default trains on the synthetic model."""
    from repro.policies import resolve
    pred = resolve("muxflow-measured").build_predictor(
        ("T4",), samples=80, epochs=2, seed=0)
    assert set(pred.params_by_type) == {"T4"}
    pred = resolve("time-sharing").build_predictor(
        ("T4",), samples=80, epochs=2, seed=0)
    assert set(pred.params_by_type) == {"T4"}


def test_measured_policy_tracks_env_var_matrix(matrix, tmp_path,
                                               monkeypatch):
    """The registry singleton must not pin a stale matrix: setting or
    clearing REPRO_SPEED_MATRIX between runs swaps the calibration source."""
    from repro.policies import resolve
    pol = resolve("muxflow-measured")
    monkeypatch.delenv("REPRO_SPEED_MATRIX", raising=False)
    assert pol.matrix.data == matrix.data
    provider_default = pol.provider
    path = tmp_path / "alt.json"
    alt = json.loads(matrix.to_json())
    alt["seed"] = 999
    path.write_text(json.dumps(alt, sort_keys=True))
    monkeypatch.setenv("REPRO_SPEED_MATRIX", str(path))
    assert pol.matrix.data["seed"] == 999
    assert pol.provider is not provider_default
    monkeypatch.delenv("REPRO_SPEED_MATRIX")
    assert pol.matrix.data == matrix.data
    # an explicitly supplied matrix is pinned — env var does not override
    pinned = type(pol)(matrix=matrix)
    monkeypatch.setenv("REPRO_SPEED_MATRIX", str(path))
    assert pinned.matrix.data == matrix.data


def test_cluster_cli_policy_override(tmp_path):
    """--policy swaps any registered policy into any scenario (CLI path)."""
    from repro.cluster.run import main as cluster_main
    out = tmp_path / "r.json"
    rc = cluster_main(["--scenario", "smoke", "--policy", "time-sharing",
                       "--devices", "16", "--hours", "0.5", "--seed", "0",
                       "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["sim"]["policy"] == "time-sharing"
    assert rep["scenario"]["policy"] == "time-sharing"


# --------------------------------------------- predictor feature contract
_PROFILE_FIELDS = st.tuples(
    st.floats(0.0, 1.0), st.floats(0.05, 1.0), st.floats(0.0, 1.0),
    st.floats(0.05, 1.0), st.floats(0.01, 10_000.0), st.floats(0.0, 1.0))


def _profile(name, fields):
    util, act, occ, bw, ms, mem = fields
    return WorkloadProfile(name=name, gpu_util=util, sm_activity=act,
                           sm_occupancy=occ, mem_bw=bw, exec_time_ms=ms,
                           mem_bytes_frac=mem)


@settings(max_examples=60, deadline=None)
@given(_PROFILE_FIELDS, _PROFILE_FIELDS, st.floats(0.0, 1.0))
def test_pair_features_within_documented_ranges(on_f, off_f, share):
    feats = pair_features(_profile("on", on_f), _profile("off", off_f), share)
    assert feats.shape == (N_FEATURES,)
    assert np.isfinite(feats).all()
    lo, hi = FEATURE_RANGES[:, 0], FEATURE_RANGES[:, 1]
    assert (feats >= lo - 1e-6).all() and (feats <= hi + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=12),
       st.sampled_from(["recommend", "translate", "vision"]),
       st.floats(10.0, 180.0))
def test_predicted_tput_monotone_in_share(shares, svc, qps):
    """After training on measured data, predicted offline throughput along
    any share sweep is monotone non-decreasing (isotonic contract)."""
    pred = _MONO["pred"]
    on = online_profile(svc, qps)
    off = _MONO["off"]
    curve = predict_share_curve(pred, "T4", on, off, np.array(shares))
    order = np.argsort(shares)
    assert (np.diff(curve[order]) >= -1e-12).all()
    assert ((curve >= 0.0) & (curve <= 1.0)).all()


_MONO: dict = {}


@pytest.fixture(autouse=True, scope="module")
def _mono_setup(matrix, measured_predictor):
    _MONO["pred"] = measured_predictor
    _MONO["off"] = workload_profile(matrix, "lm-train-step")
    yield
    _MONO.clear()


# --------------------------------------------------------- profiler home
def test_core_profiler_shim_is_gone():
    """The PR-4 deprecation shim has been removed: the profiler's single
    home is repro.profiling.workloads, and the old import path now fails
    loudly instead of warning."""
    import importlib
    import sys
    sys.modules.pop("repro.core.profiler", None)
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.core.profiler")
    from repro.profiling.workloads import profile_from_trace
    assert profile_from_trace("VGG16").name == "VGG16"
