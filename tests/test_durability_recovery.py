"""Crash-recovery equivalence: a durable run killed at an arbitrary tick and
resumed produces a report (and obs artifacts) byte-identical to an
uninterrupted same-seed run — across WAL backends, tick engines, and crash
points (property-tested when hypothesis is installed)."""
import gc
import json
import os

import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.cluster import Scenario
from repro.cluster.control import ControlPlane
from repro.cluster.scenario import scenario_by_name
from repro.durability import DurableRun, resume_run, run_durable
from repro.obs import ObsConfig


def _tiny(**kw):
    base = dict(name="t", policy="time-sharing", n_devices=32, hours=1.0,
                seed=3, trace="C")
    base.update(kw)
    return Scenario(**base)


def _report_bytes(report: dict) -> str:
    return json.dumps(report, indent=2, sort_keys=True)


class _Crash(Exception):
    pass


def _crash_run(sc, rundir, crash_after_ticks, *, snapshot_every_s=300.0,
               backend="jsonl", obs=None):
    """An in-process stand-in for SIGKILL: run a durable run, abandon it
    mid-flight after `crash_after_ticks`, flush stale file handles, and
    leave the directory exactly as a dead process would (no report, no
    final manifest).  CI's recovery-smoke job does the real kill -9."""
    run = DurableRun.create(sc, rundir, obs=obs,
                            snapshot_every_s=snapshot_every_s,
                            backend=backend)
    snap_cb = run._tick_callback()

    def cb(ticks_done, t):
        snap_cb(ticks_done, t)
        if ticks_done >= crash_after_ticks:
            raise _Crash
    run.store.truncate(0)
    run.cp = ControlPlane(sc, obs=run.obs)
    run.cp.bus.attach_sink(run.store.append)
    with pytest.raises(_Crash):
        run.cp.run(tick_callback=cb)
    # drop the dead run's handles so nothing writes behind the resume
    run.store.close()
    del run
    gc.collect()


@pytest.mark.parametrize("backend", ["jsonl", "sqlite"])
def test_crash_resume_byte_identical(tmp_path, backend):
    sc = _tiny()
    base = run_durable(sc, str(tmp_path / "base"), backend=backend)
    _crash_run(sc, str(tmp_path / "crash"), 70, backend=backend)
    resumed = resume_run(str(tmp_path / "crash"))
    assert resumed.resumed_from_tick == 70
    assert _report_bytes(resumed.report) == _report_bytes(base.report)


@pytest.mark.parametrize("engine", ["numpy", "xla"])
def test_crash_resume_across_engines(tmp_path, engine):
    sc = _tiny(engine=engine)
    base = run_durable(sc, str(tmp_path / "base"))
    _crash_run(sc, str(tmp_path / "crash"), 45)
    resumed = resume_run(str(tmp_path / "crash"))
    assert resumed.resumed_from_tick == 40
    assert _report_bytes(resumed.report) == _report_bytes(base.report)


def test_crash_before_first_snapshot_restarts(tmp_path):
    sc = _tiny()
    base = run_durable(sc, str(tmp_path / "base"))
    _crash_run(sc, str(tmp_path / "crash"), 5, snapshot_every_s=1800.0)
    resumed = resume_run(str(tmp_path / "crash"))
    assert resumed.resumed_from_tick is None
    assert _report_bytes(resumed.report) == _report_bytes(base.report)


def test_crash_resume_full_control_plane(tmp_path):
    """The smoke scenario has every subsystem on — faults, flaky agents,
    autoscaling, a trained predictor with its memo cache, a retained event
    log — so this exercises the whole snapshot surface."""
    sc = scenario_by_name("smoke").with_overrides(
        n_devices=48, predictor_samples=100, predictor_epochs=3)
    base = run_durable(sc, str(tmp_path / "base"))
    _crash_run(sc, str(tmp_path / "crash"), 80)
    resumed = resume_run(str(tmp_path / "crash"))
    assert resumed.resumed_from_tick == 80
    assert _report_bytes(resumed.report) == _report_bytes(base.report)
    # the recovered WAL is gaplessly consistent with the bus digest
    n = resumed.report["events"]["n_events"]
    assert (resumed.store.replay_digest(n).hexdigest()
            == resumed.report["events"]["digest"])


def test_crash_resume_with_serving_and_obs(tmp_path):
    """Serving lanes mid-queue and obs writers mid-stream survive: the
    resumed metrics/trace/prom artifacts are byte-identical too."""
    sc = scenario_by_name("serving-slo").with_overrides(
        n_devices=64, hours=1.0, predictor_samples=100, predictor_epochs=3)

    def run_one(tag, crash=None):
        d = tmp_path / tag
        obs = ObsConfig(metrics_out=str(d / "metrics.jsonl"),
                        trace_out=str(d / "trace.jsonl"),
                        prom_out=str(d / "metrics.prom"),
                        metrics_every_s=300.0)
        os.makedirs(d, exist_ok=True)
        if crash is None:
            return run_durable(sc, str(d / "run"), obs=obs).report, d
        _crash_run(sc, str(d / "run"), crash, obs=obs)
        return resume_run(str(d / "run")).report, d

    base_rep, base_dir = run_one("base")
    res_rep, res_dir = run_one("crash", crash=75)
    assert _report_bytes(res_rep) == _report_bytes(base_rep)
    for f in ("metrics.jsonl", "trace.jsonl", "metrics.prom"):
        assert ((res_dir / f).read_bytes() == (base_dir / f).read_bytes()), f


def test_double_crash_resume(tmp_path):
    """A resume that itself dies is resumable again from a later snapshot."""
    sc = _tiny()
    base = run_durable(sc, str(tmp_path / "base"))
    _crash_run(sc, str(tmp_path / "crash"), 35)
    run = DurableRun.open(str(tmp_path / "crash"))
    snap_cb = run._tick_callback()

    def cb(ticks_done, t):
        snap_cb(ticks_done, t)
        if ticks_done >= 90:
            raise _Crash
    picked = run._pick_snapshot()
    assert picked is not None
    _path, snap = picked
    prefixes = run._read_obs_prefixes(snap)
    run.cp = ControlPlane(sc, obs=run.obs)
    from repro.durability import restore_control
    restore_control(run.cp, snap, store=run.store, obs_prefixes=prefixes)
    run.store.truncate(snap["bus"]["n_events"])
    run.cp.bus.attach_sink(run.store.append)
    with pytest.raises(_Crash):
        run.cp.run(start_tick=snap["tick_i"], start_t=snap["t"],
                   tick_callback=cb)
    run.store.close()
    del run
    gc.collect()
    resumed = resume_run(str(tmp_path / "crash"))
    assert resumed.resumed_from_tick == 90
    assert _report_bytes(resumed.report) == _report_bytes(base.report)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestCrashPointProperty:
    @settings(max_examples=6, deadline=None)
    @given(crash_after=st.integers(min_value=1, max_value=115),
           every_s=st.sampled_from([150.0, 300.0, 750.0]))
    def test_any_crash_tick_recovers_identically(self, tmp_path_factory,
                                                 crash_after, every_s):
        sc = _tiny()
        tmp = tmp_path_factory.mktemp("crashprop")
        base = run_durable(sc, str(tmp / "base"))
        _crash_run(sc, str(tmp / "crash"), crash_after,
                   snapshot_every_s=every_s)
        resumed = resume_run(str(tmp / "crash"))
        assert _report_bytes(resumed.report) == _report_bytes(base.report)
