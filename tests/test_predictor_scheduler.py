"""Speed predictor accuracy + Algorithm-1 scheduler behaviour."""
import jax
import numpy as np
import pytest

from repro.core.dynamic_sm import dynamic_sm, fixed_sm
from repro.core.interference import (OFFLINE_MODEL_PROFILES, online_profile,
                                     shared_performance)
from repro.core.predictor import (SpeedPredictor, make_dataset, mlp_apply,
                                  mlp_init, pair_features, train_predictor)
from repro.core.scheduler import OfflineJob, OnlineSlot, SchedulerConfig, schedule


@pytest.fixture(scope="module")
def trained():
    rng = np.random.default_rng(0)
    feats, targets = make_dataset(rng, n=1200)
    params, hist = train_predictor(jax.random.PRNGKey(0), feats, targets,
                                   epochs=60)
    return params, hist


def test_predictor_learns(trained):
    params, hist = trained
    assert hist["val_mae"][-1] < 0.06           # within a few % throughput
    assert hist["val_mae"][-1] < hist["val_mae"][0] * 0.5


def test_predictor_monotone_in_sm_share(trained):
    """More SMs for the offline workload => no lower predicted tput (holds
    for an uncontended online partner)."""
    params, _ = trained
    on = online_profile("recommend", 30.0)
    off = OFFLINE_MODEL_PROFILES["ResNet50"]
    preds = [float(mlp_apply(params, pair_features(on, off, s)))
             for s in (0.1, 0.3, 0.5, 0.7, 0.9)]
    assert preds[-1] > preds[0]


def test_dynamic_sm_complementary():
    assert dynamic_sm(0.2) >= dynamic_sm(0.8)
    assert 0.1 <= dynamic_sm(0.0) <= 0.9
    assert 0.1 <= dynamic_sm(1.0) <= 0.9
    assert dynamic_sm(0.15, step=0.1) == pytest.approx(0.8)
    assert fixed_sm() == 0.4


def test_scheduler_prefers_good_pairs(trained):
    params, _ = trained
    pred = SpeedPredictor({"T4": params})
    # one lightly-loaded and one heavily-loaded online device
    light = OnlineSlot(0, "T4", online_profile("recommend", 15.0))
    heavy = OnlineSlot(1, "T4", online_profile("vision", 190.0))
    job = OfflineJob(7, OFFLINE_MODEL_PROFILES["VGG16"], 3600.0)
    out = schedule([light, heavy], [job], pred)
    assert len(out) == 1
    assert out[0].device_id == 0                 # matches the idle device
    assert out[0].job_id == 7
    assert 0.1 <= out[0].sm_share <= 0.9


def test_scheduler_matching_beats_fifo(trained):
    params, _ = trained
    pred = SpeedPredictor({"T4": params})
    rng = np.random.default_rng(1)
    slots = [OnlineSlot(i, "T4", online_profile("translate", float(q)))
             for i, q in enumerate(rng.uniform(10, 190, 8))]
    jobs = [OfflineJob(j, OFFLINE_MODEL_PROFILES[m], 3600.0)
            for j, m in enumerate(rng.choice(list(OFFLINE_MODEL_PROFILES), 8))]
    km = schedule(slots, jobs, pred, SchedulerConfig(use_matching=True))
    fifo = schedule(slots, jobs, pred, SchedulerConfig(use_matching=False))
    assert sum(a.predicted_tput for a in km) >= sum(a.predicted_tput for a in fifo) - 1e-9


def test_interference_matches_fig4():
    """Fig 4(a): a tuned share yields >= 0.6 offline tput at < 1.2x online
    slowdown; Fig 4(b): the share sweep moves offline perf > 5x."""
    on = online_profile("vision", 100.0)
    off = OFFLINE_MODEL_PROFILES["VGG16"]
    best = 0.0
    for s in np.linspace(0.1, 0.9, 9):
        slow, tput = shared_performance(on, off, float(s))
        if slow <= 1.2:
            best = max(best, tput)
    assert best >= 0.55
    t10 = shared_performance(on, off, 0.1)[1]
    t90 = shared_performance(on, off, 0.9)[1]
    assert t90 / max(t10, 1e-9) > 5.0


def test_cached_predictor_memoizes_and_stays_close(trained):
    from repro.core.predictor import CachedSpeedPredictor

    params, _ = trained
    pred = SpeedPredictor({"T4": params})
    cached = CachedSpeedPredictor(pred, quantum=0.01)
    rng = np.random.default_rng(0)
    feats = rng.uniform(0, 1, (256, 9)).astype(np.float32)
    a = cached.predict("T4", feats)
    assert cached.misses > 0 and cached.hits == 0
    b = cached.predict("T4", feats)          # identical batch: all hits
    assert cached.hits == 256
    np.testing.assert_array_equal(a, b)
    exact = pred.predict("T4", feats)
    assert float(np.max(np.abs(a - exact))) < 0.05   # quantization is gentle
    # the scheduler runs unchanged on the cached predictor
    slots = [OnlineSlot(i, "T4", online_profile("recommend", 20.0 + i))
             for i in range(4)]
    jobs = [OfflineJob(j, OFFLINE_MODEL_PROFILES[m], 3600.0)
            for j, m in enumerate(OFFLINE_MODEL_PROFILES)]
    out = schedule(slots, jobs, cached)
    assert len(out) > 0
