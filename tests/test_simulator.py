"""Cluster simulator: conservation invariants + the paper's qualitative claims."""
import numpy as np
import pytest

import jax

from repro.core.predictor import build_speed_predictor
from repro.core.simulator import ClusterSim, SimConfig, run_policy
from repro.policies import resolve

FAST = dict(n_devices=40, horizon_s=3 * 3600.0, tick_s=60.0, trace="B", seed=3)


@pytest.fixture(scope="module")
def predictor():
    return build_speed_predictor(gpu_types=("T4", "A10"), n=600, epochs=30)


@pytest.fixture(scope="module")
def results(predictor):
    out = {}
    for pol in ("online-only", "muxflow", "pb-time-sharing", "time-sharing",
                "muxflow-s-m"):
        out[pol] = run_policy(
            pol, predictor if resolve(pol).needs_predictor else None, **FAST)
    return out


def test_online_only_is_baseline(results):
    r = results["online-only"]
    assert r.avg_slowdown == pytest.approx(1.0)
    assert r.oversold_gpu == 0.0 and r.n_finished == 0


def test_muxflow_protects_online(results):
    """Paper: online slowdown < 20 %."""
    assert results["muxflow"].avg_slowdown < 1.20


def test_muxflow_beats_time_sharing_baselines(results):
    mux = results["muxflow"]
    for base in ("time-sharing", "pb-time-sharing"):
        b = results[base]
        assert mux.oversold_gpu > b.oversold_gpu, base
    assert mux.avg_slowdown < results["time-sharing"].avg_slowdown


def test_ablations_hurt(results):
    assert results["muxflow"].oversold_gpu >= results["muxflow-s-m"].oversold_gpu - 0.02


def test_oversold_in_unit_range(results):
    for r in results.values():
        assert 0.0 <= r.oversold_gpu <= 1.0


def test_no_propagation_with_graceful_exit(results):
    assert results["muxflow"].errors_propagated == 0


def test_propagation_without_mechanism(predictor):
    r = run_policy("muxflow", predictor, graceful_exit=False,
                   error_rate_per_job_hour=0.5, **{**FAST, "seed": 7})
    assert r.errors_injected > 0
    assert r.errors_propagated > 0
    assert r.online_incidents == r.errors_propagated


def test_job_conservation(predictor):
    sim = ClusterSim(SimConfig(policy="muxflow", **FAST), predictor)
    r = sim.run()
    running = int(sim.state.has_job.sum())
    accounted = r.n_finished + running + len(sim.pending)
    # jobs not yet submitted by the horizon also count
    unsubmitted = sum(1 for j in sim.jobs if j.submit_s > sim.cfg.horizon_s)
    late = len(sim.jobs) - accounted - unsubmitted
    assert late >= 0                      # requeued jobs may split ids
    assert accounted + unsubmitted + late == len(sim.jobs)
    assert r.n_finished > 0


def test_device_failures_requeue(predictor):
    r = run_policy("muxflow", predictor, device_mtbf_h=2.0,
                   device_repair_s=600.0, **{**FAST, "seed": 11})
    # with aggressive failures jobs still complete (checkpoint/restart works)
    assert r.n_finished > 0


def test_utilization_improves(results):
    base, mux = results["online-only"], results["muxflow"]
    assert mux.gpu_util > base.gpu_util
    assert mux.sm_activity > base.sm_activity
    assert mux.mem_used > base.mem_used
