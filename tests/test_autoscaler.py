"""Unit tests for the online-service horizontal autoscaler: target-tracking
with hysteresis (upper/lower band), scale-up cooldown, and the scale-down
stability window — plus its control-plane wiring (scale-ups evict offline
partners; decisions land on the event bus)."""
import pytest

from repro.core.autoscaler import Autoscaler, AutoscalerConfig

CFG = AutoscalerConfig(target_load=0.6, upper=0.8, lower=0.35,
                       min_replicas=2, max_replicas=64,
                       cooldown_s=300.0, scale_down_stability_s=600.0)


def make(replicas=10, capacity=100.0, cfg=CFG):
    return Autoscaler(cfg, replicas=replicas, qps_capacity_per_replica=capacity)


# ------------------------------------------------------------------ scale up
def test_no_decision_inside_band():
    a = make()
    # load = 600/(10*100) = 0.6 -> inside [lower, upper]
    assert a.observe(600.0, now=1000.0) is None
    assert a.replicas == 10


def test_scale_up_targets_the_band_center():
    a = make()
    d = a.observe(1000.0, now=1000.0)          # load 1.0 > 0.8
    assert d is not None and d.delta > 0
    # sized so the new load sits at target: ceil(1000/(100*0.6)) = 17
    assert d.replicas == 17 and a.replicas == 17


def test_scale_up_cooldown_blocks_consecutive_ups():
    a = make()
    assert a.observe(1000.0, now=0.0) is not None
    assert a.observe(5000.0, now=100.0) is None        # inside cooldown
    assert a.observe(5000.0, now=301.0) is not None    # cooldown elapsed


def test_scale_up_clamped_to_max():
    a = make(replicas=60)
    d = a.observe(60 * 100.0 * 2.0, now=0.0)           # wants 200 replicas
    assert d.replicas == CFG.max_replicas


# ---------------------------------------------------------------- scale down
def test_scale_down_requires_stability_window():
    a = make()
    # load 0.2 < lower: first sighting only arms the window
    assert a.observe(200.0, now=0.0) is None
    # still inside the stability window -> no decision
    assert a.observe(200.0, now=599.0) is None
    d = a.observe(200.0, now=601.0)
    assert d is not None and d.delta < 0
    assert d.replicas == 4                              # ceil(200/60)


def test_bounce_back_resets_stability_window():
    a = make()
    assert a.observe(200.0, now=0.0) is None            # arms window
    assert a.observe(600.0, now=300.0) is None          # back in band: reset
    assert a.observe(200.0, now=601.0) is None          # re-arms, not down
    assert a.observe(200.0, now=1300.0) is not None     # full window again


def test_scale_down_clamped_to_min():
    a = make(replicas=3)
    a.observe(1.0, now=0.0)
    d = a.observe(1.0, now=700.0)
    assert d is not None and d.replicas == CFG.min_replicas


def test_hysteresis_band_no_flapping():
    """Loads wandering inside (lower, upper) never trigger decisions."""
    a = make()
    t = 0.0
    for load_frac in (0.4, 0.7, 0.5, 0.79, 0.36, 0.6):
        assert a.observe(load_frac * 10 * 100.0, now=t) is None, load_frac
        t += 1000.0
    assert a.replicas == 10


# ------------------------------------------------------- control-plane wiring
@pytest.mark.slow
def test_control_plane_scale_up_evicts_offline_partners():
    from repro.cluster import ControlPlane, Scenario
    from repro.cluster.events import EventKind

    sc = Scenario(name="as-test", n_devices=48, hours=2.0, trace="C",
                  autoscale=True, keep_event_log=True,
                  predictor_samples=120, predictor_epochs=4, seed=5)
    cp = ControlPlane(sc)
    cp.run()
    ups = [e for e in cp.bus.log if e.kind is EventKind.AUTOSCALE
           and dict(e.data)["delta"] > 0]
    evictions = [e for e in cp.bus.log if e.kind is EventKind.JOB_EVICT
                 and dict(e.data)["reason"] == "autoscale"]
    assert cp.autoscale_decisions, "diurnal load should trigger decisions"
    # every autoscale eviction coincides with some scale-up event
    up_times = {e.t for e in ups}
    assert all(e.t in up_times for e in evictions)
    rep = cp.report()
    assert rep["autoscaler"]["n_decisions"] == len(cp.autoscale_decisions)
