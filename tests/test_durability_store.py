"""Event-store unit tests: append/read round-trips, segment chain hashes,
truncation, torn-tail recovery, and the EventBus durable-sink seam."""
import json
import os

import pytest

from repro.cluster.events import Event, EventBus, EventKind
from repro.durability import open_store
from repro.durability.store import BACKENDS, JsonlEventStore


def _events(n, start=0):
    kinds = list(EventKind)
    return [Event(seq=start + i, t=30.0 * (start + i),
                  kind=kinds[(start + i) % len(kinds)],
                  device=(start + i) % 7 - 1, job=(start + i) % 5 - 1,
                  data=(("k", start + i), ("f", 0.1 * (start + i))))
            for i in range(n)]


@pytest.fixture(params=BACKENDS)
def backend(request):
    return request.param


class TestEventStore:
    def test_append_read_roundtrip(self, tmp_path, backend):
        store = open_store(str(tmp_path / "ev"), backend, segment_events=10)
        evs = _events(25)
        for ev in evs:
            store.append(ev)
        store.flush()
        assert store.count() == 25
        assert list(store.read(0, 25)) == evs
        assert list(store.read(7, 13)) == evs[7:13]
        store.close()

    def test_seq_gap_rejected(self, tmp_path, backend):
        store = open_store(str(tmp_path / "ev"), backend)
        store.append(_events(1)[0])
        with pytest.raises(ValueError):
            store.append(_events(1, start=5)[0])
        store.close()

    def test_reopen_continues_sequence(self, tmp_path, backend):
        root = str(tmp_path / "ev")
        store = open_store(root, backend, segment_events=10)
        evs = _events(25)
        for ev in evs[:15]:
            store.append(ev)
        store.close()
        store = open_store(root, backend, segment_events=10)
        assert store.count() == 15
        for ev in evs[15:]:
            store.append(ev)
        store.flush()
        assert list(store.read(0, 25)) == evs
        store.close()

    def test_chain_and_verify(self, tmp_path, backend):
        store = open_store(str(tmp_path / "ev"), backend, segment_events=5)
        for ev in _events(23):
            store.append(ev)
        store.flush()
        chain = store.chain()
        assert len(chain) == 4          # 4 sealed segments of 5, 3 open
        assert store.verify() == []
        store.close()

    def test_chain_links(self, tmp_path, backend):
        """chain_k folds in chain_{k-1}: same segments, different order
        would change every later link."""
        store = open_store(str(tmp_path / "ev"), backend, segment_events=5)
        for ev in _events(15):
            store.append(ev)
        store.flush()
        chain = store.chain()
        assert len({row["chain"] for row in chain}) == len(chain)
        store.close()

    def test_truncate_open_segment(self, tmp_path, backend):
        store = open_store(str(tmp_path / "ev"), backend, segment_events=10)
        evs = _events(17)
        for ev in evs:
            store.append(ev)
        store.truncate(13)
        assert store.count() == 13
        assert list(store.read(0, 13)) == evs[:13]
        for ev in evs[13:]:
            store.append(ev)
        store.flush()
        assert list(store.read(0, 17)) == evs
        store.close()

    def test_truncate_into_sealed_segment(self, tmp_path, backend):
        store = open_store(str(tmp_path / "ev"), backend, segment_events=5)
        evs = _events(23)
        for ev in evs:
            store.append(ev)
        store.truncate(7)           # mid-way through the second sealed seg
        assert store.count() == 7
        assert list(store.read(0, 7)) == evs[:7]
        for ev in evs[7:]:
            store.append(ev)
        store.flush()
        assert list(store.read(0, 23)) == evs
        assert store.verify() == []
        store.close()

    def test_truncate_to_zero(self, tmp_path, backend):
        store = open_store(str(tmp_path / "ev"), backend, segment_events=5)
        evs = _events(12)
        for ev in evs:
            store.append(ev)
        store.truncate(0)
        assert store.count() == 0
        for ev in evs:
            store.append(ev)
        store.flush()
        assert list(store.read(0, 12)) == evs
        store.close()

    def test_replay_digest_matches_bus(self, tmp_path, backend):
        bus = EventBus()
        store = open_store(str(tmp_path / "ev"), backend, segment_events=7)
        bus.attach_sink(store.append)
        for i in range(20):
            bus.emit(30.0 * i, EventKind.SCHEDULE, device=i % 3,
                     data=(("n", i),))
        store.flush()
        assert store.replay_digest(20).hexdigest() == bus.digest()
        store.close()

    def test_float_fidelity(self, tmp_path, backend):
        """WAL rows round-trip floats exactly (shortest-repr json), so the
        replayed digest can't drift from the live one."""
        ev = Event(0, 1234.5600000001, EventKind.ERROR,
                   data=(("lat", 0.1 + 0.2), ("w", 1e-17)))
        store = open_store(str(tmp_path / "ev"), backend)
        store.append(ev)
        store.flush()
        assert list(store.read(0, 1)) == [ev]
        store.close()


class TestRetryLadder:
    def test_injected_faults_absorbed_without_loss_or_dup(self, tmp_path,
                                                          backend):
        from repro.chaos import ScriptedInjector
        store = open_store(str(tmp_path / "ev"), backend)
        inj = ScriptedInjector(store_faults=2)
        store.fault_injector = inj
        evs = _events(5)
        for ev in evs:
            store.append(ev)
        store.flush()
        # faults fire before the real op: no lost rows, no duplicates
        assert store.count() == 5
        assert list(store.read(0, 5)) == evs
        assert store.io_faults == 2 and store.io_retries == 2
        # the injector was told the ladder absorbed every fault
        assert sum(a for _, a in inj.recovered) == 2
        store.close()

    def test_burst_beyond_retry_budget_propagates(self, tmp_path, backend):
        from repro.chaos import ScriptedInjector
        store = open_store(str(tmp_path / "ev"), backend)
        store.fault_injector = ScriptedInjector(store_faults=10)
        with pytest.raises(OSError):
            store.append(_events(1)[0])
        # the ladder stopped at its bound, not at fault exhaustion
        assert store.io_retries == store.max_io_retries
        assert store.io_faults == store.max_io_retries + 1
        # the failed append left no partial state: seq 0 is still next
        store.fault_injector = None
        store.append(_events(1)[0])
        store.flush()
        assert store.count() == 1
        store.close()


class TestTornTail:
    def test_jsonl_torn_tail_dropped_on_reopen(self, tmp_path):
        root = str(tmp_path / "ev")
        store = JsonlEventStore(root, segment_events=100)
        evs = _events(6)
        for ev in evs:
            store.append(ev)
        store.close()
        seg = os.path.join(root, "segment-000000000.jsonl")
        with open(seg, "a") as f:
            f.write('{"seq": 6, "t": 180.0, "kin')   # torn mid-write
        store = JsonlEventStore(root, segment_events=100)
        assert store.count() == 6
        assert list(store.read(0, 6)) == evs
        # the rewritten segment is parseable end to end again
        with open(seg) as f:
            rows = [json.loads(line) for line in f]
        assert len(rows) == 6
        store.close()

    def test_sqlite_uncommitted_suffix_rolled_back(self, tmp_path):
        """The sqlite analog of a torn jsonl tail: rows appended after the
        last commit are lost on SIGKILL (``abandon()``), the committed
        prefix survives intact, and resume re-appends the suffix."""
        root = str(tmp_path / "ev")
        store = open_store(root, "sqlite")
        evs = _events(10)
        for ev in evs[:6]:
            store.append(ev)
        store.flush()                 # commit the prefix
        for ev in evs[6:]:
            store.append(ev)
        store.abandon()               # SIGKILL stand-in: rollback + close
        store = open_store(root, "sqlite")
        assert store.count() == 6
        assert list(store.read(0, 6)) == evs[:6]
        for ev in evs[6:]:
            store.append(ev)
        store.flush()
        assert list(store.read(0, 10)) == evs
        store.close()


class TestSinkSeam:
    def test_sink_never_drops_while_log_caps(self):
        """Satellite guarantee: the capped in-memory log may drop, the
        durable sink may not — they disagree by exactly zero events."""
        bus = EventBus(keep_log=True, log_cap=5)
        seen = []
        bus.attach_sink(seen.append)
        for i in range(40):
            bus.emit(float(i), EventKind.JOB_SUBMIT, job=i)
        s = bus.summary()
        assert s["log_dropped"] == 35 and len(bus.log) == 5
        assert s["sink_events"] == 40 == s["n_events"] == len(seen)
        assert s["sink_dropped"] == 0
        assert s["n_events"] - len(seen) == 0
        assert [ev.seq for ev in seen] == list(range(40))

    def test_sink_sees_events_before_subscribers(self):
        order = []
        bus = EventBus()
        bus.attach_sink(lambda ev: order.append("sink"))
        bus.subscribe(lambda ev: order.append("sub"))
        bus.emit(0.0, EventKind.ERROR)
        assert order == ["sink", "sub"]

    def test_sink_exception_aborts_emit(self):
        bus = EventBus()

        def bad(ev):
            raise OSError("disk full")
        bus.attach_sink(bad)
        with pytest.raises(OSError):
            bus.emit(0.0, EventKind.ERROR)
