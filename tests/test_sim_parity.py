"""Fixed-seed parity: the vectorized engine reproduces the per-device
reference engine's trajectory.

Both engines share one RNG stream (per-tick (3, n) uniform blocks) and one
set of vectorized trace/profile providers, so discrete events (evictions,
errors, finishes) must match *exactly* and continuous aggregates to
accumulation-order tolerance."""
import pytest

from repro.core.predictor import build_speed_predictor
from repro.core.simulator import ClusterSim, SimConfig
from repro.core.simulator_legacy import LegacyClusterSim
from repro.policies import resolve

CFG = dict(n_devices=50, horizon_s=4 * 3600.0, tick_s=30.0, trace="B",
           seed=12345)

_FLOAT_FIELDS = ("avg_latency_ms", "base_avg_latency_ms", "avg_slowdown",
                 "gpu_util", "sm_activity", "mem_used", "avg_norm_tput",
                 "oversold_gpu", "avg_jct_s", "makespan_s", "eviction_frac")
_COUNT_FIELDS = ("n_jobs", "n_finished", "evictions", "errors_injected",
                 "errors_propagated", "online_incidents")


@pytest.fixture(scope="module")
def predictor():
    return build_speed_predictor(gpu_types=("T4", "A10"), n=500, epochs=25)


def _run_pair(policy, predictor, **overrides):
    kwargs = {**CFG, **overrides}
    p = predictor if resolve(policy).needs_predictor else None
    vec = ClusterSim(SimConfig(policy=policy, **kwargs), p).run()
    ref = LegacyClusterSim(SimConfig(policy=policy, **kwargs), p).run()
    return vec, ref


def _assert_parity(vec, ref):
    for f in _COUNT_FIELDS:
        assert getattr(vec, f) == getattr(ref, f), f
    for f in _FLOAT_FIELDS:
        assert getattr(vec, f) == pytest.approx(getattr(ref, f), rel=1e-9,
                                                abs=1e-12), f
    # p99 is histogram-binned (0.05 ms) in the vectorized engine while the
    # reference interpolates between order statistics, which can sit a few
    # tenths of a ms apart in the sparse latency tail — compare loosely
    assert vec.p99_latency_ms == pytest.approx(ref.p99_latency_ms, rel=0.02,
                                               abs=0.2)
    assert vec.timeline["t"] == ref.timeline["t"]
    for k in ("gpu_util", "sm_act", "mem", "slowdown", "tput"):
        assert vec.timeline[k] == pytest.approx(ref.timeline[k], rel=1e-9)


@pytest.mark.parametrize("policy", ["muxflow", "muxflow-s", "muxflow-m",
                                    "muxflow-s-m", "time-sharing",
                                    "pb-time-sharing", "online-only"])
def test_vectorized_engine_matches_reference(policy, predictor):
    vec, ref = _run_pair(policy, predictor)
    _assert_parity(vec, ref)


def test_parity_under_heavy_failures_and_errors(predictor):
    """Eviction/requeue/checkpoint paths exercised hard: aggressive hardware
    failures and container error rates, graceful exit off."""
    vec, ref = _run_pair("muxflow", predictor, device_mtbf_h=3.0,
                         device_repair_s=600.0, error_rate_per_job_hour=0.8,
                         graceful_exit=False, seed=7)
    assert vec.errors_injected > 0 and vec.evictions >= 0
    _assert_parity(vec, ref)


def test_parity_on_busier_trace(predictor):
    vec, ref = _run_pair("muxflow", predictor, trace="D", seed=3)
    assert vec.n_finished > 0
    _assert_parity(vec, ref)
