"""Distribution-layer integration tests that need >1 device: run in a
subprocess with forced host-device count (the main test process must keep
seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_a2a_moe_matches_dense_on_mesh():
    out = run_sub("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.models import moe as M
        from repro.launch.mesh import make_mesh
        from repro.sharding.context import activation_mesh
        cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b", smoke=True),
                                  dtype=jnp.float32, moe_capacity_factor=100.0)
        key = jax.random.PRNGKey(0)
        p = M.moe_init(key, cfg, jnp.float32)
        x = jax.random.normal(key, (4, 16, cfg.d_model), jnp.float32)
        yd, _ = M.moe_dense_dispatch(p, x, cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh, activation_mesh(mesh):
            ya, _ = jax.jit(lambda p, x: M.moe_a2a_dispatch(p, x, cfg, 100.0))(p, x)
            g = jax.jit(jax.grad(lambda x: M.moe_a2a_dispatch(p, x, cfg, 100.0)[0].sum()))(x)
        gd = jax.grad(lambda x: M.moe_dense_dispatch(p, x, cfg)[0].sum())(x)
        print("fwd", float(jnp.abs(jnp.asarray(ya) - yd).max()))
        print("grad", float(jnp.abs(jnp.asarray(g) - gd).max()))
    """)
    for line in out.splitlines():
        name, val = line.split()
        assert float(val) < 1e-4, (name, val)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The FSDP×TP-sharded train step computes the same loss as 1 device."""
    code = """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.models import init_params, make_train_step
        from repro.optim.optimizer import AdamW, AdamWConfig
        from repro.launch.mesh import make_mesh
        from repro.sharding.context import activation_mesh
        from repro.sharding.rules import batch_sharding, opt_state_sharding, param_sharding
        cfg = dataclasses.replace(get_config("{arch}", smoke=True), dtype=jnp.float32)
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        opt = AdamW(AdamWConfig(lr=1e-3, total_steps=10))
        batch = {{"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}}
        step = make_train_step(cfg, opt)
        mesh = make_mesh(({dp}, {tp}), ("data", "model"))
        with mesh, activation_mesh(mesh):
            p_sh = param_sharding(mesh, params, mode="train")
            p = jax.tree.map(jax.device_put, params, p_sh)
            o = opt.init(p)
            o_sh = opt_state_sharding(mesh, p_sh, o)
            o = jax.tree.map(jax.device_put, o, o_sh)
            b_sh = batch_sharding(mesh, batch)
            b = {{k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}}
            _, _, m = jax.jit(step, out_shardings=(p_sh, o_sh, None))(p, o, b)
        print("loss", float(m["loss"]))
    """
    for arch in ("h2o-danube-1.8b", "granite-moe-1b-a400m"):
        sharded = run_sub(code.format(arch=arch, dp=2, tp=4))
        single = run_sub(code.format(arch=arch, dp=1, tp=1), devices=1)
        l_sharded = float(sharded.split()[-1])
        l_single = float(single.split()[-1])
        assert abs(l_sharded - l_single) / abs(l_single) < 2e-4, arch


@pytest.mark.slow
def test_dryrun_cell_end_to_end():
    """The dry-run machinery itself: one cell lowers, compiles, analyzes."""
    out = run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import json
        from repro.launch.dryrun import run_cell
        rec = run_cell("xlstm-350m", "decode_32k", multi_pod=False)
        assert rec["status"] == "ok", rec
        assert rec["terms"]["memory_s"] > 0
        assert rec["hlo"]["dot_flops"] > 0
        print(json.dumps({"ok": True, "dom": rec["dominant"]}))
    """, devices=512)
    assert json.loads(out.splitlines()[-1])["ok"]
