"""Infrastructure: checkpointing, data pipeline determinism, fault tolerance,
gradient compression, sharding rules, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint.checkpointing import AsyncCheckpointer, latest_step, restore, save
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.runtime.compression import GradCompressor
from repro.runtime.fault_tolerance import ElasticCoordinator, HeartbeatMonitor


def tree_eq(a, b):
    return all(bool(jnp.allclose(x.astype(jnp.float32), y.astype(jnp.float32)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": ({"b": jnp.ones((5,), jnp.bfloat16)},
                       jnp.asarray(3, jnp.int32))}
    save(str(tmp_path), 7, tree)
    out, step = restore(str(tmp_path), tree)
    assert step == 7 and tree_eq(tree, out)
    assert out["nested"][0]["b"].dtype == jnp.bfloat16


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 5
    steps = sorted(os.listdir(tmp_path))
    assert len([s for s in steps if s.startswith("step_")]) == 2


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.ones((4, 4))}
    ck.save(3, tree)
    ck.wait()
    out, step = restore(str(tmp_path), tree)
    assert step == 3 and tree_eq(tree, out)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500), st.integers(1, 4))
def test_pipeline_deterministic_and_host_sharded(step, n_hosts):
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    full = TokenPipeline(cfg).batch_at(step)["tokens"]
    again = TokenPipeline(cfg).batch_at(step)["tokens"]
    np.testing.assert_array_equal(full, again)
    assert full.min() >= 0 and full.max() < 128
    if 8 % n_hosts == 0:
        host = TokenPipeline(cfg, host_id=0, n_hosts=n_hosts)
        assert host.batch_at(step)["tokens"].shape == (8 // n_hosts, 16)


def test_heartbeat_failure_and_straggler():
    hb = HeartbeatMonitor(4, timeout_s=10.0, straggler_patience=3, now=0.0)
    for t in range(5):
        for n in (0, 1, 2):   # node 3 never beats
            hb.heartbeat(n, step_time=1.0 if n else 2.5, now=float(t))
    status = None
    for _ in range(3):        # patience: 3 consecutive slow observations
        status = hb.check(now=9.0)
    assert 0 in status["stragglers"]      # node 0 at 2.5x median
    status = hb.check(now=20.0)
    assert status["dead"] == [0, 1, 2, 3] or status["dead"] == [3]


def test_heartbeat_monitor_matches_fleet_stale_mask():
    """Regression for the unified failure predicate: HeartbeatMonitor.check
    and the control plane's vectorized stale_mask classify the identical
    heartbeat history identically (boundary value included)."""
    from repro.cluster.agents import stale_mask
    beats = [0.0, 10.0, 30.0, 50.0, 51.0, 100.0]
    hb = HeartbeatMonitor(len(beats), timeout_s=50.0, now=0.0)
    for n, t in enumerate(beats):
        hb.heartbeat(n, now=t)
    now = 100.0
    dead = set(hb.check(now=now)["dead"])
    mask = stale_mask(now, np.asarray(beats), 50.0)
    assert dead == set(np.flatnonzero(mask).tolist())
    # t=50 is exactly at the timeout: strictly-older semantics — alive
    assert 3 not in dead


def test_elastic_coordinator_emits_plan():
    hb = HeartbeatMonitor(3, timeout_s=10.0, now=0.0)
    co = ElasticCoordinator(hb, get_ckpt_step=lambda: 42)
    for n in range(3):
        hb.heartbeat(n, now=1.0)
    assert co.poll(now=2.0) is None
    # node 2 dies
    for n in (0, 1):
        hb.heartbeat(n, now=15.0)
    plan = co.poll(now=20.0)
    assert plan is not None and plan.reason == "node_failure"
    assert plan.world == [0, 1] and plan.resume_step == 42


@pytest.mark.parametrize("mode,max_rel", [("int8", 0.02), ("topk", 1.0)])
def test_grad_compression_roundtrip(mode, max_rel):
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (128,))}
    comp = GradCompressor(mode=mode, k_frac=0.2)
    state = comp.init(g)
    dec, state, wire, raw = comp.compress_decompress(g, state)
    assert wire < raw * 0.5
    if mode == "int8":
        err = float(jnp.abs(dec["a"] - g["a"]).max() / jnp.abs(g["a"]).max())
        assert err < max_rel
    # error feedback: the residual carries what was dropped
    res_norm = sum(float(jnp.abs(r).sum()) for r in jax.tree.leaves(state.residual))
    if mode == "topk":
        assert res_norm > 0


def test_sharding_rules_divisibility_fallback():
    import os
    from repro.launch.mesh import make_mesh
    from repro.sharding.rules import param_sharding
    if len(jax.devices()) != 1:
        pytest.skip("single-device test")
    mesh = make_mesh((1, 1), ("data", "model"))
    params = {"blocks": ({"attn": {"w_q": jnp.zeros((2, 8, 16))}},),
              "embed": jnp.zeros((100, 8))}
    sh = param_sharding(mesh, params)   # must not raise; odd dims replicate
    specs = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert all(hasattr(s, "spec") for s in specs)


def test_hlo_analyzer_scan_vs_unroll():
    """Loop-multiplier accounting: scanned == unrolled dot flops."""
    from repro.launch.hlo_analysis import analyze
    N, B, D = 6, 16, 32

    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()

    def unrolled(x, ws):
        for i in range(N):
            x, _ = body(x, ws[i])
        return x.sum()

    x = jnp.ones((B, D))
    ws = jnp.ones((N, D, D))
    fs = analyze(jax.jit(scanned).lower(x, ws).compile().as_text()).flops
    fu = analyze(jax.jit(unrolled).lower(x, ws).compile().as_text()).flops
    assert fs == pytest.approx(fu, rel=1e-6)
    assert fs == pytest.approx(2 * B * D * D * N, rel=1e-6)
