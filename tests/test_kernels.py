"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,Hk,d,causal,window", [
    (1, 128, 128, 1, 1, 128, True, None),
    (2, 256, 256, 4, 2, 128, True, None),
    (2, 128, 256, 4, 4, 128, False, None),     # cross-attn shape (MHA)
    (1, 256, 256, 8, 2, 128, True, 128),       # GQA + sliding window
    (2, 384, 384, 2, 1, 128, True, 256),       # MQA + window
])
def test_flash_attention_vs_ref(dtype, B, Sq, Skv, H, Hk, d, causal, window):
    key = jax.random.PRNGKey(B * Sq + H)
    q = rand(key, (B, Sq, H, d), dtype)
    k = rand(jax.random.fold_in(key, 1), (B, Skv, Hk, d), dtype)
    v = rand(jax.random.fold_in(key, 2), (B, Skv, Hk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Skv,H,Hk,d,kv_len,block_k", [
    (2, 256, 4, 2, 128, 200, 128),
    (1, 512, 8, 1, 128, 512, 256),      # MQA, full cache
    (3, 256, 4, 4, 128, 17, 128),       # MHA, short prefix
])
def test_decode_attention_vs_ref(dtype, B, Skv, H, Hk, d, kv_len, block_k):
    key = jax.random.PRNGKey(Skv + H)
    q = rand(key, (B, 1, H, d), dtype)
    k = rand(jax.random.fold_in(key, 1), (B, Skv, Hk, d), dtype)
    v = rand(jax.random.fold_in(key, 2), (B, Skv, Hk, d), dtype)
    out = decode_attention(q, k, v, kv_len, block_k=block_k, interpret=True)
    want = ref.decode_attention_reference(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,S,di,N,chunk", [
    (1, 64, 128, 16, 16),
    (2, 128, 256, 16, 32),
    (2, 96, 128, 8, 32),                # chunk doesn't divide evenly? 96/32=3 ok
])
def test_ssm_scan_vs_ref(B, S, di, N, chunk):
    key = jax.random.PRNGKey(S + di)
    dt = jax.nn.softplus(rand(key, (B, S, di), jnp.float32))
    x = rand(jax.random.fold_in(key, 1), (B, S, di), jnp.float32)
    Bc = rand(jax.random.fold_in(key, 2), (B, S, N), jnp.float32)
    Cc = rand(jax.random.fold_in(key, 3), (B, S, N), jnp.float32)
    A_log = jnp.log(jnp.broadcast_to(
        jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)))
    out = ssm_scan(dt, x, Bc, Cc, A_log, chunk=chunk, interpret=True)
    want = ref.ssm_scan_reference(dt, x, Bc, Cc, A_log)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_chunked_jnp_attention_matches_ref():
    """The distribution-path chunked attention (models/layers.py) is the same
    math as the Pallas kernel; cross-check all three on one shape."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    q = rand(key, (1, 256, 4, 128), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (1, 256, 2, 128), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (1, 256, 2, 128), jnp.float32)
    a = L.attention_chunked(q, k, v, causal=True, chunk_q=128, chunk_k=128)
    b = flash_attention(q, k, v, causal=True, interpret=True)
    c = ref.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=2e-5, rtol=2e-5)
