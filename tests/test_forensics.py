"""Forensics tests: time-travel inspection (snapshot replay vs from-start
byte-identity, incident targeting) and WAL diffing (chain bisection to the
exact first divergent event), plus crash-resume byte-identity of the alert
engine's incidents.jsonl."""
import gc
import json
import os

import pytest

from repro.cluster.control import ControlPlane
from repro.cluster.scenario import scenario_by_name
from repro.durability import (DurableRun, build_paused, diff_runs,
                              dump_inspection, format_diff, inspect_run,
                              resume_run, run_durable)
from repro.obs import ObsConfig


def _storm(**kw):
    base = dict(hours=2.5, n_devices=100, seed=0)
    base.update(kw)
    return scenario_by_name("fault-storm").with_overrides(**base)


def _durable(tmp_path, tag, sc, *, alerts=True, **kw):
    d = tmp_path / tag
    os.makedirs(d, exist_ok=True)
    obs = (ObsConfig(alerts_out=str(d / "incidents.jsonl"),
                     metrics_every_s=600.0) if alerts else None)
    run = run_durable(sc, str(d / "run"), obs=obs,
                      snapshot_every_s=900.0, keep_snapshots=99, **kw)
    run.finalize_manifest()   # closes the store
    return str(d / "run")


@pytest.fixture(scope="module")
def storm_rundirs(tmp_path_factory):
    tmp_path = tmp_path_factory.mktemp("forensics")
    a = _durable(tmp_path, "a", _storm())
    a2 = _durable(tmp_path, "a2", _storm())
    b = _durable(tmp_path, "b", _storm(seed=1))
    return a, a2, b


# ----------------------------------------------------------------- inspect
def test_inspect_snapshot_vs_from_start_byte_identical(storm_rundirs):
    rundir, _, _ = storm_rundirs
    doc_snap = inspect_run(rundir, 180)
    doc_full = inspect_run(rundir, 180, from_start=True)
    assert dump_inspection(doc_snap) == dump_inspection(doc_full)
    # and the snapshot path really did start from a snapshot, not tick 0
    run = DurableRun.open(rundir)
    try:
        _cp, start = build_paused(run, 180)
        assert start > 0
    finally:
        run.store.close()


def test_inspect_state_summary_content(storm_rundirs):
    rundir, _, _ = storm_rundirs
    doc = inspect_run(rundir, 120)
    assert doc["tick"] == 120 and doc["t"] == pytest.approx(120 * 30.0)
    dev = doc["devices"]
    assert dev["total"] == 100
    assert 0 <= dev["busy"] <= dev["total"]
    assert sum(doc["mstate"].values()) == dev["total"]
    assert doc["jobs"]["running"] == dev["busy"]
    assert doc["events"]["n_events"] > 0
    assert sum(doc["placements"]["by_pool"].values()) == dev["busy"]
    assert doc["incidents"] is not None  # the run recorded alerts


def test_inspect_around_incident_targets_open_tick(storm_rundirs):
    rundir, _, _ = storm_rundirs
    from repro.obs import read_incidents
    timeline = read_incidents(os.path.join(
        os.path.dirname(rundir), "incidents.jsonl"))
    assert timeline, "fault-storm should open incidents"
    inc = timeline[0]
    doc = inspect_run(rundir, around_incident=inc.id)
    assert doc["t"] == pytest.approx(inc.opened_t)
    open_ids = [r["id"] for r in doc["incidents"]["open_at_t"]]
    assert inc.id in open_ids


def test_inspect_rejects_bad_targets(storm_rundirs):
    rundir, _, _ = storm_rundirs
    with pytest.raises(ValueError, match="horizon"):
        inspect_run(rundir, 10_000_000)
    with pytest.raises(ValueError, match="no incident id"):
        inspect_run(rundir, around_incident=999)
    with pytest.raises(ValueError, match="tick or an incident"):
        inspect_run(rundir)


def test_inspect_without_alerts_has_null_incidents(tmp_path):
    rundir = _durable(tmp_path, "noal", _storm(hours=1.0), alerts=False)
    doc = inspect_run(rundir, 60)
    assert doc["incidents"] is None
    with pytest.raises(ValueError, match="recorded none"):
        inspect_run(rundir, around_incident=0)


def test_inspect_is_read_only(storm_rundirs):
    rundir, _, _ = storm_rundirs
    inc_path = os.path.join(os.path.dirname(rundir), "incidents.jsonl")
    before = open(inc_path, "rb").read()
    events_dir = os.path.join(rundir, "events")
    seg_bytes = {f: os.path.getsize(os.path.join(events_dir, f))
                 for f in os.listdir(events_dir)}
    inspect_run(rundir, 150)
    assert open(inc_path, "rb").read() == before
    assert {f: os.path.getsize(os.path.join(events_dir, f))
            for f in os.listdir(events_dir)} == seg_bytes


# -------------------------------------------------------------------- diff
def test_diff_identical_runs(storm_rundirs):
    a, a2, _ = storm_rundirs
    doc = diff_runs(a, a2)
    assert doc["identical"] is True
    assert doc["first_divergence"] is None
    assert doc["sealed_segments_compared"] >= 0
    assert "identical" in format_diff(doc)


def test_diff_pinpoints_first_divergent_event(storm_rundirs):
    a, _, b = storm_rundirs
    doc = diff_runs(a, b, context=2)
    assert doc["identical"] is False
    fd = doc["first_divergence"]
    # independently locate the first key mismatch by a full linear scan
    from repro.durability.store import open_store
    sa = open_store(os.path.join(a, "events"), "jsonl")
    sb = open_store(os.path.join(b, "events"), "jsonl")
    try:
        expect = next(i for i, (ea, eb) in enumerate(
            zip(sa.read(0, None), sb.read(0, None)))
            if ea.key() != eb.key())
    finally:
        sa.close()
        sb.close()
    assert fd["seq"] == expect
    assert fd["event_a"] != fd["event_b"]
    assert fd["event_a"]["seq"] == expect
    assert len(fd["context_a"]) <= 5 and fd["context_a"][-1]["seq"] >= expect
    assert doc["incidents_at_divergence"] is not None
    assert "first divergence" in format_diff(doc)


def test_diff_rejects_non_rundir(tmp_path, storm_rundirs):
    with pytest.raises(FileNotFoundError):
        diff_runs(str(tmp_path), storm_rundirs[0])


# ---------------------------------------------------------- crash + resume
class _Crash(Exception):
    pass


def test_crash_resume_restores_alert_engine_byte_identical(tmp_path):
    """Kill a durable run while an incident is open; the resumed run's
    incidents.jsonl (mid-stream alert writer + rule-state machines +
    incident list restored from the snapshot) is byte-identical to an
    uninterrupted run's."""
    sc = _storm(hours=2.5)

    def obs_for(d):
        return ObsConfig(alerts_out=str(d / "incidents.jsonl"),
                         metrics_out=str(d / "metrics.jsonl"),
                         metrics_every_s=600.0)

    base = tmp_path / "base"
    os.makedirs(base)
    run = run_durable(sc, str(base / "run"), obs=obs_for(base),
                      snapshot_every_s=900.0)
    run.finalize_manifest()

    crash = tmp_path / "crash"
    os.makedirs(crash)
    run = DurableRun.create(sc, str(crash / "run"), obs=obs_for(crash),
                            snapshot_every_s=900.0)
    snap_cb = run._tick_callback()

    def cb(ticks_done, t):
        snap_cb(ticks_done, t)
        if ticks_done >= 220:   # t=6600s: online-slowdown already firing
            raise _Crash
    run.store.truncate(0)
    run.cp = ControlPlane(sc, obs=run.obs)
    run.cp.bus.attach_sink(run.store.append)
    with pytest.raises(_Crash):
        run.cp.run(tick_callback=cb)
    run.store.close()
    del run
    gc.collect()

    resumed = resume_run(str(crash / "run"))
    assert resumed.resumed_from_tick is not None
    resumed.store.close()
    for f in ("incidents.jsonl", "metrics.jsonl"):
        assert ((crash / f).read_bytes() == (base / f).read_bytes()), f
    rep_inc = resumed.report["incidents"]
    assert rep_inc is not None and rep_inc["total"] >= 1


def test_incident_stream_structure(storm_rundirs):
    """One open row per incident id, resolves pair with opens, and the
    summary rows land in id order after the transitions."""
    rundir, _, _ = storm_rundirs
    rows = [json.loads(line) for line in open(
        os.path.join(os.path.dirname(rundir), "incidents.jsonl"))]
    opens = [r["id"] for r in rows if r.get("kind") == "incident_open"]
    resolves = [r["id"] for r in rows if r.get("kind") == "incident_resolve"]
    assert len(set(opens)) == len(opens)
    assert set(resolves) <= set(opens)
    summaries = [r for r in rows if r.get("kind") == "incident"]
    assert [r["id"] for r in summaries] == sorted(opens)
    assert rows[0]["kind"] == "header" and rows[-1]["kind"] == "footer"
    assert rows[-1]["incidents"] == len(summaries)
