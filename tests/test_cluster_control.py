"""repro.cluster subsystem tests: deterministic event ordering, job-lifecycle
legality, fault-campaign ERROR_MIX proportions, agent staleness, heterogeneous
fleets, and the scenario report contract."""
import json

import numpy as np
import pytest

from repro.cluster import (ControlPlane, FaultCampaignConfig, FleetSpec,
                           GPUPool, JobManager, JobState, LifecycleError,
                           Scenario, run_scenario)
from repro.cluster.agents import AgentConfig
from repro.cluster.control import run_policy_scenario
from repro.cluster.events import EventBus, EventKind
from repro.cluster.run import check_schema
from repro.core.errors import ERROR_MIX, ErrorKind
from repro.core.predictor import build_speed_predictor
from repro.core.simulator import run_policy

TINY = dict(n_devices=48, hours=1.5, seed=9, predictor_samples=120,
            predictor_epochs=4)


@pytest.fixture(scope="module")
def predictor():
    return build_speed_predictor(gpu_types=("T4", "A10"), n=150, epochs=5)


def _scenario(**kw):
    base = dict(name="t", trace="C", keep_event_log=True, **TINY)
    base.update(kw)
    return Scenario(**base)


# ------------------------------------------------------------ event ordering
def test_event_stream_deterministic_under_fixed_seed(predictor):
    sc = _scenario(faults=FaultCampaignConfig(rate_per_device_hour=0.6),
                   agents=AgentConfig(drop_rate=0.05), autoscale=True)
    runs = []
    for _ in range(2):
        cp = ControlPlane(sc, predictor=predictor)
        cp.run()
        runs.append(cp)
    a, b = runs
    assert a.bus.digest() == b.bus.digest()
    assert [e.key() for e in a.bus.log] == [e.key() for e in b.bus.log]
    # seq numbers are a gapless total order
    seqs = [e.seq for e in a.bus.log]
    assert seqs == list(range(len(seqs)))
    # and a different seed produces a different stream
    cp3 = ControlPlane(_scenario(
        seed=10, faults=FaultCampaignConfig(rate_per_device_hour=0.6),
        agents=AgentConfig(drop_rate=0.05), autoscale=True),
        predictor=predictor)
    cp3.run()
    assert cp3.bus.digest() != a.bus.digest()


def test_event_time_is_nondecreasing(predictor):
    cp = ControlPlane(_scenario(
        faults=FaultCampaignConfig(rate_per_device_hour=0.4)),
        predictor=predictor)
    cp.run()
    ts = [e.t for e in cp.bus.log]
    assert all(t2 >= t1 for t1, t2 in zip(ts, ts[1:]))


# -------------------------------------------------------- lifecycle legality
def test_lifecycle_legal_under_fault_pressure(predictor):
    """Strict JobManager across a fault/failure-heavy run: no illegal
    transition (double placement, run-after-complete) ever fires."""
    sc = _scenario(faults=FaultCampaignConfig(rate_per_device_hour=1.5),
                   device_mtbf_h=20.0, error_rate_per_job_hour=0.3)
    cp = ControlPlane(sc, predictor=predictor)
    cp.run()                       # strict mode raises on violation
    jm = cp.job_manager
    assert not jm.violations
    s = jm.summary()
    assert s["n_jobs"] == sum(s["by_state"].values())
    # every engine-finished job is COMPLETED in the manager
    assert s["completed"] >= cp.results.n_finished
    assert s["total_preemptions"] > 0          # pressure actually preempted
    assert s["total_lost_work_s"] >= 0.0
    # re-placements after preemption pay the modeled restore cost
    if s["total_preemptions"]:
        assert s["total_restore_overhead_s"] >= 0.0
    # n_jobs flows through the engine's injected-job accounting
    assert cp.results.n_jobs == s["n_jobs"]


def test_event_bus_summary_exposes_dropped_event_count():
    bus = EventBus(keep_log=True, log_cap=3)
    for i in range(5):
        bus.emit(float(i), EventKind.SCHEDULE, data=(("round", i),))
    s = bus.summary()
    assert s["log_dropped"] == 2 and len(bus.log) == 3
    # digest/counts cover the FULL stream — only retention truncates
    assert s["n_events"] == 5 and s["counts"] == {"schedule": 5}
    full = EventBus(keep_log=True)
    for i in range(5):
        full.emit(float(i), EventKind.SCHEDULE, data=(("round", i),))
    assert full.summary()["log_dropped"] == 0
    assert full.digest() == s["digest"]
    # without keep_log nothing is retained, so nothing is "dropped"
    assert EventBus().summary()["log_dropped"] == 0


def test_job_manager_rejects_illegal_transitions():
    bus = EventBus()
    jm = JobManager(bus, strict=True)
    bus.emit(0.0, EventKind.JOB_SUBMIT, job=1,
             data=(("model", "ResNet50"), ("duration_s", 100.0)))
    bus.emit(10.0, EventKind.JOB_START, device=3, job=1)
    with pytest.raises(LifecycleError):        # double placement
        bus.emit(11.0, EventKind.JOB_START, device=4, job=1)
    bus.emit(50.0, EventKind.JOB_FINISH, device=3, job=1,
             data=(("jct_s", 50.0),))
    with pytest.raises(LifecycleError):        # run after complete
        bus.emit(60.0, EventKind.JOB_START, device=5, job=1)
    with pytest.raises(LifecycleError):        # finish after complete
        bus.emit(61.0, EventKind.JOB_FINISH, device=3, job=1)
    assert jm.jobs[1].state is JobState.COMPLETED


def test_job_manager_preemption_bookkeeping():
    bus = EventBus()
    jm = JobManager(bus, restart_delay_s=90.0, strict=True)
    bus.emit(0.0, EventKind.JOB_SUBMIT, job=7,
             data=(("model", "VGG16"), ("duration_s", 500.0)))
    bus.emit(30.0, EventKind.JOB_START, device=0, job=7)
    bus.emit(130.0, EventKind.JOB_EVICT, device=0, job=7,
             data=(("reason", "overlimit"), ("progress_s", 100.0),
                   ("checkpoint_s", 60.0), ("requeued", True)))
    bus.emit(200.0, EventKind.JOB_START, device=2, job=7)
    bus.emit(700.0, EventKind.JOB_FINISH, device=2, job=7,
             data=(("jct_s", 700.0),))
    rec = jm.jobs[7]
    assert rec.preemptions == 1 and rec.placements == 2
    assert rec.lost_work_s == pytest.approx(40.0)       # 100 - 60
    assert rec.restore_overhead_s == pytest.approx(90.0)
    assert rec.queue_wait_s == pytest.approx(30.0 + 70.0)


# ------------------------------------------------------------ fault campaign
def test_fault_campaign_matches_error_mix(predictor):
    """Injected kind counts follow the Fig. 7 production mix."""
    rep = run_scenario(
        "fault-storm", predictor=predictor, n_devices=300, hours=4.0, seed=1,
        faults=FaultCampaignConfig(rate_per_device_hour=4.0))
    f = rep["faults"]
    total = f["injected"]
    assert total > 400                       # enough mass to test proportions
    sig = (f["injected_by_kind"].get("sigint", 0)
           + f["injected_by_kind"].get("sigterm", 0))
    p_sig = (ERROR_MIX[ErrorKind.SIGINT] + ERROR_MIX[ErrorKind.SIGTERM])
    assert sig / total == pytest.approx(p_sig, abs=0.02)
    for kind in ("mps_server_crash", "xid31_page_fault", "mps_hang"):
        assert f["injected_by_kind"].get(kind, 0) / total < 0.03
    # engine accounting matches campaign accounting (campaign drives all
    # errors in fault-storm: the engine's own error process is off)
    assert rep["sim"]["errors_injected"] == total


def test_propagation_with_and_without_graceful_exit(predictor):
    on = run_scenario("fault-storm", predictor=predictor, n_devices=200,
                      hours=2.0, seed=0)
    off = run_scenario("fault-storm", predictor=predictor, n_devices=200,
                       hours=2.0, seed=0, graceful_exit=False)
    assert on["faults"]["injected"] > 30
    assert on["faults"]["propagation_rate"] < 0.01
    assert off["faults"]["propagation_rate"] > 0.50
    assert off["sim"]["online_incidents"] > 0
    assert on["sim"]["online_incidents"] == 0


# ------------------------------------------------------------------- agents
def test_agent_staleness_shrinks_schedulable_set(predictor):
    sc = _scenario(agents=AgentConfig(drop_rate=0.4, stale_after=1.0))
    cp = ControlPlane(sc, predictor=predictor)
    cp.run()
    s = cp.agents.summary()
    assert s["reports_dropped"] > 0
    assert s["stale_episodes"] > 0 and s["stale_device_ticks"] > 0
    assert cp.bus.counts.get("agent_stale", 0) == s["stale_episodes"]
    # recovery events exist too (agents come back on a successful heartbeat)
    assert cp.bus.counts.get("agent_fresh", 0) > 0
    snap = cp.agents.snapshot(now=sc.hours * 3600.0)
    assert snap["stale"].dtype == bool and len(snap["age_s"]) == sc.n_devices
    # the §4.3 recommendation derived from reported telemetry stays in-band
    reco = snap["dyn_sm_recommended"]
    assert np.all((reco >= 0.1 - 1e-12) & (reco <= 0.9 + 1e-12))


# ------------------------------------------------------ heterogeneous fleets
def test_fleet_spec_apportionment_exact():
    pools = (GPUPool("a", "T4", 0.6), GPUPool("b", "A10", 0.25, 1.35, 24.0),
             GPUPool("c", "A100", 0.15, 2.6, 40.0))
    fs = FleetSpec(1000, pools)
    assert sum(fs.counts) == 1000 and fs.counts == [600, 250, 150]
    assert len(fs.gpu_type) == 1000 and fs.speed.shape == (1000,)
    assert fs.gpu_types == ("T4", "A10", "A100")
    # odd sizes still sum exactly
    assert sum(FleetSpec(101, pools).counts) == 101


def test_per_pool_memory_feasibility(predictor):
    """An HBM-starved pool rejects pairings a roomy pool accepts."""
    sc = _scenario(pools=(
        GPUPool("tiny", "T4", 0.5, 1.0, hbm_gb=10.0),
        GPUPool("roomy", "T4", 0.5, 1.0, hbm_gb=32.0)))
    cp = ControlPlane(sc, predictor=predictor)
    feas = cp.sim.feasible
    assert feas.shape[0] == 2
    assert feas[0].sum() < feas[1].sum()
    # pool views carry the hbm sizes
    views = cp.sim.pool_view(0.0)
    assert [v["pool"] for v in views] == ["tiny", "roomy"]
    assert views[0]["hbm_gb"] == pytest.approx(10.0)


# ------------------------------------------------------- report + entry point
def test_report_schema_and_json_round_trip(predictor):
    rep = run_scenario("smoke", predictor=None)
    assert check_schema(rep) == []
    blob = json.dumps(rep, sort_keys=True)
    assert json.loads(blob) == rep


def test_mid_run_injection_counts(predictor):
    sc = _scenario()
    cp = ControlPlane(sc, predictor=predictor)
    cp.run()
    # every trace job was submitted by the control plane, none pre-loaded
    assert len(cp.sim.jobs) == 0
    assert cp.results.n_jobs == len(cp.trace_jobs)
    assert cp.bus.counts["job_submit"] == len(cp.trace_jobs)


def test_policy_passthrough_matches_run_policy(predictor):
    """With every control-plane feature neutral, ControlPlane reproduces
    run_policy exactly — same engine, same RNG stream."""
    # includes knobs only SimConfig (not the Scenario headline set) carries,
    # pinning that nothing is silently dropped on the way through
    kw = dict(n_devices=40, horizon_s=2 * 3600.0, tick_s=60.0, trace="B",
              seed=4, memory_quota=0.3, device_repair_s=900.0,
              checkpoint_interval_s=240.0, gpu_types=("T4", "A10", "A10"))
    ref = run_policy("muxflow", predictor, **kw)
    got = run_policy_scenario("muxflow", predictor, **kw)
    for f in ("n_jobs", "n_finished", "evictions", "errors_injected",
              "online_incidents"):
        assert getattr(got, f) == getattr(ref, f), f
    assert got.avg_slowdown == pytest.approx(ref.avg_slowdown, rel=1e-12)
    assert got.oversold_gpu == pytest.approx(ref.oversold_gpu, rel=1e-12)
    # a horizon whose seconds->hours->seconds conversion does NOT round-trip
    # (1950/3600*3600 != 1950): the exact horizon must still carry through
    kw2 = dict(n_devices=20, horizon_s=1950.0, tick_s=30.0, trace="B",
               seed=3)
    ref2 = run_policy("time-sharing", None, **kw2)
    got2 = run_policy_scenario("time-sharing", None, **kw2)
    assert got2.gpu_util == ref2.gpu_util
    assert got2.n_jobs == ref2.n_jobs


# ---------------------------------------------------------------- event bus
def test_event_bus_counts_digest_and_subscribers():
    bus = EventBus(keep_log=True)
    seen = []
    bus.subscribe(lambda e: seen.append(("one", e.seq)), EventKind.ERROR)
    bus.subscribe(lambda e: seen.append(("all", e.seq)))
    bus.emit(0.0, EventKind.ERROR, device=1, data=(("kind", "sigint"),))
    bus.emit(1.0, EventKind.SCHEDULE, data=(("free", 3),))
    assert bus.counts == {"error": 1, "schedule": 1}
    assert seen == [("one", 0), ("all", 0), ("all", 1)]
    d1 = bus.digest()
    bus.emit(2.0, EventKind.ERROR, device=2)
    assert bus.digest() != d1
    assert bus.n_events == 3 and len(bus.log) == 3
