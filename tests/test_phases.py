"""PhaseProfiler unit tests with a deterministic fake clock: accumulation,
the nested-phase exclusion arithmetic, summary shape, and the stderr
table.  (The quarantine of these wall-clock numbers from deterministic
artifacts is covered in test_obs.py.)"""
from repro.obs import PhaseProfiler
from repro.obs.phases import PHASES


class _Clock:
    """A clock advancing 1.0 per call: every timed block 'lasts' exactly
    the number of clock reads inside it, so assertions are exact."""

    def __init__(self):
        self.now = 0.0

    def __call__(self):
        self.now += 1.0
        return self.now


def test_add_and_total_accumulate():
    p = PhaseProfiler(clock=_Clock())
    p.add("match", 0.25)
    p.add("match", 0.5)
    p.add("inputs", 1.0)
    assert p.total("match") == 0.75
    assert p.calls == {"match": 2, "inputs": 1}
    assert p.total("never-timed") == 0.0


def test_phase_context_times_the_block():
    p = PhaseProfiler(clock=_Clock())
    with p.phase("dense_core"):
        pass  # enter-read then exit-read: dt = 1.0
    assert p.total("dense_core") == 1.0
    assert p.calls["dense_core"] == 1


def test_nested_exclusion_subtracts_inner_growth():
    p = PhaseProfiler(clock=_Clock())
    with p.phase("account", exclude=("serving",)):
        with p.phase("serving"):
            pass
    # outer block spans 4 clock reads (dt=3), inner spans 2 (dt=1);
    # exclusion leaves account with only its own 2.0
    assert p.total("serving") == 1.0
    assert p.total("account") == 2.0


def test_exclusion_only_counts_growth_inside_the_block():
    p = PhaseProfiler(clock=_Clock())
    with p.phase("serving"):
        pass
    before = p.total("serving")
    with p.phase("account", exclude=("serving",)):
        pass  # no serving activity inside: nothing subtracted
    assert p.total("serving") == before
    assert p.total("account") == 1.0


def test_phase_records_even_when_block_raises():
    p = PhaseProfiler(clock=_Clock())
    try:
        with p.phase("match"):
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert p.calls["match"] == 1 and p.total("match") == 1.0


def test_summary_shape_and_rounding():
    p = PhaseProfiler(clock=_Clock())
    p.add("inputs", 0.1234567891)
    p.add("match", 2.0)
    s = p.summary()
    assert set(s) == {"phases", "total_s"}
    assert s["phases"]["inputs"] == {"wall_s": 0.123457, "calls": 1}
    assert s["phases"]["match"] == {"wall_s": 2.0, "calls": 1}
    assert s["total_s"] == round(0.1234567891 + 2.0, 6)
    assert list(s["phases"]) == sorted(s["phases"])


def test_format_table_orders_known_phases_then_extras():
    p = PhaseProfiler(clock=_Clock())
    p.add("serving", 1.0)
    p.add("inputs", 1.0)
    p.add("zz_custom", 1.0)
    p.add("aa_custom", 1.0)
    lines = p.format_table().splitlines()
    names = [ln.split()[1] for ln in lines[1:-1]]
    # canonical pipeline order first, unknown phases sorted after
    assert names == ["inputs", "serving", "aa_custom", "zz_custom"]
    assert all(ln.startswith("[phases]") for ln in lines)
    assert lines[-1].split()[1] == "total"
    assert PHASES[0] == "inputs"  # the order the table leans on


def test_format_table_empty_profiler_degrades_gracefully():
    p = PhaseProfiler(clock=_Clock())
    out = p.format_table()
    assert "total" in out  # header + total line, no division by zero
