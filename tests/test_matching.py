"""KM matching: exactness vs brute force + scipy, validity properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.matching import brute_force_match, km_match, matching_weight

try:
    from scipy.optimize import linear_sum_assignment
    HAVE_SCIPY = True
except Exception:  # pragma: no cover
    HAVE_SCIPY = False


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 10_000))
def test_km_optimal_vs_brute_force(n, m, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0, 1, (n, m))
    pairs = km_match(w)
    got = matching_weight(w, pairs)
    want = brute_force_match(w)
    assert got == pytest.approx(want, rel=1e-9, abs=1e-9)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 10_000))
def test_km_matching_is_valid(n, m, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0, 1, (n, m))
    pairs = km_match(w)
    rows = [r for r, _ in pairs]
    cols = [c for _, c in pairs]
    assert len(set(rows)) == len(rows), "row matched twice"
    assert len(set(cols)) == len(cols), "col matched twice"
    assert all(0 <= r < n and 0 <= c < m for r, c in pairs)
    assert all(w[r, c] > 0 for r, c in pairs)


@pytest.mark.skipif(not HAVE_SCIPY, reason="scipy unavailable")
@pytest.mark.parametrize("seed", range(5))
def test_km_matches_scipy(seed):
    rng = np.random.default_rng(seed)
    n, m = rng.integers(5, 60), rng.integers(5, 60)
    w = rng.uniform(0.01, 1, (n, m))
    got = matching_weight(w, km_match(w))
    # scipy maximizes on the padded square the same way
    k = max(n, m)
    pad = np.zeros((k, k))
    pad[:n, :m] = w
    ri, ci = linear_sum_assignment(pad, maximize=True)
    want = pad[ri, ci].sum()
    assert got == pytest.approx(want, rel=1e-9)


def test_km_zero_and_empty():
    assert km_match(np.zeros((3, 4))) == []
    assert km_match(np.zeros((0, 0))) == []


def test_km_prefers_heavier_plan_paper_example():
    # Figure 9: plan1 (A-D, B-C) = 1.6 beats plan2 (A-C, B-E) = 0.7
    #    C    D    E
    w = np.array([[0.3, 0.8, 0.1],   # A
                  [0.8, 0.1, 0.4]])  # B
    pairs = km_match(w)
    assert matching_weight(w, pairs) == pytest.approx(1.6)
    assert set(pairs) == {(0, 1), (1, 0)}
