"""KM matching: exactness vs brute force + scipy, validity properties."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.matching import brute_force_match, km_match, matching_weight

try:
    from scipy.optimize import linear_sum_assignment
    HAVE_SCIPY = True
except Exception:  # pragma: no cover
    HAVE_SCIPY = False


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 10_000))
def test_km_optimal_vs_brute_force(n, m, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0, 1, (n, m))
    pairs = km_match(w)
    got = matching_weight(w, pairs)
    want = brute_force_match(w)
    assert got == pytest.approx(want, rel=1e-9, abs=1e-9)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 10_000))
def test_km_matching_is_valid(n, m, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0, 1, (n, m))
    pairs = km_match(w)
    rows = [r for r, _ in pairs]
    cols = [c for _, c in pairs]
    assert len(set(rows)) == len(rows), "row matched twice"
    assert len(set(cols)) == len(cols), "col matched twice"
    assert all(0 <= r < n and 0 <= c < m for r, c in pairs)
    assert all(w[r, c] > 0 for r, c in pairs)


@pytest.mark.skipif(not HAVE_SCIPY, reason="scipy unavailable")
@pytest.mark.parametrize("seed", range(5))
def test_km_matches_scipy(seed):
    rng = np.random.default_rng(seed)
    n, m = rng.integers(5, 60), rng.integers(5, 60)
    w = rng.uniform(0.01, 1, (n, m))
    got = matching_weight(w, km_match(w))
    # scipy maximizes on the padded square the same way
    k = max(n, m)
    pad = np.zeros((k, k))
    pad[:n, :m] = w
    ri, ci = linear_sum_assignment(pad, maximize=True)
    want = pad[ri, ci].sum()
    assert got == pytest.approx(want, rel=1e-9)


def test_km_zero_and_empty():
    assert km_match(np.zeros((3, 4))) == []
    assert km_match(np.zeros((0, 0))) == []


def test_km_prefers_heavier_plan_paper_example():
    # Figure 9: plan1 (A-D, B-C) = 1.6 beats plan2 (A-C, B-E) = 0.7
    #    C    D    E
    w = np.array([[0.3, 0.8, 0.1],   # A
                  [0.8, 0.1, 0.4]])  # B
    pairs = km_match(w)
    assert matching_weight(w, pairs) == pytest.approx(1.6)
    assert set(pairs) == {(0, 1), (1, 0)}


# ---------------------------------------------------------------------- shard
def test_sharded_match_exact_vs_brute_force():
    """Within one shard the partitioned matcher is the dense exact KM."""
    from repro.core.matching import sharded_match

    rng = np.random.default_rng(0)
    for _ in range(120):
        n, m = rng.integers(1, 8, 2)
        w = rng.uniform(0, 1, (n, m))
        got = matching_weight(w, sharded_match(w))
        assert got == pytest.approx(brute_force_match(w), rel=1e-9, abs=1e-9)


def test_sharded_match_valid_and_near_dense_on_scheduler_instances():
    """Scheduler-shaped instances (few distinct offline models => duplicated
    weight columns): sharded matching stays within 1% of dense KM weight."""
    from repro.core.matching import sharded_match_compact

    rng = np.random.default_rng(7)
    for n, m in ((500, 200), (300, 700), (600, 600)):
        vals = rng.uniform(0, 1, (n, 4))
        grp = rng.integers(0, 4, m)
        w = vals[:, grp]
        dense = matching_weight(w, km_match(w))
        pairs = sharded_match_compact(vals, grp, shard_size=128)
        rows = [r for r, _ in pairs]
        cols = [c for _, c in pairs]
        assert len(set(rows)) == len(rows) and len(set(cols)) == len(cols)
        assert all(0 <= r < n and 0 <= c < m for r, c in pairs)
        assert matching_weight(w, pairs) >= 0.99 * dense


def test_sharded_match_prunes_min_weight():
    from repro.core.matching import sharded_match

    w = np.array([[0.5, 0.01], [0.015, 0.4]])
    pairs = sharded_match(w, min_weight=0.02)
    assert pairs == [(0, 0), (1, 1)]
    assert all(w[r, c] >= 0.02 for r, c in pairs)


def test_sharded_match_scales_far_beyond_dense():
    """20k devices x 1k jobs completes in seconds (dense KM would pad to a
    20k^3 problem); every job lands somewhere with positive weight."""
    import time

    from repro.core.matching import sharded_match_compact

    rng = np.random.default_rng(3)
    n, m = 20_000, 1_000
    vals = rng.uniform(0.1, 1, (n, 4))
    grp = rng.integers(0, 4, m)
    t0 = time.perf_counter()
    pairs = sharded_match_compact(vals, grp, shard_size=256)
    assert time.perf_counter() - t0 < 10.0
    assert len(pairs) == m
