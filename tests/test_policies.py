"""SharingPolicy registry contract suite.

Every registered policy — current and future — must satisfy the array
contract (`shared_performance` shapes/bounds, `sm_shares` in [0, 1],
`scheduler_config` typing) and run end-to-end through the engine; the
registry itself must resolve strings, instances, and aliases, and fail
loudly (a real ValueError listing the available names, never an assert) on
unknown policies.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.interference import (OFFLINE_MODEL_PROFILES,
                                     offline_profile_arrays,
                                     online_profile_arrays)
from repro.core.scheduler import SchedulerConfig
from repro.core.simulator import ClusterSim, SimConfig, run_policy
from repro.core.traces import SERVICES
from repro.policies import (MuxFlowPolicy, SharingPolicy, available,
                            register, resolve, unregister)

TINY = dict(n_devices=16, horizon_s=3600.0, tick_s=60.0, trace="B", seed=5)


@pytest.fixture(scope="module")
def predictor():
    from repro.core.predictor import build_speed_predictor
    return build_speed_predictor(gpu_types=("T4", "A10"), n=150, epochs=5)


def _fleet_arrays(n=32, seed=0):
    """Synthetic per-device online/offline profile arrays for a small fleet
    spanning every service and offline model."""
    rng = np.random.default_rng(seed)
    sidx = np.arange(n) % len(SERVICES)
    qps = rng.uniform(1.0, 160.0, n)
    on = online_profile_arrays(sidx, qps, SERVICES)
    models = tuple(OFFLINE_MODEL_PROFILES)
    off = offline_profile_arrays(rng.integers(0, len(models), n), models)
    shares = rng.uniform(0.1, 0.9, n)
    return on, off, shares


@pytest.mark.parametrize("name", available())
def test_policy_array_contract(name):
    pol = resolve(name)
    n = 32
    on, off, shares = _fleet_arrays(n)
    slow, tput = pol.shared_performance(on, off, shares)
    assert slow.shape == (n,) and tput.shape == (n,)
    assert np.all(slow >= 1.0), f"{name}: slowdown below 1.0"
    assert np.all((tput >= 0.0) & (tput <= 1.0)), f"{name}: tput outside [0,1]"
    idx = np.arange(0, n, 3)
    sh = pol.sm_shares(on, idx)
    assert sh.shape == idx.shape
    assert np.all((sh >= 0.0) & (sh <= 1.0))
    sc = pol.scheduler_config(shard_size=128)
    assert sc is None or isinstance(sc, SchedulerConfig)
    if sc is not None:
        assert sc.shard_size == 128


@pytest.mark.parametrize("name", available())
def test_every_policy_runs_end_to_end(name, predictor):
    pol = resolve(name)
    r = run_policy(name, predictor if pol.needs_predictor else None, **TINY)
    assert r.policy == name
    assert r.avg_slowdown >= 1.0 - 1e-9
    assert 0.0 <= r.oversold_gpu <= 1.0
    # policy tput is in [0,1]; the engine then scales by hardware speed
    # (A10 = 1.35x in the default fleet)
    assert 0.0 <= r.avg_norm_tput <= 1.35


def test_dedicated_is_exactly_idle():
    on, off, shares = _fleet_arrays()
    pol = resolve("online-only")
    slow, tput = pol.shared_performance(on, off, shares)
    assert np.all(slow == 1.0) and np.all(tput == 0.0)
    assert not pol.wants_scheduling


def test_dedicated_alias():
    assert resolve("dedicated") is resolve("online-only")
    assert "dedicated" not in available()       # canonical names only


def test_unknown_policy_error_lists_available():
    with pytest.raises(ValueError) as ei:
        run_policy("no-such-policy", **TINY)
    msg = str(ei.value)
    for name in available():
        assert name in msg


def test_engine_raises_valueerror_not_assert():
    """ISSUE 3 satellite: registry resolution is a real ValueError from
    ClusterSim construction (asserts vanish under ``python -O``)."""
    with pytest.raises(ValueError, match="available"):
        ClusterSim(SimConfig(policy="bogus"))


def test_predictor_requirement_enforced():
    with pytest.raises(ValueError, match="needs a speed predictor"):
        run_policy("muxflow", None, **TINY)


def test_string_vs_instance_byte_identical(predictor):
    """A registry-resolved name and a freshly constructed policy instance
    must produce byte-identical SimResults."""
    a = run_policy("muxflow", predictor, **TINY)
    b = run_policy(MuxFlowPolicy(), predictor, **TINY)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)


def test_register_custom_policy_roundtrip():
    """The README's "add your own policy" path: subclass, register, run by
    name — no engine edits."""

    class FiftyFifty(SharingPolicy):
        name = "test-fifty-fifty"
        description = "test-only: constant half-speed sharing"

        def shared_performance(self, on, off, shares):
            n = on["gpu_util"].shape[0]
            return np.full(n, 1.1), np.full(n, 0.5)

    pol = register(FiftyFifty())
    try:
        assert "test-fifty-fifty" in available()
        r = run_policy("test-fifty-fifty", **TINY)
        assert r.policy == "test-fifty-fifty"
        # 0.5 per device, scaled by hardware speed (T4 1.0x / A10 1.35x)
        assert 0.5 - 1e-9 <= r.avg_norm_tput <= 0.5 * 1.35 + 1e-9
        # duplicate name bound to a different object must be rejected
        with pytest.raises(ValueError, match="already registered"):
            register(FiftyFifty())
        register(pol)                       # same object: idempotent
    finally:
        unregister("test-fifty-fifty")
    assert "test-fifty-fifty" not in available()


class _TmpPolicy(SharingPolicy):
    name = "test-tmp"

    def shared_performance(self, on, off, shares):
        n = on["gpu_util"].shape[0]
        return np.ones(n), np.zeros(n)


def test_unregister_removes_aliases_too():
    """available() must never advertise a name resolve() would reject:
    removing a policy via any of its keys drops all of them."""
    register(_TmpPolicy(), aliases=("test-tmp-alias",))
    try:
        assert resolve("test-tmp-alias") is resolve("test-tmp")
    finally:
        unregister("test-tmp-alias")
    assert "test-tmp" not in available()
    with pytest.raises(ValueError):
        resolve("test-tmp")
    with pytest.raises(ValueError):
        resolve("test-tmp-alias")


def test_register_rejects_unnamed_policy():
    """Forgetting the `name` class attribute fails fast at register() time
    instead of binding the policy under the base-class placeholder."""

    class Nameless(SharingPolicy):
        def shared_performance(self, on, off, shares):
            n = on["gpu_util"].shape[0]
            return np.ones(n), np.zeros(n)

    with pytest.raises(ValueError, match="must set a unique `name`"):
        register(Nameless())
    assert "unnamed" not in available()


def test_register_is_atomic_on_alias_collision():
    """A rejected registration (alias colliding with an existing name) must
    leave the registry untouched — no half-registered policy."""
    with pytest.raises(ValueError, match="already registered"):
        register(_TmpPolicy(), aliases=("muxflow",))
    assert "test-tmp" not in available()
    with pytest.raises(ValueError):
        resolve("test-tmp")
