"""Property tests: interference-model invariants, trace determinism,
dynamic-SM quantization, report generation."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.dynamic_sm import dynamic_sm
from repro.core.interference import (OFFLINE_MODEL_PROFILES, online_profile,
                                     qps_to_activity, shared_performance)
from repro.core.traces import OnlineQPS, make_trace, philly_like_trace

svc = st.sampled_from(["recommend", "translate", "vision"])
offm = st.sampled_from(list(OFFLINE_MODEL_PROFILES))


@settings(max_examples=150, deadline=None)
@given(svc, st.floats(0.0, 250.0), offm, st.floats(0.0, 1.0))
def test_shared_performance_invariants(service, qps, model, sm):
    on = online_profile(service, qps)
    off = OFFLINE_MODEL_PROFILES[model]
    slow, tput = shared_performance(on, off, sm)
    assert slow >= 1.0                      # sharing never speeds online up
    assert 0.0 <= tput <= 1.0               # normalized throughput
    # zero share => no offline progress, (almost) no online impact
    slow0, tput0 = shared_performance(on, off, 0.0)
    assert tput0 == 0.0
    assert slow0 <= 1.05


@settings(max_examples=80, deadline=None)
@given(svc, st.floats(5.0, 60.0), offm,
       st.floats(0.1, 0.5), st.floats(0.5, 0.9))
def test_more_sm_more_offline_tput_when_online_idle(service, qps, model,
                                                    lo, hi):
    """With a lightly-loaded online partner, offline tput is monotone in the
    SM share (no contention regime)."""
    on = online_profile(service, qps)
    off = OFFLINE_MODEL_PROFILES[model]
    _, t_lo = shared_performance(on, off, lo)
    _, t_hi = shared_performance(on, off, hi)
    assert t_hi >= t_lo - 1e-9


@settings(max_examples=60, deadline=None)
@given(st.floats(0, 500), st.floats(10, 500), st.floats(0.05, 1.0))
def test_qps_activity_saturates(qps, cap, peak):
    a = qps_to_activity(qps, cap, peak)
    assert 0.0 <= a <= peak + 1e-9


@settings(max_examples=60, deadline=None)
@given(st.floats(0, 1))
def test_dynamic_sm_bounds_and_quantization(a_on):
    s = dynamic_sm(a_on)
    assert 0.1 <= s <= 0.9
    assert abs(s / 0.1 - round(s / 0.1)) < 1e-9     # 10% MPS steps


def test_online_qps_deterministic_and_in_range():
    rng = np.random.default_rng(7)
    q = OnlineQPS(rng)
    vals = [q.qps(t) for t in np.linspace(0, 86400, 200)]
    q2 = OnlineQPS(np.random.default_rng(7))
    vals2 = [q2.qps(t) for t in np.linspace(0, 86400, 200)]
    assert vals == vals2
    assert min(vals) >= 20.0 and max(vals) <= 190.0 * 1.3


def test_trace_generation_properties():
    jobs = make_trace("B", n_devices=100, horizon_s=12 * 3600.0)
    assert len(jobs) > 100
    subs = [j.submit_s for j in jobs]
    assert subs == sorted(subs)
    assert all(600.0 <= j.duration_s <= 8 * 3600.0 for j in jobs)
    # trace load factors ordered A < B < C < D
    sizes = [len(make_trace(t, 100, 12 * 3600.0)) for t in "ABCD"]
    assert sizes == sorted(sizes)


def test_report_renders(tmp_path, monkeypatch):
    """Render the dry-run/roofline tables from records (synthetic here — the
    real ones are produced by launch/dryrun.py into experiments/dryrun)."""
    import json

    from repro.launch import report

    ok = {"arch": "gemma_7b", "shape": "train_4k", "status": "ok",
          "compile_s": 12.0, "memory": {"peak_device_bytes": 8 * 2 ** 30},
          "hlo": {"dot_flops": 1e12, "bytes": 2e11, "collective_bytes": 1e10,
                  "collective_breakdown": {"all-reduce": 1e10}},
          "terms": {"compute_s": 0.01, "memory_s": 0.02, "collective_s": 0.005},
          "dominant": "memory", "model_flops": 9e11, "useful_ratio": 0.9,
          "roofline_fraction": 0.4}
    bad = {"arch": "gemma_7b", "shape": "prefill_32k", "status": "oom",
           "reason": "hbm exhausted"}
    (tmp_path / "gemma_7b__train_4k__16x16.json").write_text(json.dumps(ok))
    (tmp_path / "gemma_7b__prefill_32k__16x16.json").write_text(json.dumps(bad))
    monkeypatch.setattr(report, "OUT_DIR", str(tmp_path))
    txt = report.dryrun_section("16x16")
    assert "| arch |" in txt and "gemma_7b" in txt and "oom" in txt
    roof = report.roofline_section()
    assert "dominant" in roof and "train_4k" in roof


def test_vectorized_profile_and_sharing_match_scalar():
    """Array-shaped helpers agree with the scalar functions bitwise — the
    vectorized and per-device simulator engines rely on this."""
    from repro.core.interference import (offline_profile_arrays,
                                         online_profile_arrays,
                                         shared_performance_arrays)

    rng = np.random.default_rng(0)
    services = ("recommend", "translate", "vision")
    models = tuple(OFFLINE_MODEL_PROFILES)
    n = 512
    sidx = rng.integers(0, len(services), n)
    midx = rng.integers(0, len(models), n)
    qps = rng.uniform(0.0, 250.0, n)
    share = rng.uniform(0.0, 1.0, n)
    on = online_profile_arrays(sidx, qps, services)
    off = offline_profile_arrays(midx, models)
    slow_v, tput_v = shared_performance_arrays(on, off, share)
    for i in range(0, n, 7):
        p = online_profile(services[sidx[i]], float(qps[i]))
        # libm vs numpy transcendentals may differ in the last ULP
        assert p.gpu_util == on["gpu_util"][i]
        assert p.sm_activity == pytest.approx(on["sm_activity"][i], rel=1e-14)
        assert p.mem_bw == on["mem_bw"][i]
        # given *identical* profile inputs (what both engines consume), the
        # scalar and vector sharing model agree bitwise
        import dataclasses as _dc

        p_arr = _dc.replace(p, sm_activity=float(on["sm_activity"][i]),
                            sm_occupancy=float(on["sm_occupancy"][i]))
        slow, tput = shared_performance(
            p_arr, OFFLINE_MODEL_PROFILES[models[midx[i]]], float(share[i]))
        assert slow == slow_v[i] and tput == tput_v[i]


def test_qps_bank_matches_scalar_curves():
    from repro.core.traces import QPSBank

    rng = np.random.default_rng(5)
    curves = [OnlineQPS(rng) for _ in range(64)]
    bank = QPSBank(curves)
    for t in (0.0, 333.0, 7200.0, 50000.0, 86399.0, 100000.0):
        v = bank.qps(t)
        for i in (0, 13, 63):
            # same math up to libm-vs-numpy sin ULPs
            assert v[i] == pytest.approx(curves[i].qps(t), rel=1e-12, abs=1e-9)
