"""Property tests: interference-model invariants, trace determinism,
dynamic-SM quantization, report generation."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dynamic_sm import dynamic_sm
from repro.core.interference import (OFFLINE_MODEL_PROFILES, online_profile,
                                     qps_to_activity, shared_performance)
from repro.core.traces import OnlineQPS, make_trace, philly_like_trace

svc = st.sampled_from(["recommend", "translate", "vision"])
offm = st.sampled_from(list(OFFLINE_MODEL_PROFILES))


@settings(max_examples=150, deadline=None)
@given(svc, st.floats(0.0, 250.0), offm, st.floats(0.0, 1.0))
def test_shared_performance_invariants(service, qps, model, sm):
    on = online_profile(service, qps)
    off = OFFLINE_MODEL_PROFILES[model]
    slow, tput = shared_performance(on, off, sm)
    assert slow >= 1.0                      # sharing never speeds online up
    assert 0.0 <= tput <= 1.0               # normalized throughput
    # zero share => no offline progress, (almost) no online impact
    slow0, tput0 = shared_performance(on, off, 0.0)
    assert tput0 == 0.0
    assert slow0 <= 1.05


@settings(max_examples=80, deadline=None)
@given(svc, st.floats(5.0, 60.0), offm,
       st.floats(0.1, 0.5), st.floats(0.5, 0.9))
def test_more_sm_more_offline_tput_when_online_idle(service, qps, model,
                                                    lo, hi):
    """With a lightly-loaded online partner, offline tput is monotone in the
    SM share (no contention regime)."""
    on = online_profile(service, qps)
    off = OFFLINE_MODEL_PROFILES[model]
    _, t_lo = shared_performance(on, off, lo)
    _, t_hi = shared_performance(on, off, hi)
    assert t_hi >= t_lo - 1e-9


@settings(max_examples=60, deadline=None)
@given(st.floats(0, 500), st.floats(10, 500), st.floats(0.05, 1.0))
def test_qps_activity_saturates(qps, cap, peak):
    a = qps_to_activity(qps, cap, peak)
    assert 0.0 <= a <= peak + 1e-9


@settings(max_examples=60, deadline=None)
@given(st.floats(0, 1))
def test_dynamic_sm_bounds_and_quantization(a_on):
    s = dynamic_sm(a_on)
    assert 0.1 <= s <= 0.9
    assert abs(s / 0.1 - round(s / 0.1)) < 1e-9     # 10% MPS steps


def test_online_qps_deterministic_and_in_range():
    rng = np.random.default_rng(7)
    q = OnlineQPS(rng)
    vals = [q.qps(t) for t in np.linspace(0, 86400, 200)]
    q2 = OnlineQPS(np.random.default_rng(7))
    vals2 = [q2.qps(t) for t in np.linspace(0, 86400, 200)]
    assert vals == vals2
    assert min(vals) >= 20.0 and max(vals) <= 190.0 * 1.3


def test_trace_generation_properties():
    jobs = make_trace("B", n_devices=100, horizon_s=12 * 3600.0)
    assert len(jobs) > 100
    subs = [j.submit_s for j in jobs]
    assert subs == sorted(subs)
    assert all(600.0 <= j.duration_s <= 8 * 3600.0 for j in jobs)
    # trace load factors ordered A < B < C < D
    sizes = [len(make_trace(t, 100, 12 * 3600.0)) for t in "ABCD"]
    assert sizes == sorted(sizes)


def test_report_renders():
    from repro.launch import report
    txt = report.dryrun_section("16x16")
    assert "| arch |" in txt
    roof = report.roofline_section()
    assert "dominant" in roof and "train_4k" in roof
