"""Signed run manifests: HMAC round-trips, tamper detection, key handling."""
import json
import os

import pytest

from repro.durability.manifest import (KEY_ENV, build_manifest, file_sha256,
                                       sign_manifest, verify_manifest,
                                       write_manifest)


@pytest.fixture
def rundir(tmp_path):
    d = tmp_path / "run"
    d.mkdir()
    (d / "a.json").write_text('{"x": 1}\n')
    (d / "sub").mkdir()
    (d / "sub" / "b.bin").write_bytes(b"\x00\x01\x02")
    return d


def _write(rundir, arts=("a.json", "sub/b.bin")):
    manifest = build_manifest(
        str(rundir), [str(rundir / a) for a in arts], {"scenario": "smoke"})
    path = str(rundir / "manifest.json")
    write_manifest(path, manifest)
    return path, manifest


class TestManifest:
    def test_round_trip_ok(self, rundir):
        path, manifest = _write(rundir)
        assert verify_manifest(path) == []
        assert set(manifest["artifacts"]) == {"a.json", "sub/b.bin"}
        sha, size = file_sha256(str(rundir / "a.json"))
        assert manifest["artifacts"]["a.json"] == {"sha256": sha,
                                                   "bytes": size}

    def test_signature_deterministic(self, rundir):
        _, m1 = _write(rundir)
        _, m2 = _write(rundir)
        assert m1["signature"] == m2["signature"]

    def test_tampered_artifact_detected(self, rundir):
        path, _ = _write(rundir)
        with open(rundir / "a.json", "a") as f:
            f.write("tamper")
        problems = verify_manifest(path)
        assert any("a.json" in p for p in problems)

    def test_tampered_body_detected(self, rundir):
        path, _ = _write(rundir)
        with open(path) as f:
            doc = json.load(f)
        doc["run"]["scenario"] = "evil"
        with open(path, "w") as f:
            json.dump(doc, f)
        assert any("signature" in p for p in verify_manifest(path))

    def test_missing_artifact_detected(self, rundir):
        path, _ = _write(rundir)
        os.unlink(rundir / "sub" / "b.bin")
        assert any("b.bin" in p for p in verify_manifest(path))

    def test_signature_only_mode_skips_files(self, rundir):
        path, _ = _write(rundir)
        os.unlink(rundir / "sub" / "b.bin")
        assert verify_manifest(path, check_files=False) == []

    def test_key_env_changes_signature(self, rundir, monkeypatch):
        _, dev = _write(rundir)
        monkeypatch.setenv(KEY_ENV, "prod-secret")
        path, prod = _write(rundir)
        assert prod["signature"] != dev["signature"]
        assert verify_manifest(path) == []          # verifies under same env
        monkeypatch.delenv(KEY_ENV)
        assert any("signature" in p for p in verify_manifest(path))

    def test_sign_ignores_existing_signature_field(self):
        body = {"schema": "s", "run": {}, "artifacts": {}}
        sig = sign_manifest(body)
        assert sign_manifest({**body, "signature": "junk"}) == sig
